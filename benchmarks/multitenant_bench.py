"""Benchmark: cross-tenant page arbitration (the Memshare-style layer).

N tenants with divergent size distributions (paper operating points)
share one physical page pool, their demand peaking out of phase
(``multitenant_phased_ops``: raised-cosine arrival intensity offset by
1/N period per tenant, plus TTL churn so an off-peak tenant's pages
fill with free chunks). Three memory policies:

* ``static``     — each tenant owns a fixed equal share of the pool
                   (quota = total/N, never moved). The classic sizing
                   answer; a peaking tenant evicts while its idle
                   neighbour holds half-empty pages.
* ``pooled``     — no quotas, first-come-first-served page grabs. Better
                   while the pool has slack, but pages stick with
                   whoever grabbed them first: once the pool is
                   exhausted, an off-peak tenant's cold, hole-riddled
                   pages are unreachable to the tenant at peak.
* ``arbitrated`` — equal quotas plus the :class:`TenantArbiter`: the
                   pressure signal (eviction payload + page denials)
                   picks the recipient, the cheapest reclaimable page
                   picks the donor, the controller's cost model gates
                   the transfer, and quota + page move donor→recipient.

Every mode runs the same per-tenant *intra*-tenant adaptive controllers
(the PR-1 loop), so the deltas below isolate the *inter*-tenant layer.

The measurement is the paper's, lifted to the pool level: **memory
holes** = pool bytes not holding live payload (internal fragmentation
+ page tails + free chunks + idle pages), sampled along the stream.
``cum_hole_byte_ops`` integrates hole bytes over op time; arbitration
wins by keeping more live payload resident in the same physical pool
(fewer pressure evictions at each tenant's peak).

``python benchmarks/multitenant_bench.py`` emits the comparison as
JSON; ``run()`` returns the CSV rows for ``benchmarks/run.py``.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Sequence, Tuple

from repro.core import ControllerConfig, PagePool, TenantArbiter
from repro.core.distribution import PAPER_WORKLOADS
from repro.core.slab_policy import default_memcached_schedule
from repro.memcached import SlabAllocator, multitenant_phased_ops

PAGE_SIZE = 1 << 16       # 64 KiB pages: item sizes are 0.5-8 KiB, so a
#                           page is a meaningful arbitration quantum
TOTAL_PAGES = 88          # 5.5 MiB: between the aggregate demand trough
#                           (~4.6 MiB) and peak (~7.4 MiB) of the default
#                           stream, so tenants genuinely contend
N_SETS = 30_000
K = 6
MODES = ("static", "pooled", "arbitrated")


def build_arbiter(mode: str, n_tenants: int, *,
                  total_pages: int = TOTAL_PAGES,
                  page_size: int = PAGE_SIZE,
                  arbitrate_every: int = 1000) -> TenantArbiter:
    """One shared pool + N tenants under the given memory policy.

    All modes run through the same ``TenantArbiter`` object so the
    per-tenant refit pipeline is identical; the baselines simply never
    reach the arbitration cadence.
    """
    pool = PagePool(total_pages, page_size=page_size)
    cfg = ControllerConfig(
        k=K, page_size=page_size, check_every=2000, half_life=4000.0,
        drift_threshold=0.12, min_items_between_refits=4000,
        # TTL-churned cache traffic: victims are mostly expired-soon
        # items, so a migration byte is cheap next to a recurring
        # waste byte (same reasoning as adaptive_bench)
        amortization_windows=8.0, cost_weight=0.1)
    arb = TenantArbiter(
        pool, controller_config=cfg,
        arbitrate_every=(arbitrate_every if mode == "arbitrated"
                         else 1 << 62),
        amortization_windows=8.0, cost_weight=0.1)
    classes = default_memcached_schedule(page_size=page_size)
    for t in range(n_tenants):
        name = f"tenant{t}"
        alloc = SlabAllocator(classes, page_size=page_size,
                              page_pool=pool, tenant=name)
        arb.register(name, alloc, floor_pages=total_pages // (4 * n_tenants))
    if mode in ("static", "arbitrated"):
        pool.equal_partition()
    return arb


def drive(ops, n_tenants: int, mode: str, *,
          total_pages: int = TOTAL_PAGES, page_size: int = PAGE_SIZE,
          sample_every: int = 250) -> Dict:
    """Replay one multi-tenant op stream under ``mode``."""
    arb = build_arbiter(mode, n_tenants,
                        total_pages=total_pages, page_size=page_size)
    pool_bytes = total_pages * page_size
    cum_holes = 0
    samples: List[Dict] = []
    since_sample = 0
    for op in ops:
        if op.op == "set":
            arb.set(f"tenant{op.tenant}", op.key, op.size)
        else:
            arb.delete(f"tenant{op.tenant}", op.key)
        since_sample += 1
        if since_sample >= sample_every:
            since_sample = 0
            live = sum(t.allocator.stats().item_bytes
                       for t in arb.tenants.values())
            holes = pool_bytes - live
            cum_holes += holes * sample_every
            samples.append({"op": arb.n_ops,
                            "hole_frac": holes / pool_bytes})
    assert arb.pool.conserved
    per_tenant = arb.stats()
    return {
        "cum_hole_byte_ops": int(cum_holes),
        "mean_hole_frac": (sum(s["hole_frac"] for s in samples)
                           / max(len(samples), 1)),
        "final_live_bytes": sum(v["item_bytes"] for v in per_tenant.values()),
        "evicted_bytes": sum(v["evicted_bytes"] for v in per_tenant.values()),
        "n_page_denials": sum(v["n_page_denials"]
                              for v in per_tenant.values()),
        "n_transfers": arb.n_transfers,
        "n_refits": sum(v["n_refits"] for v in per_tenant.values()),
        "per_tenant": per_tenant,
        "trajectory": samples,
    }


def compare(n_sets: int = N_SETS, *, n_tenants: int = 3,
            seed: int = 7) -> Dict[str, Dict]:
    """static vs pooled vs arbitrated on one out-of-phase op stream.

    The live working set scales with the stream (item TTL is a fraction
    of the period, which is a fraction of the stream), so the pool is
    scaled with ``n_sets`` to keep the same contention at every size.
    """
    workloads = PAPER_WORKLOADS[:n_tenants]
    total_pages = max(12, TOTAL_PAGES * n_sets // N_SETS)
    ops = multitenant_phased_ops(workloads, n_sets=n_sets,
                                 trough_mix=0.5, seed=seed)
    return {mode: drive(ops, n_tenants, mode, total_pages=total_pages)
            for mode in MODES}


def run(n_sets: int = 20_000) -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    res = compare(n_sets)
    dt = (time.perf_counter() - t0) * 1e6 / (len(MODES) * n_sets)
    return [(
        "out_of_phase_3tenant", dt,
        f"static={res['static']['mean_hole_frac']:.4f};"
        f"pooled={res['pooled']['mean_hole_frac']:.4f};"
        f"arbitrated={res['arbitrated']['mean_hole_frac']:.4f};"
        f"transfers={res['arbitrated']['n_transfers']};"
        f"evicted_mb_arbitrated="
        f"{res['arbitrated']['evicted_bytes'] / 2**20:.1f}")]


def main(n_sets: int = N_SETS) -> Dict:
    out: Dict = {"n_sets": n_sets, "page_size": PAGE_SIZE,
                 "total_pages": TOTAL_PAGES, "k": K,
                 "modes": compare(n_sets)}
    for mode in MODES:
        del out["modes"][mode]["trajectory"][:-1]
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
