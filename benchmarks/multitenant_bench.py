"""Benchmark: cross-tenant page arbitration (the Memshare-style layer).

N tenants with divergent size distributions (paper operating points)
share one physical page pool, their demand peaking out of phase
(``multitenant_phased_ops``: raised-cosine arrival intensity offset by
1/N period per tenant, plus TTL churn so an off-peak tenant's pages
fill with free chunks). Three memory policies:

* ``static``     — each tenant owns a fixed equal share of the pool
                   (quota = total/N, never moved). The classic sizing
                   answer; a peaking tenant evicts while its idle
                   neighbour holds half-empty pages.
* ``pooled``     — no quotas, first-come-first-served page grabs. Better
                   while the pool has slack, but pages stick with
                   whoever grabbed them first: once the pool is
                   exhausted, an off-peak tenant's cold, hole-riddled
                   pages are unreachable to the tenant at peak.
* ``arbitrated`` — equal quotas plus the :class:`TenantArbiter`: the
                   pressure signal (eviction payload + page denials)
                   picks the recipient, the cheapest reclaimable page
                   picks the donor, the controller's cost model gates
                   the transfer, and quota + page move donor→recipient.

Every mode runs the same per-tenant *intra*-tenant adaptive controllers
(the PR-1 loop), so the deltas below isolate the *inter*-tenant layer.

The measurement is the paper's, lifted to the pool level: **memory
holes** = pool bytes not holding live payload (internal fragmentation
+ page tails + free chunks + idle pages), sampled along the stream.
``cum_hole_byte_ops`` integrates hole bytes over op time; arbitration
wins by keeping more live payload resident in the same physical pool
(fewer pressure evictions at each tenant's peak).

A second axis (``--policy``): the same arbitrated stack under each
eviction policy (``coldest`` / ``segmented`` / ``ranked``, see
``repro.memcached.eviction``) on ``zipfian_rereference`` traffic —
Zipf-skewed re-references over a fixed key universe with read-through
refills, where the *choice* of eviction victim and the honesty of the
predicted migration cost are both measurable. The cost-aware policies
win twice: refits/transfers the wholesale model vetoed get approved
(lower hole fraction), and the victims they pick are re-referenced
less (fewer refill misses, fewer migration evictions downstream).

``python benchmarks/multitenant_bench.py`` emits the mode comparison as
JSON; ``--policy ranked`` (or ``all``) runs the eviction-policy axis
against the ``coldest`` baseline; ``--quick`` is the CI smoke size.
``run()`` returns the CSV rows for ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Sequence, Tuple

from repro.core import ControllerConfig, PagePool, TenantArbiter
from repro.core.distribution import PAPER_WORKLOADS
from repro.core.slab_policy import default_memcached_schedule
from repro.memcached import (SlabAllocator, make_policy,
                             multitenant_phased_ops,
                             zipfian_rereference_ops)

PAGE_SIZE = 1 << 16       # 64 KiB pages: item sizes are 0.5-8 KiB, so a
#                           page is a meaningful arbitration quantum
TOTAL_PAGES = 88          # 5.5 MiB: between the aggregate demand trough
#                           (~4.6 MiB) and peak (~7.4 MiB) of the default
#                           stream, so tenants genuinely contend
N_SETS = 30_000
K = 6
MODES = ("static", "pooled", "arbitrated")
POLICIES = ("coldest", "segmented", "ranked")


def build_arbiter(mode: str, n_tenants: int, *,
                  total_pages: int = TOTAL_PAGES,
                  page_size: int = PAGE_SIZE,
                  arbitrate_every: int = 1000,
                  policy: str = "coldest",
                  check_every: int = 2000,
                  cost_weight: float = 0.1,
                  forecast=None,
                  forecast_horizon: int = 1) -> TenantArbiter:
    """One shared pool + N tenants under the given memory policy.

    All modes run through the same ``TenantArbiter`` object so the
    per-tenant refit pipeline is identical; the baselines simply never
    reach the arbitration cadence. ``policy`` picks the per-tenant
    eviction policy (``repro.memcached.eviction``) — it changes victim
    selection AND the predicted costs the refit/transfer gates charge.
    ``forecast`` (a ``repro.core.DemandForecaster``) turns on
    forecast-aware donor selection; ``None`` is the reactive baseline.
    """
    pool = PagePool(total_pages, page_size=page_size)
    cfg = ControllerConfig(
        k=K, page_size=page_size, check_every=check_every,
        half_life=2.0 * check_every,
        drift_threshold=0.12, min_items_between_refits=2 * check_every,
        # TTL-churned cache traffic: victims are mostly expired-soon
        # items, so a migration byte is cheap next to a recurring
        # waste byte (same reasoning as adaptive_bench)
        amortization_windows=8.0, cost_weight=cost_weight)
    arb = TenantArbiter(
        pool, controller_config=cfg,
        arbitrate_every=(arbitrate_every if mode == "arbitrated"
                         else 1 << 62),
        amortization_windows=8.0, cost_weight=0.1, forecast=forecast,
        forecast_horizon=forecast_horizon)
    classes = default_memcached_schedule(page_size=page_size)
    for t in range(n_tenants):
        name = f"tenant{t}"
        alloc = SlabAllocator(classes, page_size=page_size,
                              page_pool=pool, tenant=name,
                              eviction_policy=make_policy(policy))
        arb.register(name, alloc, floor_pages=total_pages // (4 * n_tenants))
    if mode in ("static", "arbitrated"):
        pool.equal_partition()
    return arb


def drive(ops, n_tenants: int, mode: str, *,
          total_pages: int = TOTAL_PAGES, page_size: int = PAGE_SIZE,
          sample_every: int = 250, policy: str = "coldest",
          check_every: int = 2000, cost_weight: float = 0.1,
          liveness_window: int = 0, arbitrate_every: int = 1000,
          forecast=None, forecast_horizon: int = 1) -> Dict:
    """Replay one multi-tenant op stream under ``mode``. Gets are
    read-through: a miss is refilled with a set of the key's payload —
    the loop that makes a wrongly-chosen eviction victim cost bytes.

    ``liveness_window > 0`` measures holes against *referenced*
    payload (``SlabAllocator.referenced_bytes``): a resident byte
    nobody touched for that many ops is counted as a hole. Re-reference
    traffic needs this — under raw residency a policy can look good by
    hoarding dead bytes a refill stream would anyway restore. The raw
    measure is still reported as ``mean_raw_hole_frac``."""
    arb = build_arbiter(mode, n_tenants, total_pages=total_pages,
                        page_size=page_size, policy=policy,
                        check_every=check_every, cost_weight=cost_weight,
                        arbitrate_every=arbitrate_every, forecast=forecast,
                        forecast_horizon=forecast_horizon)
    pool_bytes = total_pages * page_size
    cum_holes = 0
    raw_hole_fracs: List[float] = []
    samples: List[Dict] = []
    since_sample = 0
    for op in ops:
        name = f"tenant{op.tenant}"
        if op.op == "set":
            arb.set(name, op.key, op.size)
        elif op.op == "get":
            if not arb.get(name, op.key):
                arb.set(name, op.key, op.size)     # read-through refill
        else:
            arb.delete(name, op.key)
        since_sample += 1
        if since_sample >= sample_every:
            since_sample = 0
            raw = sum(t.allocator.stats().item_bytes
                      for t in arb.tenants.values())
            raw_hole_fracs.append((pool_bytes - raw) / pool_bytes)
            live = (sum(t.allocator.referenced_bytes(liveness_window)
                        for t in arb.tenants.values())
                    if liveness_window else raw)
            holes = pool_bytes - live
            cum_holes += holes * sample_every
            samples.append({"op": arb.n_ops,
                            "hole_frac": holes / pool_bytes})
    assert arb.pool.conserved
    per_tenant = arb.stats()
    return {
        "cum_hole_byte_ops": int(cum_holes),
        "mean_hole_frac": (sum(s["hole_frac"] for s in samples)
                           / max(len(samples), 1)),
        "final_live_bytes": sum(v["item_bytes"] for v in per_tenant.values()),
        "evicted_bytes": sum(v["evicted_bytes"] for v in per_tenant.values()),
        "n_page_denials": sum(v["n_page_denials"]
                              for v in per_tenant.values()),
        "n_transfers": arb.n_transfers,
        "n_bounced": arb.n_bounced,
        "n_refits": sum(v["n_refits"] for v in per_tenant.values()),
        "mean_raw_hole_frac": (sum(raw_hole_fracs)
                               / max(len(raw_hole_fracs), 1)),
        "migration_evictions": sum(v["migration_evictions"]
                                   for v in per_tenant.values()),
        "reused_after_evict": sum(v["reused_after_evict"]
                                  for v in per_tenant.values()),
        "evicted_hot_bytes": sum(v["evicted_hot_bytes"]
                                 for v in per_tenant.values()),
        "per_tenant": per_tenant,
        "trajectory": samples,
    }


def compare(n_sets: int = N_SETS, *, n_tenants: int = 3,
            seed: int = 7) -> Dict[str, Dict]:
    """static vs pooled vs arbitrated on one out-of-phase op stream.

    The live working set scales with the stream (item TTL is a fraction
    of the period, which is a fraction of the stream), so the pool is
    scaled with ``n_sets`` to keep the same contention at every size.
    """
    workloads = PAPER_WORKLOADS[:n_tenants]
    total_pages = max(12, TOTAL_PAGES * n_sets // N_SETS)
    ops = multitenant_phased_ops(workloads, n_sets=n_sets,
                                 trough_mix=0.5, seed=seed)
    return {mode: drive(ops, n_tenants, mode, total_pages=total_pages)
            for mode in MODES}


def compare_policies(n_ops: int = N_SETS, *, n_tenants: int = 3,
                     policies: Sequence[str] = POLICIES,
                     traffic: str = "zipfian_rereference",
                     seed: int = 7) -> Dict[str, Dict]:
    """The eviction-policy axis: the full arbitrated stack under each
    policy, same op stream — the deltas isolate victim selection and
    cost-model honesty. The pool is tighter than the mode comparison's
    (contention from the first quarter, not the last) and holes are
    measured against referenced payload (see :func:`drive`)."""
    workloads = PAPER_WORKLOADS[:n_tenants]
    total_pages = max(12, (TOTAL_PAGES * n_ops // N_SETS) * 4 // 11)
    if traffic == "zipfian_rereference":
        ops = zipfian_rereference_ops(workloads, n_ops=n_ops,
                                      shift_at=0.4, seed=seed)
    elif traffic == "phased":
        ops = multitenant_phased_ops(workloads, n_sets=n_ops,
                                     trough_mix=0.5, seed=seed)
    else:
        raise ValueError(f"unknown traffic {traffic!r}")
    # cost_weight=1.0: a migration byte priced like a waste byte. The
    # wholesale (coldest) model needs that weight hand-discounted to
    # ever refit; the cost-aware policies discover the discount
    # themselves by charging only likely-re-referenced bytes — the
    # honesty this axis measures.
    return {p: drive(ops, n_tenants, "arbitrated",
                     total_pages=total_pages, policy=p,
                     check_every=max(300, n_ops // 40), cost_weight=1.0,
                     liveness_window=2000)
            for p in policies}


def run(n_sets: int = 20_000) -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    res = compare(n_sets)
    dt = (time.perf_counter() - t0) * 1e6 / (len(MODES) * n_sets)
    rows = [(
        "out_of_phase_3tenant", dt,
        f"static={res['static']['mean_hole_frac']:.4f};"
        f"pooled={res['pooled']['mean_hole_frac']:.4f};"
        f"arbitrated={res['arbitrated']['mean_hole_frac']:.4f};"
        f"transfers={res['arbitrated']['n_transfers']};"
        f"evicted_mb_arbitrated="
        f"{res['arbitrated']['evicted_bytes'] / 2**20:.1f}")]
    t0 = time.perf_counter()
    pol = compare_policies(n_sets, policies=("coldest", "ranked"))
    dt = (time.perf_counter() - t0) * 1e6 / (2 * n_sets)
    rows.append((
        "zipfian_rereference_policy_axis", dt,
        f"coldest={pol['coldest']['mean_hole_frac']:.4f};"
        f"ranked={pol['ranked']['mean_hole_frac']:.4f};"
        f"migr_evict_coldest={pol['coldest']['migration_evictions']};"
        f"migr_evict_ranked={pol['ranked']['migration_evictions']}"))
    return rows


def main(n_sets: int = N_SETS) -> Dict:
    out: Dict = {"n_sets": n_sets, "page_size": PAGE_SIZE,
                 "total_pages": TOTAL_PAGES, "k": K,
                 "modes": compare(n_sets)}
    for mode in MODES:
        del out["modes"][mode]["trajectory"][:-1]
    return out


def policy_main(n_ops: int, policy: str, traffic: str) -> Dict:
    """The ``--policy`` entry point: the requested policy (or all)
    against the ``coldest`` baseline, arbitrated mode, same stream."""
    policies = POLICIES if policy == "all" else tuple(
        dict.fromkeys(("coldest", policy)))
    res = compare_policies(n_ops, policies=policies, traffic=traffic)
    for cfg in res.values():
        del cfg["trajectory"][:-1]
    base = res["coldest"]
    summary = {
        p: {"mean_hole_frac": round(r["mean_hole_frac"], 4),
            "migration_evictions": r["migration_evictions"],
            "reused_after_evict": r["reused_after_evict"],
            "beats_coldest": bool(
                r["mean_hole_frac"] < base["mean_hole_frac"]
                and r["migration_evictions"] <= base["migration_evictions"])}
        for p, r in res.items() if p != "coldest"}
    return {"n_ops": n_ops, "traffic": traffic, "k": K,
            "summary": summary, "policies": res}


if __name__ == "__main__":
    from bench_io import write_bench_json
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--policy", choices=POLICIES + ("all",), default=None,
                    help="run the eviction-policy axis (vs the coldest "
                         "baseline) instead of the mode comparison")
    ap.add_argument("--traffic", default="zipfian_rereference",
                    choices=("zipfian_rereference", "phased"),
                    help="op stream for the policy axis")
    ap.add_argument("--forecast", action="store_true",
                    help="reactive vs forecast-aware donor selection "
                         "(forecast_bench's arbiter axis)")
    ap.add_argument("--n-sets", type=int, default=N_SETS)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke size (covers both axes)")
    args = ap.parse_args()
    if args.forecast:
        from forecast_bench import arbiter_axis
        n = min(args.n_sets, 5000) if args.quick else args.n_sets
        out = arbiter_axis(n)
        # axis-specific artifact: never clobber the headline
        # mode-comparison trajectory with a different schema
        write_bench_json("multitenant_forecast", out)
        print(json.dumps(out, indent=2, default=str))
        raise SystemExit(0)
    if args.quick:
        n = min(args.n_sets, 4000)
        out = {"modes": main(n)["modes"],
               "policy_axis": policy_main(n, "ranked",
                                          args.traffic)["summary"]}
    elif args.policy is not None:
        out = policy_main(args.n_sets, args.policy, args.traffic)
    else:
        out = main(args.n_sets)
    write_bench_json("multitenant", out)
    print(json.dumps(out, indent=2, default=str))
