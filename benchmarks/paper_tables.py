"""Benchmark: the paper's Tables 1-5 at full scale (1M items each).

For every operating point: regenerate the workload, measure old-config
waste, run (a) the exact DP optimizer, (b) the paper-faithful hill
climb, (c) batched parallel hill climb, and report bytes + % recovered
against the paper's reported numbers.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from repro.core import (PAPER_WORKLOADS, SlabPolicy, default_waste_fraction,
                        size_histogram, waste_exact)
from repro.memcached import paper_traffic

N_ITEMS = 1_000_000


def run(n_items: int = N_ITEMS, methods=("dp", "hillclimb", "parallel")
        ) -> List[Tuple[str, float, str]]:
    rows = []
    for wl in PAPER_WORKLOADS:
        sizes = paper_traffic(wl, n_items=n_items, seed=0)
        support, freqs = size_histogram(sizes)
        old = np.asarray(wl.old_chunks)
        w_old = waste_exact(old, support, freqs)
        frac = default_waste_fraction(old, support, freqs)
        rows.append((f"table{wl.table}_old_waste_bytes", 0.0,
                     f"{w_old};paper={wl.old_waste};"
                     f"waste_frac={frac:.3f}"))
        for method in methods:
            policy = SlabPolicy(seed=wl.table)
            kwargs = {}
            if method == "hillclimb":
                kwargs = dict(patience=1000, max_steps=150_000)
            t0 = time.perf_counter()
            sched = policy.fit(support, freqs, k=len(old), baseline=old,
                               method=method, **kwargs)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append((
                f"table{wl.table}_{method}", dt,
                f"waste={sched.waste};recovered={sched.recovered_frac:.4f};"
                f"paper_recovered={wl.recovered_frac:.4f};"
                f"chunks={list(sched.chunk_sizes)}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
