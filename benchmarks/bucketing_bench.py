"""Benchmark: learned length buckets vs pow2 padding in the data path."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.data import fit_buckets, padding_waste, pow2_buckets
from repro.core import sample_lognormal_sizes


def run(n: int = 200_000) -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    lengths = sample_lognormal_sizes(rng, n, 900.0, 450.0, max_size=4096)
    rows = []
    for k in (4, 8, 16):
        t0 = time.perf_counter()
        scheme = fit_buckets(lengths, k)
        dt = (time.perf_counter() - t0) * 1e6
        w_learned, f_learned = padding_waste(scheme.boundaries, lengths)
        w_base, f_base = padding_waste(scheme.baseline_boundaries, lengths)
        rows.append((f"buckets_k{k}", dt,
                     f"pad_frac_learned={f_learned:.4f};"
                     f"pad_frac_pow2={f_base:.4f};"
                     f"recovered={scheme.recovered_frac:.4f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
