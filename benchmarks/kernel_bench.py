"""Benchmark: Pallas waste_eval kernel vs pure-jnp oracle (CPU interpret).

On CPU this measures the interpret-mode overhead, not TPU speed; the
useful derived number is evaluations/s for the search loop and the
verified agreement between the two paths at benchmark scale.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import waste_batch_jax
from repro.kernels.ops import waste_eval


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6, out


def run() -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    support = jnp.asarray(
        np.sort(rng.choice(20_000, 2048, replace=False)) + 1, jnp.int32)
    freqs = jnp.asarray(rng.integers(1, 100, 2048), jnp.float32)
    batch = jnp.asarray(rng.integers(1, 25_000, (64, 8)), jnp.int32)
    us_ref, ref = _time(
        lambda b: waste_batch_jax(b, support, freqs), batch)
    us_pal, pal = _time(
        lambda b: waste_eval(b, support, freqs), batch)
    agree = float(jnp.max(jnp.abs(ref - pal) / jnp.maximum(ref, 1.0)))
    return [
        ("waste_eval_jnp_64x8x2048", us_ref,
         f"evals_per_s={64 / (us_ref * 1e-6):.0f}"),
        ("waste_eval_pallas_interpret", us_pal,
         f"evals_per_s={64 / (us_pal * 1e-6):.0f};max_rel_err={agree:.2e}"),
    ]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
