"""Benchmark driver: one suite per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (and trailing roofline rows
when dry-run artifacts exist). Scale knobs keep the full run a few
minutes on one CPU core; paper_tables uses the paper's full 1e6 items.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (adaptive_bench, bucketing_bench,
                            convergence_bench, forecast_bench, k_sweep,
                            kernel_bench, kv_pool_bench, multitenant_bench,
                            observe_bench, paper_tables, sigma_sweep)
    suites = [
        ("paper_tables", lambda: paper_tables.run()),
        ("sigma_sweep", lambda: sigma_sweep.run()),
        ("k_sweep", lambda: k_sweep.run()),
        ("convergence", lambda: convergence_bench.run()),
        ("kv_pool", lambda: kv_pool_bench.run()),
        ("adaptive", lambda: adaptive_bench.run()),
        ("multitenant", lambda: multitenant_bench.run()),
        ("bucketing", lambda: bucketing_bench.run()),
        ("kernels", lambda: kernel_bench.run()),
        ("observe", lambda: observe_bench.run()),
        ("forecast", lambda: forecast_bench.run()),
    ]
    failures = 0
    for suite, fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{suite}.{name},{us:.0f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{suite}.ERROR,0,{traceback.format_exc(limit=2)!r}",
                  flush=True)
    try:
        from benchmarks import roofline
        rows = roofline.build_table()
    except Exception:  # noqa: BLE001
        rows = []
    if rows:
        for r in rows:
            print(f"roofline.{r['arch']}__{r['shape']},0,"
                  f"dominant={r['dominant']};"
                  f"compute_s={r['compute_s']:.4f};"
                  f"memory_s={r['memory_s']:.4f};"
                  f"collective_s={r['collective_s']:.4f};"
                  f"useful={r['useful_ratio']:.2f}", flush=True)
    else:
        print("roofline.SKIP,0,no dry-run artifacts (run "
              "repro.launch.dryrun first)", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
