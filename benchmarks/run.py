"""Benchmark driver: one suite per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (and trailing roofline rows
when dry-run artifacts exist). Scale knobs keep the full run a few
minutes on one CPU core; paper_tables uses the paper's full 1e6 items.

Every run opens with a slablint self-check (``repro.analysis`` over
``src/`` under the checked-in baseline): benchmark numbers from a tree
with dispatch-discipline violations are not comparable, so an
unsuppressed finding fails the run before anything is timed.
``--quick`` runs ONLY that self-check — the per-suite ``--quick``
smoke flags live on the individual bench scripts (see CI).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def slablint_self_check() -> tuple:
    """One CSV row; raises on any unsuppressed finding/stale entry."""
    from repro.analysis import baseline as baseline_mod
    from repro.analysis import run_check

    t0 = time.perf_counter()
    findings = run_check(REPO / "src", tests_root=REPO / "tests")
    applied, stale = baseline_mod.apply(
        findings, baseline_mod.load(REPO / ".slablint-baseline"))
    us = 1e6 * (time.perf_counter() - t0)
    unsup = [f for f in applied if not f.suppressed]
    if unsup or stale:
        raise SystemExit(
            "slablint self-check failed: "
            + "; ".join([f.render().splitlines()[0] for f in unsup]
                        + [f"stale: {s}" for s in stale]))
    n_sup = len(applied) - len(unsup)
    return ("slablint", us,
            f"findings={len(applied)};suppressed={n_sup};unsuppressed=0")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="run only the slablint self-check")
    args = ap.parse_args(argv)

    failures = 0
    try:
        name, us, derived = slablint_self_check()
        print(f"analysis.{name},{us:.0f},{derived}", flush=True)
    except SystemExit as e:
        failures += 1
        print(f"analysis.ERROR,0,{str(e)!r}", flush=True)
    if args.quick:
        if failures:
            sys.exit(1)
        return

    from benchmarks import (adaptive_bench, bucketing_bench,
                            convergence_bench, forecast_bench, k_sweep,
                            kernel_bench, kv_pool_bench, multitenant_bench,
                            observe_bench, paper_tables, sigma_sweep)
    suites = [
        ("paper_tables", lambda: paper_tables.run()),
        ("sigma_sweep", lambda: sigma_sweep.run()),
        ("k_sweep", lambda: k_sweep.run()),
        ("convergence", lambda: convergence_bench.run()),
        ("kv_pool", lambda: kv_pool_bench.run()),
        ("adaptive", lambda: adaptive_bench.run()),
        ("multitenant", lambda: multitenant_bench.run()),
        ("bucketing", lambda: bucketing_bench.run()),
        ("kernels", lambda: kernel_bench.run()),
        ("observe", lambda: observe_bench.run()),
        ("forecast", lambda: forecast_bench.run()),
    ]
    for suite, fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{suite}.{name},{us:.0f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{suite}.ERROR,0,{traceback.format_exc(limit=2)!r}",
                  flush=True)
    try:
        from benchmarks import roofline
        rows = roofline.build_table()
    except Exception:  # noqa: BLE001
        rows = []
    if rows:
        for r in rows:
            print(f"roofline.{r['arch']}__{r['shape']},0,"
                  f"dominant={r['dominant']};"
                  f"compute_s={r['compute_s']:.4f};"
                  f"memory_s={r['memory_s']:.4f};"
                  f"collective_s={r['collective_s']:.4f};"
                  f"useful={r['useful_ratio']:.2f}", flush=True)
    else:
        print("roofline.SKIP,0,no dry-run artifacts (run "
              "repro.launch.dryrun first)", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
