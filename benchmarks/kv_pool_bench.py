"""Benchmark: KV slab pool fragmentation — pow2 vs learned vs online refit.

The paper's technique applied to the serving runtime (DESIGN.md §2),
measured with the continuous-batching simulator.
"""
from __future__ import annotations

import copy
import time
from typing import List, Tuple

import numpy as np

from repro.core import SlabPolicy, size_histogram
from repro.serving import (ContinuousBatcher, KVSlabPool,
                           default_pow2_classes,
                           lognormal_request_workload, quantize_lengths)


def run(n_requests: int = 300) -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    workload = lognormal_request_workload(rng, n_requests)
    final = quantize_lengths([r.prompt_len + r.output_len
                              for r in workload])
    sup, fr = size_histogram(final)
    sched = SlabPolicy(page_size=1 << 22, min_chunk=128).fit(
        sup, fr, 8, baseline=default_pow2_classes())
    learned = np.unique(quantize_lengths(sched.chunk_sizes))

    rows = []
    for name, classes, refit, adaptive in (
            ("pow2_baseline", default_pow2_classes(), None, False),
            ("learned_offline", learned, None, False),
            ("learned_online_refit", default_pow2_classes(), 200, False),
            ("adaptive_controller", default_pow2_classes(), None, True)):
        if adaptive:
            from repro.core import ControllerConfig
            pool = KVSlabPool(2_000_000, default_pow2_classes(),
                              controller_config=ControllerConfig(
                                  page_size=1 << 22, min_chunk=128,
                                  align=128, k=8, check_every=100,
                                  half_life=400.0, drift_threshold=0.1,
                                  min_items_between_refits=100))
        else:
            pool = KVSlabPool(2_000_000, classes)
        batcher = ContinuousBatcher(pool, max_batch=48, refit_every=refit,
                                    adaptive=adaptive)
        t0 = time.perf_counter()
        res = batcher.run(copy.deepcopy(workload), steps=4000)
        dt = (time.perf_counter() - t0) * 1e6 / max(res.steps, 1)
        rows.append((f"kvpool_{name}", dt,
                     f"waste_frac={res.mean_waste_fraction:.4f};"
                     f"completed={res.completed};"
                     f"copies={res.realloc_copies};"
                     f"refits={res.n_refits}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
