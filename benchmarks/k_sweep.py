"""Benchmark: the paper's §7 open question — class-count trade-off.

The paper's §3 notes that Memcached's own mitigation (lowering the 1.25
growth factor => more classes) "may come at the cost of significantly
increasing the eviction rates", and §7 proposes studying class count vs
efficiency as future work. This bench runs it:

Under a fixed memory limit, sweep (a) the default geometric schedule at
growth factors 1.25 / 1.10 / 1.05 and (b) DP-learned schedules at
K = 1..12 classes, and measure BOTH internal fragmentation and eviction
rate in the allocator simulator. The learned schedules reach the
low-waste regime with far fewer classes than a tightened growth factor,
which is exactly why they avoid the eviction penalty: fewer classes =>
fewer partially-filled per-class page pools under pressure.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import (SlabPolicy, default_memcached_schedule,
                        size_histogram)
from repro.memcached import paper_traffic, run_workload
from repro.core.distribution import PAPER_WORKLOADS


def run(n_items: int = 150_000) -> List[Tuple[str, float, str]]:
    wl = PAPER_WORKLOADS[1]  # mu=1210
    sizes = paper_traffic(wl, n_items=n_items, seed=1)
    support, freqs = size_histogram(sizes)
    # memory limit: ~85% of what the default schedule needs resident
    baseline_alloc = run_workload(wl.old_chunks, sizes)
    mem_limit = int(baseline_alloc.pages_allocated * (1 << 20) * 0.85)

    rows = []
    for gf in (1.25, 1.10, 1.05):
        classes = default_memcached_schedule(growth_factor=gf)
        lo = np.searchsorted(classes, support.min()) - 1
        hi = np.searchsorted(classes, support.max()) + 1
        classes = classes[max(lo, 0):hi + 1]
        t0 = time.perf_counter()
        st = run_workload(classes, sizes, mem_limit=mem_limit)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"growth_{gf:g}_k{len(classes)}", dt,
            f"waste_frac={st.waste_fraction:.4f};"
            f"evict_rate={st.n_evicted / n_items:.4f};"
            f"resident={st.n_resident}"))

    policy = SlabPolicy(seed=0)
    for k in (1, 2, 4, 6, 8, 12):
        sched = policy.fit(support, freqs, k, method="dp")
        t0 = time.perf_counter()
        st = run_workload(sched.chunk_sizes, sizes, mem_limit=mem_limit)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"learned_k{k}", dt,
            f"waste_frac={st.waste_fraction:.4f};"
            f"evict_rate={st.n_evicted / n_items:.4f};"
            f"resident={st.n_resident}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
