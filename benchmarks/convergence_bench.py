"""Benchmark: search-method convergence (paper Alg.1 vs beyond-paper).

Steps-to-quality for the paper's +-1 walk, the batched parallel climb,
multi-restart, annealing, and the DP optimum (quality floor).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from repro.core import (SlabPolicy, anneal, dp_optimal, multi_restart,
                        paper_hillclimb, parallel_hillclimb,
                        sample_lognormal_sizes, size_histogram, waste_exact)


def run(n_items: int = 300_000) -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    sizes = sample_lognormal_sizes(rng, n_items, 1210.0, 15.8)
    support, freqs = size_histogram(sizes)
    init = np.asarray([944, 1184, 1480, 1856], dtype=np.int64)
    init[-1] = max(init[-1], int(support.max()))
    w0 = waste_exact(init, support, freqs)

    rows = []
    t0 = time.perf_counter()
    opt = dp_optimal(support, freqs, 4)
    rows.append(("dp_exact", (time.perf_counter() - t0) * 1e6,
                 f"waste={opt.waste};recovered={1 - opt.waste / w0:.4f}"))
    for name, fn in (
        ("paper_hillclimb", lambda: paper_hillclimb(
            jax.random.PRNGKey(0), init, support, freqs,
            patience=1000, max_steps=100_000)),
        ("parallel_hillclimb", lambda: parallel_hillclimb(
            init, support, freqs)),
        ("multi_restart_x8", lambda: multi_restart(
            jax.random.PRNGKey(0), init, support, freqs, n_restarts=8)),
        ("anneal_20k", lambda: anneal(
            jax.random.PRNGKey(0), init, support, freqs, n_steps=20_000)),
    ):
        t0 = time.perf_counter()
        r = fn()
        dt = (time.perf_counter() - t0) * 1e6
        gap = (r.waste - opt.waste) / max(opt.waste, 1)
        rows.append((name, dt,
                     f"waste={r.waste};steps={r.steps};"
                     f"recovered={r.recovered_frac:.4f};"
                     f"gap_to_optimal={gap:.4f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
