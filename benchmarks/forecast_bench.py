"""Benchmark: reactive vs forecast-driven adaptation.

Three axes, one question each — what does the shared
``DemandForecaster`` (``repro.core.forecast``) buy over the purely
reactive loop the paper describes?

* **controller** — diurnal traffic (periodic mixture of two paper
  operating points) through the adaptive controller, reactive vs
  ``ControllerConfig(forecast=...)``. Measured where reactivity hurts:
  ``peak_onset_waste_frac`` (insert-charged waste inside the ramp
  quarter-period before each peak — the window a reactive refit has
  not happened yet) and ``refit_lead_items`` (how far before the peak
  the schedule move landed; bigger = pre-positioned).
* **arbiter** — out-of-phase multi-tenant op streams through the
  ``TenantArbiter``, reactive vs forecast-aware donor selection.
  Measured as hole fraction plus ``n_bounced``: approved transfers
  whose recipient had itself donated within ``bounce_window`` ops —
  the take-a-page-from-a-tenant-about-to-surge loop the forecast
  surcharge exists to break.
* **kv_quota** — two serving streams with out-of-phase bursts over one
  ``KVSlabPool``, static token quotas vs arbiter-managed quotas
  (``repro.serving.token_quota_arbiter``). Measured as rejected
  requests per stream and the quota trajectory.

``python benchmarks/forecast_bench.py`` emits JSON (and writes
``BENCH_forecast.json`` at the repo root); ``--quick`` is the CI smoke
size.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

import numpy as np

try:
    from adaptive_bench import K, WARMUP_FRAC, charge_waste
    from bench_io import write_bench_json
except ImportError:                      # imported as benchmarks.<name>
    from benchmarks.adaptive_bench import K, WARMUP_FRAC, charge_waste
    from benchmarks.bench_io import write_bench_json

from repro.core import (PAGE_SIZE, ControllerConfig, DemandForecaster,
                        SlabController, SlabPolicy,
                        schedule_with_default_tail, size_histogram)
from repro.core.distribution import PAPER_WORKLOADS
from repro.memcached import (SlabAllocator, diurnal_multimodal_traffic,
                             multitenant_phased_ops)


# ---------------------------------------------------------------------------
# controller axis: diurnal multi-modal traffic, reactive vs predictive
# ---------------------------------------------------------------------------

CTRL_PAGE = 1 << 16     # 64 KiB pages: the policy axes' cache quantum
K_SCARCE = 4            # fewer classes than the union of day+night modes
# Two multi-modal phases built from the paper's operating points: the
# night set and the day set each need ~3 tight classes of their own, so
# under K_SCARCE the optimal schedule genuinely TRACKS the phase — the
# regime where pre-positioning pays (a unimodal diurnal mix is covered
# once by any 6-class fit and never needs a second refit).
NIGHT_MODES = ((1.0, 518.0, 12.0), (0.8, 1210.0, 20.0), (0.5, 4133.0, 40.0))
DAY_MODES = ((1.0, 810.0, 16.0), (0.8, 2109.0, 25.0), (0.5, 8131.0, 60.0))


def _controller(chunks, n_items: int, cadence: int, forecast,
                horizon: int) -> SlabController:
    return SlabController(chunks, config=ControllerConfig(
        k=K_SCARCE, page_size=CTRL_PAGE, check_every=cadence,
        half_life=2.0 * cadence,
        # a production threshold: small mixture wobbles never trigger —
        # which is exactly the window where only the forecast can see
        # the daily peak coming
        drift_threshold=0.4, min_items_between_refits=2 * cadence,
        min_rel_improvement=0.02, amortization_windows=8.0,
        cost_weight=0.1, forecast=forecast, forecast_horizon=horizon,
        forecast_min_confidence=0.3))


def drive_diurnal(sizes: np.ndarray, period: int, chunks, *,
                  controller: Optional[SlabController] = None,
                  page_size: int = CTRL_PAGE,
                  mem_pages: Optional[int] = None) -> Dict:
    """Replay ``sizes`` through a memory-LIMITED allocator (a real cache
    holds a bounded working set — an unbounded one would price every
    migration at the whole stream's payload and veto everything),
    charging waste per insert against the schedule active at that
    moment and bucketing the charges by phase of the diurnal period so
    the onset windows are separable afterwards."""
    mem_pages = mem_pages or max(12, len(sizes) // 1200)
    alloc = SlabAllocator(chunks, page_size=page_size,
                          mem_limit=mem_pages * page_size)
    csizes = alloc.chunk_sizes
    n = len(sizes)
    onset_waste = onset_bytes = 0      # ramp quarter before each peak
    cum_waste = cum_bytes = 0
    refit_items: List[int] = []
    predictive_refits = 0
    for i, s in enumerate(np.asarray(sizes).tolist()):
        s = int(s)
        w = charge_waste(csizes, s, page_size)
        cum_waste += w
        cum_bytes += s
        phase = i % period
        if period // 4 <= phase < period // 2:   # rising into the peak
            onset_waste += w
            onset_bytes += s
        alloc.set(str(i), s)
        if controller is None:
            continue
        controller.observe(s)
        decision = controller.maybe_refit(
            cost_bytes_fn=lambda c: alloc.migration_cost_bytes(
                schedule_with_default_tail(c, page_size=page_size)))
        if decision is not None and decision.approved:
            deployed = schedule_with_default_tail(decision.chunks,
                                                  page_size=page_size)
            alloc.reconfigure(deployed)
            controller.set_chunks(deployed)
            csizes = alloc.chunk_sizes
            refit_items.append(i)
            if decision.predictive:
                predictive_refits += 1
    # where in the phase did refits land? The diurnal cycle has two
    # transitions per period (into the day peak at period/2, into the
    # night trough at period): lead = items left until the next
    # transition, and a refit inside the RAMP quarter before the day
    # peak (phase in [period/4, period/2)) is a pre-positioned one —
    # the reactive failure mode is landing just AFTER the peak instead
    half = period // 2
    leads = [half - (i % half) for i in refit_items]
    pre_peak = sum(1 for i in refit_items
                   if period // 4 <= i % period < period // 2)
    post_peak = sum(1 for i in refit_items
                    if period // 2 <= i % period < 3 * period // 4)
    return {
        "cum_waste_frac": cum_waste / max(cum_bytes, 1),
        "peak_onset_waste_frac": onset_waste / max(onset_bytes, 1),
        "n_refits": len(refit_items),
        "n_predictive_refits": predictive_refits,
        "refit_items": refit_items,
        "n_pre_peak_refits": pre_peak,
        "n_post_peak_refits": post_peak,
        "mean_refit_lead_items": (float(np.mean(leads)) if leads else 0.0),
        "n_items": n,
    }


def controller_axis(n_items: int, *, period: Optional[int] = None,
                    seed: int = 7) -> Dict[str, Dict]:
    period = period or max(2000, n_items // 3)
    sizes = diurnal_multimodal_traffic(DAY_MODES, NIGHT_MODES,
                                       n_items=n_items, period=period,
                                       seed=seed)
    # fit the starting schedule on the TROUGH (the stream starts at
    # p_day = 0): the realistic cold-start — the peak mixture is
    # exactly what the schedule has never seen and only the forecast
    # can anticipate
    warmup = sizes[:max(1, period // 8)]
    support, freqs = size_histogram(warmup)
    fit = SlabPolicy(page_size=CTRL_PAGE).fit(support, freqs, K_SCARCE,
                                              method="dp")
    learned = schedule_with_default_tail(fit.chunk_sizes,
                                         page_size=CTRL_PAGE)
    cadence = max(400, period // 20)      # ~20 forecast windows / cycle
    horizon = max(1, period // (4 * cadence))   # ~quarter-period of lead
    out = {"period": period, "cadence": cadence, "horizon": horizon}
    for mode, forecast in (("reactive", None),
                           ("predictive", DemandForecaster())):
        ctl = _controller(learned, n_items, cadence, forecast, horizon)
        out[mode] = drive_diurnal(sizes, period, learned, controller=ctl)
    out["predictive_wins_onset"] = bool(
        out["predictive"]["peak_onset_waste_frac"]
        < out["reactive"]["peak_onset_waste_frac"])
    return out


# ---------------------------------------------------------------------------
# arbiter axis: phased tenants, reactive vs forecast-aware donors
# ---------------------------------------------------------------------------

def arbiter_axis(n_sets: int, *, n_tenants: int = 3,
                 seed: int = 7) -> Dict[str, Dict]:
    try:
        import multitenant_bench as mb
    except ImportError:
        from benchmarks import multitenant_bench as mb
    workloads = PAPER_WORKLOADS[:n_tenants]
    total_pages = max(12, mb.TOTAL_PAGES * n_sets // mb.N_SETS)
    ops = multitenant_phased_ops(workloads, n_sets=n_sets,
                                 trough_mix=0.5, seed=seed)
    # a tight cadence gives the forecaster enough windows per tenant
    # phase; one window of donor lead (pages a tenant needs THAT soon
    # are not taken from it). NOTE the honest finding this axis
    # records: under TTL-churned phased traffic most bounced pages are
    # EMPTY when reclaimed (quota flapping, not payload loss), so the
    # donor surcharge moves the aggregate numbers only marginally —
    # the forecast's decisive wins are the controller axis above and
    # the KV quota axis below.
    arbitrate_every = max(200, n_sets // 60)
    out = {"arbitrate_every": arbitrate_every, "horizon": 1}
    for mode, forecast in (("reactive", None),
                           ("forecast", DemandForecaster())):
        r = mb.drive(ops, n_tenants, "arbitrated",
                     total_pages=total_pages,
                     arbitrate_every=arbitrate_every, forecast=forecast,
                     forecast_horizon=1)
        out[mode] = {k: r[k] for k in
                     ("mean_hole_frac", "evicted_bytes", "n_transfers",
                      "n_bounced", "n_page_denials")}
    out["fewer_bounces"] = bool(out["forecast"]["n_bounced"]
                                <= out["reactive"]["n_bounced"])
    return out


# ---------------------------------------------------------------------------
# kv_quota axis: phased serving streams, static vs arbitrated quotas
# ---------------------------------------------------------------------------

def kv_quota_axis(steps: int, *, seed: int = 0) -> Dict[str, Dict]:
    from repro.serving import (ContinuousBatcher, KVSlabPool, Request,
                               token_quota_arbiter)

    def phased_requests(rng, stream: int, n: int, period: int):
        """Bursty arrivals: stream 0 peaks in the first half of each
        period, stream 1 in the second half."""
        reqs = []
        for i in range(n):
            phase = (i / n * period) % 1.0
            active = phase < 0.5 if stream == 0 else phase >= 0.5
            if not active:
                continue
            reqs.append((int(i / n * steps),
                         Request(rid=stream * 10_000_000 + i,
                                 prompt_len=int(rng.integers(400, 1200)),
                                 output_len=int(rng.integers(8, 32)))))
        return reqs

    out = {}
    for mode in ("static", "arbitrated"):
        rng = np.random.default_rng(seed)
        kv = KVSlabPool(1 << 15, [512, 1024, 2048])
        b0 = ContinuousBatcher(kv, tenant="chat", max_batch=24,
                               quota_tokens=(1 << 15) // 2)
        b1 = ContinuousBatcher(kv, tenant="batch", max_batch=24,
                               quota_tokens=(1 << 15) // 2)
        arb = None
        if mode == "arbitrated":
            arb = token_quota_arbiter(kv, unit_tokens=2048,
                                      arbitrate_every=8,
                                      cost_weight=0.25,
                                      forecast=DemandForecaster())
            b0.arbiter = arb
            b1.arbiter = None      # one tick per shared-pool step
        arrivals = {0: phased_requests(rng, 0, 600, 3.0),
                    1: phased_requests(rng, 1, 600, 3.0)}
        quota_traj = []
        for t in range(steps):
            for stream, batcher in ((0, b0), (1, b1)):
                while arrivals[stream] and arrivals[stream][0][0] <= t:
                    batcher.submit(arrivals[stream].pop(0)[1])
                batcher.step(t)
            # finished-but-retained chunks are what the arbiter reclaims
            for rid in list(kv._live):
                if rid % 7 == 0 and kv._live[rid].length >= 1200:
                    kv.finish(rid, retain=True)
                    for b in (b0, b1):
                        b.active.pop(rid, None)
            if t % 10 == 0:
                quota_traj.append({
                    "step": t,
                    "chat": kv._tenants["chat"].quota_tokens,
                    "batch": kv._tenants["batch"].quota_tokens})
        out[mode] = {
            "rejected_chat": b0.rejected,
            "rejected_batch": b1.rejected,
            "rejected_total": b0.rejected + b1.rejected,
            "completed_total": b0.completed + b1.completed,
            "n_failed_chat": kv._tenants["chat"].n_failed,
            "n_failed_batch": kv._tenants["batch"].n_failed,
            "n_transfers": 0 if arb is None else arb.n_transfers,
            "final_quota_chat": kv._tenants["chat"].quota_tokens,
            "final_quota_batch": kv._tenants["batch"].quota_tokens,
            "quota_trajectory": quota_traj[-6:],
        }
    out["quotas_moved"] = bool(out["arbitrated"]["n_transfers"] > 0)
    return out


def main(n_items: int, n_sets: int, steps: int) -> Dict:
    return {
        "controller": controller_axis(n_items),
        "arbiter": arbiter_axis(n_sets),
        "kv_quota": kv_quota_axis(steps),
    }


def run(n_items: int = 24_000, n_sets: int = 5000, steps: int = 120):
    """CSV-driver alias (see ``benchmarks/run.py``): quick-size axes,
    persisted through the shared ``bench_io`` path."""
    out = main(n_items, n_sets, steps)
    write_bench_json("forecast", out)
    ctrl, arb, kv = out["controller"], out["arbiter"], out["kv_quota"]
    return [
        ("controller", 0.0,
         f"predictive_wins_onset={ctrl['predictive_wins_onset']};"
         f"onset_waste={ctrl['predictive']['peak_onset_waste_frac']:.4f}"),
        ("arbiter", 0.0,
         f"fewer_bounces={arb['fewer_bounces']};"
         f"n_bounced={arb['forecast']['n_bounced']}"),
        ("kv_quota", 0.0,
         f"quotas_moved={kv['quotas_moved']};"
         f"n_transfers={kv['arbitrated']['n_transfers']}"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n-items", type=int, default=120_000,
                    help="controller-axis diurnal stream length")
    ap.add_argument("--n-sets", type=int, default=20_000,
                    help="arbiter-axis multi-tenant sets")
    ap.add_argument("--steps", type=int, default=400,
                    help="kv-quota-axis serving steps")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke size (all three axes)")
    args = ap.parse_args()
    if args.quick:
        out = main(min(args.n_items, 24_000), min(args.n_sets, 5000),
                   min(args.steps, 120))
    else:
        out = main(args.n_items, args.n_sets, args.steps)
    write_bench_json("forecast", out)
    print(json.dumps(out, indent=2))
    # enforced, not just reported: CI's bench-smoke run must go red when
    # the predictive path stops beating reactive where it is built to
    # (cheaper peak onsets, refits landing earlier) or when the quota
    # arbiter stops moving tokens between phased streams
    ctrl = out["controller"]
    if not ctrl["predictive_wins_onset"]:
        raise SystemExit(
            "predictive refits did not beat reactive on peak-onset waste: "
            f"{ctrl['predictive']['peak_onset_waste_frac']:.4f} vs "
            f"{ctrl['reactive']['peak_onset_waste_frac']:.4f}")
    if (ctrl["predictive"]["cum_waste_frac"]
            > ctrl["reactive"]["cum_waste_frac"]):
        raise SystemExit("predictive path lost on cumulative waste")
    if (ctrl["predictive"]["n_pre_peak_refits"]
            < ctrl["reactive"]["n_pre_peak_refits"]):
        raise SystemExit("predictive path pre-positioned fewer refits "
                         "before the peak than reactive")
    if not out["kv_quota"]["quotas_moved"]:
        raise SystemExit("token-quota arbiter moved no quota under "
                         "phased serving load")
