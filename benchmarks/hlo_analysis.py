"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so for
scan-over-layers models it under-reports FLOPs by ~L x microbatches
(verified empirically — see EXPERIMENTS.md §Dry-run methodology). This
walker parses the post-SPMD optimized HLO text and computes, per device:

  * dot FLOPs — every computation's cost multiplied through the while
    trip counts enclosing its call sites. Trip counts come from XLA's
    ``backend_config known_trip_count`` annotation on the while op
    (fallback: the `compare(iv, constant(N)), direction=LT` in the
    condition computation);
  * collective bytes (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute), using each op's RESULT shape as
    the per-device wire proxy (exact for all-reduce/permute; a
    participant-factor bound for gather/scatter — documented in
    EXPERIMENTS.md §Roofline);
  * per-collective-kind breakdowns for bottleneck attribution.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\-.]+)\s*=\s*((?:\([^=]*?\))|(?:[a-z0-9]+"
    r"\[[^\]]*\](?:\{[^}]*\})?))\s+([\w\-]+)")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\-.]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_PARAM_RE = re.compile(r"([\w\-.]+)\s*:\s*([a-z0-9]+\[[\d,]*\])")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        m = _HDR_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = [line]
        elif cur is not None:
            comps[cur].append(line)
            if line.strip() == "}":
                cur = None
    return comps


def _symbol_table(lines: List[str]) -> Dict[str, str]:
    table: Dict[str, str] = {}
    for p_name, p_type in _PARAM_RE.findall(lines[0]):
        table[p_name] = p_type
    for ln in lines[1:]:
        m = _DEF_RE.match(ln)
        if m:
            table[m.group(1)] = m.group(2)
    return table


def _dot_flops(line: str, table: Dict[str, str]) -> float:
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    out_dims = _dims(m.group(2))
    if out_dims is None:
        return 0.0
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    k = None
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    opm = re.search(r"dot\(([^)]*)\)", line)
    if cd is not None and opm is not None:
        names = re.findall(r"%([\w\-.]+)", opm.group(1))
        if names and names[0] in table:
            lhs_dims = _dims(table[names[0]])
            if lhs_dims is not None and cd.group(1):
                k = 1
                for ci in cd.group(1).split(","):
                    ci = int(ci)
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
    return 2.0 * out_elems * (k if k else 1)


def _while_trip(line: str, cond_name: Optional[str],
                trip_by_cond: Dict[str, Optional[int]]) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
    if m:
        return float(m.group(1))
    if cond_name is not None:
        t = trip_by_cond.get(cond_name)
        if t:
            return float(t)
    return 1.0


def _cond_trip_count(lines: List[str]) -> Optional[int]:
    consts = {}
    for ln in lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w\-.]+)\s*=\s*\S+\s+"
                     r"constant\((-?\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in lines:
        if "compare(" in ln and ("direction=LT" in ln
                                 or "direction=GT" in ln):
            for a in re.findall(r"%([\w\-.]+)", ln[ln.index("compare("):]):
                if a in consts:
                    return abs(consts[a])
    return None


def parse_hlo(text: str) -> Dict[str, CompStats]:
    comps = _split_computations(text)
    trip_by_cond = {name: _cond_trip_count(lines)
                    for name, lines in comps.items()}
    stats: Dict[str, CompStats] = {}
    for name, lines in comps.items():
        st = CompStats()
        table = _symbol_table(lines)
        for ln in lines[1:]:
            if " dot(" in ln:
                st.dot_flops += _dot_flops(ln, table)
                continue
            hit_coll = False
            for kind in _COLLECTIVES:
                if re.search(rf"\s{kind}(-start)?\(", ln):
                    m = _DEF_RE.match(ln)
                    if m:
                        b = _shape_bytes(m.group(2))
                        st.collective_bytes += b
                        st.coll_by_kind[kind] += b
                        hit_coll = True
                    break
            if hit_coll:
                continue
            if re.search(r"\swhile\(", ln):
                body = re.search(r"body=%?([\w\-.]+)", ln)
                cond = re.search(r"condition=%?([\w\-.]+)", ln)
                trip = _while_trip(ln, cond.group(1) if cond else None,
                                   trip_by_cond)
                if body:
                    st.calls.append((body.group(1), trip))
                continue
            for attr in ("to_apply", "calls"):
                mc = re.search(rf"{attr}=%?([\w\-.]+)", ln)
                if mc:
                    st.calls.append((mc.group(1), 1.0))
            mb = re.search(r"branch_computations=\{([^}]*)\}", ln)
            if mb:
                for callee in re.findall(r"%?([\w\-.]+)", mb.group(1)):
                    st.calls.append((callee, 1.0))
        stats[name] = st
    return stats


@dataclasses.dataclass
class HloCosts:
    dot_flops: float
    collective_bytes: float
    coll_by_kind: Dict[str, float]
    n_while: int

    def to_json(self) -> Dict:
        return {"dot_flops": self.dot_flops,
                "collective_bytes": self.collective_bytes,
                "coll_by_kind": dict(self.coll_by_kind),
                "n_while": self.n_while}


def analyze(text: str, entry: Optional[str] = None) -> HloCosts:
    """Total per-device dot FLOPs + collective bytes, trip-count aware."""
    stats = parse_hlo(text)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\-.]+)", text, re.MULTILINE)
        entry = m.group(1) if m else next(iter(stats))

    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def walk(name: str, depth=0) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        st = stats.get(name)
        if st is None or depth > 64:
            return 0.0, 0.0, {}
        memo[name] = (0.0, 0.0, {})  # cycle guard
        fl, cb = st.dot_flops, st.collective_bytes
        by = dict(st.coll_by_kind)
        for callee, mult in st.calls:
            cfl, ccb, cby = walk(callee, depth + 1)
            fl += mult * cfl
            cb += mult * ccb
            for k, v in cby.items():
                by[k] = by.get(k, 0.0) + mult * v
        memo[name] = (fl, cb, by)
        return memo[name]

    fl, cb, by = walk(entry)
    return HloCosts(dot_flops=fl, collective_bytes=cb, coll_by_kind=by,
                    n_while=len(re.findall(r"\swhile\(", text)))
