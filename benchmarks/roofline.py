"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run artifacts (reports/dryrun/*/<arch>__<shape>.json) and
derives, per cell:

  compute term    = dot_FLOPs_per_device / peak_FLOPs          [s]
  memory term     = HBM_traffic_per_device / HBM_bw            [s]
  collective term = collective_bytes_per_device / link_bw      [s]

Sources & method (documented because each needs care):
  * dot FLOPs and collective bytes come from the trip-count-aware HLO
    walker (benchmarks/hlo_analysis.py) over the compiled, SPMD-
    partitioned module — these are exact per-device counts including
    scan bodies (XLA's own cost_analysis counts loop bodies once; we
    record it alongside for reference but never use it raw).
  * collective bytes use each op's result shape: exact for all-reduce /
    collective-permute; for all-gather it counts the gathered result
    (≈ ring traffic per device), for reduce-scatter the scattered
    result x1 (lower bound). A single-number wire proxy, consistent
    across cells.
  * HBM traffic is ANALYTIC (XLA reports no loop-aware bytes): per
    microbatch the weights are read fwd+bwd and the gradient written
    (3x params), optimizer update reads+writes moments and params (5x),
    decode/prefill read weights once and stream the KV cache once, plus
    activation traffic ~ 4 bytes x tokens x d_model x layers x 6.
    The formulas are in `hbm_traffic()` below.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.

MODEL_FLOPS = 6·N·D for training (N = params, active for MoE), 2·N·D
for prefill, 2·N_active·B for decode. The ratio MODEL_FLOPS / HLO_FLOPs
exposes remat/replication waste.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)

SHAPE_TOKENS = {
    "train_4k": (256, 4096),
    "prefill_32k": (32, 32768),
    "decode_32k": (128, 1),
    "long_500k": (1, 1),
}


def _cfg(arch: str):
    from repro.models import get_config
    return get_config(arch)


def model_flops(arch: str, shape: str) -> float:
    cfg = _cfg(arch)
    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    b, s = SHAPE_TOKENS[shape]
    if shape == "train_4k":
        return 6.0 * n_active * b * s
    if shape == "prefill_32k":
        return 2.0 * n_active * b * s
    return 2.0 * n_active * b  # decode: one token per sequence


def _params_per_device(cfg, n_dev: int) -> float:
    """bf16 parameter bytes resident per device under the rule set:
    everything shards over the model axis (16); MoE expert tensors also
    shard over the data axis (EP) when divisible."""
    model_ways = 16
    total = cfg.param_count() * 2.0
    if cfg.n_experts and cfg.n_experts % (n_dev // 256 * 16) == 0:
        glu = 3
        expert = (cfg.n_experts * glu * cfg.d_model * cfg.d_ff
                  * cfg.n_layers * 2.0)
        rest = total - expert
        return expert / n_dev + rest / model_ways
    return total / model_ways


def hbm_traffic(arch: str, shape: str, rec: Dict, n_dev: int) -> float:
    """Analytic per-device HBM bytes per step (see module docstring)."""
    cfg = _cfg(arch)
    params_dev = _params_per_device(cfg, n_dev)
    b, s = SHAPE_TOKENS[shape]
    d = cfg.d_model
    layers = cfg.n_layers + cfg.encoder_layers
    if shape == "train_4k":
        m = rec.get("microbatches", 16)
        # per microbatch: params read fwd + read bwd + grad accum r/w
        weight_traffic = m * 4 * params_dev
        # optimizer pass: read mu, nu, params; write mu, nu, params
        moment_bytes = 2 * params_dev  # f32 moments (2x bf16), x2 tensors
        opt_traffic = 2 * moment_bytes * 2 + 2 * params_dev
        # activations: ~6 r/w of (tokens x d_model) per layer (bf16)
        act = 6 * (b * s * d * 2 / n_dev) * layers
        return weight_traffic + opt_traffic + act
    if shape == "prefill_32k":
        act = 4 * (b * s * d * 2 / n_dev) * layers
        return params_dev + act
    # decode: stream weights once + stream the KV/state cache once
    cache_bytes = _decode_cache_bytes(
        cfg, b, 32768 if shape == "decode_32k" else 524288) / n_dev
    return params_dev + cache_bytes


def _decode_cache_bytes(cfg, b: int, s: int) -> float:
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        per = (cfg.d_model // cfg.n_heads) * d_in * 4  # C matrix f32
        return cfg.n_layers * b * per
    slots = s
    pattern = cfg.block_pattern
    if pattern and all(k == "attn_local" for k in pattern):
        slots = min(s, cfg.sliding_window)
    n_attn = (cfg.n_layers if cfg.family != "hybrid"
              else cfg.n_layers // 3)
    kv = 2 * n_attn * b * slots * cfg.n_kv_heads * cfg.head_dim * 2
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        kv += (2 * cfg.n_layers // 3) * b * d_in * cfg.ssm_state * 4
    return kv


def roofline_row(rec: Dict) -> Dict:
    arch, shape = rec["arch"], rec["shape"]
    n_dev = rec["n_devices"]
    walk = rec["hlo_walk_per_device"]
    compute_s = walk["dot_flops"] / PEAK_FLOPS
    coll_s = walk["collective_bytes"] / LINK_BW
    mem_bytes = hbm_traffic(arch, shape, rec, n_dev)
    memory_s = mem_bytes / HBM_BW
    mf = model_flops(arch, shape)
    useful_ratio = mf / max(walk["dot_flops"] * n_dev, 1.0)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # roofline fraction: useful-model-time / bound-time
    model_time = mf / n_dev / PEAK_FLOPS
    frac = model_time / bound if bound > 0 else 0.0
    return {
        "arch": arch, "shape": shape, "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": walk["dot_flops"] * n_dev,
        "useful_ratio": useful_ratio, "roofline_frac": frac,
        "mem_gib_dev": (rec["memory_per_device"].get(
            "argument_size_in_bytes", 0)
            + rec["memory_per_device"].get("temp_size_in_bytes", 0)
            - rec["memory_per_device"].get("alias_size_in_bytes", 0))
        / 2**30,
        "coll_by_kind": walk["coll_by_kind"],
    }


_SUGGEST = {
    "compute": ("useful_ratio low -> recompute/replication waste: relax "
                "remat policy or fix head/TP divisibility"),
    "memory": ("stream less state: shard cache further, rolling windows "
               "for local layers, bf16 moments, fewer microbatches"),
    "collective": ("resharding churn: align layer in/out shardings, "
                   "replicate small-head activations instead of "
                   "gathering, move reduce out of scan body"),
}


def suggestion(row: Dict) -> str:
    if row["dominant"] == "compute" and row["useful_ratio"] > 0.5:
        return "near-roofline compute bound: increase arithmetic intensity"
    return _SUGGEST[row["dominant"]]


def build_table(report_dir: str = "reports/dryrun/single") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        rows.append(roofline_row(rec))
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bound | MODEL_TF | useful | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['model_flops']/1e12:.1f} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |\n")
    return "".join(out)


def main() -> None:
    report_dir = sys.argv[1] if len(sys.argv) > 1 else \
        "reports/dryrun/single"
    rows = build_table(report_dir)
    if not rows:
        print(f"no dry-run artifacts under {report_dir}")
        return
    print(to_markdown(rows))
    os.makedirs("reports", exist_ok=True)
    with open("reports/roofline.md", "w") as f:
        f.write(to_markdown(rows))
        f.write("\nPer-cell bottleneck notes:\n")
        for r in rows:
            f.write(f"- {r['arch']}:{r['shape']} -> {r['dominant']}-bound; "
                    f"{suggestion(r)}\n")
    with open("reports/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(f"{len(rows)} cells -> reports/roofline.md")


if __name__ == "__main__":
    main()
