"""Benchmark: host-dict vs device-sketch observation, fused vs per-batch.

The observe half of the paper's loop, measured three ways:

* **throughput** — items/second of ``DecayedSizeHistogram.observe_many``
  (the host python-dict sketch, one dict update per item) vs the device
  sketch driven per-batch (one jitted dispatch per ``observe_many``) vs
  the FUSED observe window (``observe_window``: a whole chunk of batches
  scanned through ``sketch_update`` in ONE dispatch), with dispatch
  accounting (``n_dispatches``) per path. CI-enforced: the run fails if
  fused device throughput regresses below the host baseline.
* **sync traffic** — a phase-shifted traffic replay through three
  ``SlabController``s (host sketch, ``device=True`` per-batch,
  ``device=True`` fused window), counting device↔host materializations
  (``n_host_syncs``) and observe-loop launches (``n_dispatches``) per
  cadence window, and checking all three paths reach the SAME refit
  decisions. The fused path costs 1 dispatch + at most 1 host sync per
  window — the drift scalar rides along in the flush dispatch.
* **arbiter scoring** — N tenants' drift checks coming due on the same
  ``TenantArbiter.tick``: every pending candidate frontier is scored in
  ONE batched ``waste_eval`` launch (CI-enforced), instead of one
  launch per tenant.

``python benchmarks/observe_bench.py`` emits JSON;
``--quick`` is the CI smoke size.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import (ControllerConfig, DecayedSizeHistogram,
                        DeviceSizeSketch, SlabController, SlabPolicy,
                        schedule_with_default_tail, size_histogram)
from repro.core.distribution import PAPER_WORKLOADS
from repro.memcached import phase_shift_traffic

K = 6
BATCH = 512
WINDOW_BATCHES = 8      # batches per fused observe window


def observe_throughput(n_items: int, *, batch: int = BATCH,
                       half_life: float = 4000.0,
                       num_buckets: int = 1 << 12) -> Dict:
    """items/s of host dict vs per-batch device vs fused device window."""
    rng = np.random.default_rng(0)
    # full batches and full windows only: ragged tails compile extra
    # programs (a one-off cost), and this axis measures the steady-state
    # per-window dispatch cost
    n_items = max(n_items // (batch * WINDOW_BATCHES), 1) \
        * batch * WINDOW_BATCHES
    sizes = rng.integers(64, num_buckets - 1, n_items).astype(np.int64)
    batches = [sizes[i:i + batch] for i in range(0, n_items, batch)]

    host = DecayedSizeHistogram(half_life=half_life)
    t0 = time.perf_counter()
    for b in batches:
        host.observe_many(b)
    host_s = time.perf_counter() - t0

    device = DeviceSizeSketch(half_life=half_life, num_buckets=num_buckets)
    device.observe_many(batches[0])          # warmup: compile the launch
    device.reset()
    t0 = time.perf_counter()
    for b in batches:
        device.observe_many(b)
    device.weights_device.block_until_ready()
    device_s = time.perf_counter() - t0
    device_dispatches = device.n_dispatches

    fused = DeviceSizeSketch(half_life=half_life, num_buckets=num_buckets,
                             window=True)
    fused.observe_window(batches[:WINDOW_BATCHES])     # warmup compile
    fused.reset()
    t0 = time.perf_counter()
    for i in range(0, len(batches), WINDOW_BATCHES):
        fused.observe_window(batches[i:i + WINDOW_BATCHES])
    fused.weights_device.block_until_ready()
    fused_s = time.perf_counter() - t0
    n_windows = -(-len(batches) // WINDOW_BATCHES)

    out = {
        "n_items": n_items,
        "batch": batch,
        "window_batches": WINDOW_BATCHES,
        "host_items_per_s": round(n_items / host_s),
        "device_items_per_s": round(n_items / device_s),
        "fused_items_per_s": round(n_items / fused_s),
        "device_dispatches": device_dispatches,
        "fused_dispatches": fused.n_dispatches,
        "fused_dispatches_per_window": round(
            fused.n_dispatches / n_windows, 2),
        "device_speedup": round(host_s / device_s, 2),
        "fused_speedup": round(host_s / fused_s, 2),
    }
    if out["fused_items_per_s"] < out["host_items_per_s"]:
        # enforced, not just recorded: the whole point of the fused
        # window is that the device path stops losing to the host dict
        raise SystemExit(
            "fused device observe is SLOWER than the host baseline: "
            f"{out['fused_items_per_s']} < {out['host_items_per_s']} "
            "items/s")
    return out


def sync_axis(n_items: int, *, batch: int = BATCH) -> Dict:
    """Same refit decisions, one launch + at most one sync per window:
    host vs per-batch device vs fused device on phase-shifted traffic."""
    a, b = PAPER_WORKLOADS[0], PAPER_WORKLOADS[2]
    sizes = phase_shift_traffic(a, b, n_items=n_items, shift_at=0.5,
                                seed=11)
    support, freqs = size_histogram(sizes[:max(1, n_items // 10)])
    fit = SlabPolicy().fit(support, freqs, K, method="dp")
    deployed = schedule_with_default_tail(fit.chunk_sizes)
    cadence = max(250, n_items // 60)
    common = dict(k=K, check_every=cadence, half_life=2.0 * cadence,
                  drift_threshold=0.12,
                  min_items_between_refits=4 * cadence,
                  amortization_windows=8.0, cost_weight=0.1)

    out: Dict[str, Dict] = {}
    decisions = {}
    for name, config in (
            ("host", ControllerConfig(**common)),
            ("device_per_batch",
             ControllerConfig(**common, device=True,
                              device_buckets=1 << 12,
                              fused_observe=False)),
            ("device_fused",
             ControllerConfig(**common, device=True,
                              device_buckets=1 << 12))):
        ctl = SlabController(deployed, config=config)
        t0 = time.perf_counter()
        for i in range(0, len(sizes), batch):
            ctl.observe_many(sizes[i:i + batch])
            ctl.maybe_refit()
        dt = time.perf_counter() - t0
        decisions[name] = [(d.approved, d.reason) for d in ctl.decisions]
        out[name] = {
            "n_checks": ctl.n_checks,
            "n_refits": ctl.n_refits,
            "host_syncs": ctl.sketch.n_host_syncs,
            "dispatches": ctl.sketch.n_dispatches,
            "dispatches_per_window": round(
                ctl.sketch.n_dispatches / max(ctl.n_checks, 1), 2),
            "host_syncs_per_window": round(
                ctl.sketch.n_host_syncs / max(ctl.n_checks, 1), 2),
            "syncs_per_refit_window": round(
                ctl.sketch.n_host_syncs / max(ctl.n_refits, 1), 2),
            "wall_s": round(dt, 3),
        }
    out["decisions_match"] = (
        decisions["host"] == decisions["device_per_batch"]
        == decisions["device_fused"])
    out["sync_ratio"] = round(out["host"]["host_syncs"]
                              / max(out["device_fused"]["host_syncs"], 1), 1)
    if not out["decisions_match"]:
        # enforced, not just reported: CI's bench-smoke run must go red
        # when a device path stops reproducing the host decisions
        raise SystemExit(
            f"host/device refit decisions diverged: {decisions}")
    return out


def arbiter_axis(*, n_tenants: int = 8, per_tenant: int = 4000) -> Dict:
    """All tenants' drift checks due on one tick -> ONE waste_eval
    launch scoring every pending candidate frontier (CI-enforced)."""
    from repro.core.arbiter import PagePool, TenantArbiter
    from repro.core.slab_policy import default_memcached_schedule
    from repro.memcached import SlabAllocator

    page_size = 1 << 16
    pool = PagePool(64 * n_tenants, page_size=page_size)
    cadence = per_tenant // 2
    cfg = ControllerConfig(k=K, check_every=cadence,
                           half_life=float(cadence),
                           drift_threshold=0.05,
                           min_items_between_refits=0,
                           min_rel_improvement=0.0, cost_weight=0.0,
                           page_size=page_size)
    arb = TenantArbiter(pool, controller_config=cfg,
                        arbitrate_every=1 << 62)
    classes = default_memcached_schedule(page_size=page_size)
    rng = np.random.default_rng(3)
    for t in range(n_tenants):
        name = f"tenant{t}"
        alloc = SlabAllocator(classes, page_size=page_size,
                              page_pool=pool, tenant=name)
        arb.register(name, alloc)
    # phase A: every controller adopts its reference on the first tick
    for t in range(n_tenants):
        arb.tenants[f"tenant{t}"].controller.observe_many(
            rng.integers(100, 2000, cadence))
    arb.tick(0)
    # phase B: drifted traffic -> every frontier comes due together
    for t in range(n_tenants):
        arb.tenants[f"tenant{t}"].controller.observe_many(
            rng.integers(4000, 30000, cadence))
    launches0 = arb.n_score_launches
    t0 = time.perf_counter()
    arb.tick(0)
    dt = time.perf_counter() - t0
    out = {
        "n_tenants": n_tenants,
        "frontiers_scored": arb.n_frontiers_scored,
        "waste_eval_launches_per_tick": arb.n_score_launches - launches0,
        "tick_wall_s": round(dt, 4),
    }
    if out["waste_eval_launches_per_tick"] > 1:
        # enforced: fleet scoring must stay one launch per tick no
        # matter how many tenants come due together
        raise SystemExit(
            f"arbiter used {out['waste_eval_launches_per_tick']} "
            f"waste_eval launches for {n_tenants} pending tenants")
    return out


def main(n_items: int, *, guard: bool = False) -> Dict:
    from contextlib import nullcontext

    from repro.analysis.guards import no_implicit_transfers

    # --guard runs every axis under the transfer sanitizer: any implicit
    # device->host sync in the measured loops aborts the bench instead
    # of silently serializing the device queue into the timings
    with no_implicit_transfers() if guard else nullcontext():
        out = {
            "observe_throughput": observe_throughput(n_items),
            "syncs": sync_axis(n_items),
            "arbiter": arbiter_axis(),
        }
    out["guarded"] = guard
    return out


def run(n_items: int = 60_000) -> List[Tuple[str, float, str]]:
    """CSV-driver alias (see ``benchmarks/run.py``): same measurements,
    persisted through the shared ``bench_io`` path."""
    try:
        from bench_io import write_bench_json
    except ImportError:      # running as a package module
        from benchmarks.bench_io import write_bench_json
    out = main(n_items)
    write_bench_json("observe", out)
    tp, sx, ar = out["observe_throughput"], out["syncs"], out["arbiter"]
    return [
        ("host_observe", 1e6 * tp["n_items"] / tp["host_items_per_s"]
         / max(tp["n_items"] // tp["batch"], 1),
         f"items_per_s={tp['host_items_per_s']}"),
        ("fused_observe", 1e6 * tp["n_items"] / tp["fused_items_per_s"]
         / max(tp["n_items"] // tp["batch"], 1),
         f"items_per_s={tp['fused_items_per_s']};"
         f"dispatches_per_window={tp['fused_dispatches_per_window']}"),
        ("sync_axis", 1e6 * sx["device_fused"]["wall_s"],
         f"decisions_match={sx['decisions_match']};"
         f"dispatches_per_window="
         f"{sx['device_fused']['dispatches_per_window']}"),
        ("arbiter_axis", 1e6 * ar["tick_wall_s"],
         f"launches_per_tick={ar['waste_eval_launches_per_tick']};"
         f"frontiers={ar['frontiers_scored']}"),
    ]


if __name__ == "__main__":
    from bench_io import write_bench_json
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n-items", type=int, default=200_000)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke size")
    ap.add_argument("--guard", action="store_true",
                    help="arm repro.analysis.guards.no_implicit_transfers "
                         "around every measured loop")
    args = ap.parse_args()
    n = min(args.n_items, 20_000) if args.quick else args.n_items
    out = main(n, guard=args.guard)
    write_bench_json("observe", out)
    print(json.dumps(out, indent=2))
