"""Benchmark: host-dict vs device-sketch observation.

The observe half of the paper's loop, measured two ways:

* **throughput** — items/second of ``DecayedSizeHistogram.observe_many``
  (the host python-dict sketch, one dict update per item) vs
  ``DeviceSizeSketch.observe_many`` (one Pallas ``sketch_update`` launch
  per batch), on the same batched size stream;
* **sync traffic** — a phase-shifted traffic replay through two
  ``SlabController``s (host sketch vs ``device=True``), counting
  device↔host sketch materializations (``n_host_syncs``) per refit
  window and checking the two paths reach the SAME refit decisions.
  The host path materializes the sketch at every drift check; the
  device path only when the drift gate has already passed and a refit
  is actually evaluated.

``python benchmarks/observe_bench.py`` emits JSON;
``--quick`` is the CI smoke size.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict

import numpy as np

from repro.core import (ControllerConfig, DecayedSizeHistogram,
                        DeviceSizeSketch, SlabController, SlabPolicy,
                        schedule_with_default_tail, size_histogram)
from repro.core.distribution import PAPER_WORKLOADS
from repro.memcached import phase_shift_traffic

K = 6
BATCH = 512


def observe_throughput(n_items: int, *, batch: int = BATCH,
                       half_life: float = 4000.0,
                       num_buckets: int = 1 << 12) -> Dict:
    """items/s of the host dict vs the device sketch on one stream."""
    rng = np.random.default_rng(0)
    sizes = rng.integers(64, num_buckets - 1, n_items).astype(np.int64)
    batches = [sizes[i:i + batch] for i in range(0, n_items, batch)]

    host = DecayedSizeHistogram(half_life=half_life)
    t0 = time.perf_counter()
    for b in batches:
        host.observe_many(b)
    host_s = time.perf_counter() - t0

    device = DeviceSizeSketch(half_life=half_life, num_buckets=num_buckets)
    device.observe_many(batches[0])          # warmup: compile the launch
    device.reset()
    t0 = time.perf_counter()
    for b in batches:
        device.observe_many(b)
    device.weights_device.block_until_ready()
    device_s = time.perf_counter() - t0

    return {
        "n_items": n_items,
        "batch": batch,
        "host_items_per_s": round(n_items / host_s),
        "device_items_per_s": round(n_items / device_s),
        "device_speedup": round(host_s / device_s, 2),
    }


def sync_axis(n_items: int, *, batch: int = BATCH) -> Dict:
    """Same refit decisions, far fewer host syncs: the fused device path
    vs the host path on phase-shifted traffic."""
    a, b = PAPER_WORKLOADS[0], PAPER_WORKLOADS[2]
    sizes = phase_shift_traffic(a, b, n_items=n_items, shift_at=0.5,
                                seed=11)
    support, freqs = size_histogram(sizes[:max(1, n_items // 10)])
    fit = SlabPolicy().fit(support, freqs, K, method="dp")
    deployed = schedule_with_default_tail(fit.chunk_sizes)
    cadence = max(250, n_items // 60)
    common = dict(k=K, check_every=cadence, half_life=2.0 * cadence,
                  drift_threshold=0.12,
                  min_items_between_refits=4 * cadence,
                  amortization_windows=8.0, cost_weight=0.1)

    out: Dict[str, Dict] = {}
    decisions = {}
    for name, config in (
            ("host", ControllerConfig(**common)),
            ("device", ControllerConfig(**common, device=True,
                                        device_buckets=1 << 12))):
        ctl = SlabController(deployed, config=config)
        t0 = time.perf_counter()
        for i in range(0, len(sizes), batch):
            ctl.observe_many(sizes[i:i + batch])
            ctl.maybe_refit()
        dt = time.perf_counter() - t0
        decisions[name] = [(d.approved, d.reason) for d in ctl.decisions]
        out[name] = {
            "n_checks": ctl.n_checks,
            "n_refits": ctl.n_refits,
            "host_syncs": ctl.sketch.n_host_syncs,
            "syncs_per_refit_window": round(
                ctl.sketch.n_host_syncs / max(ctl.n_refits, 1), 2),
            "wall_s": round(dt, 3),
        }
    out["decisions_match"] = decisions["host"] == decisions["device"]
    out["sync_ratio"] = round(out["host"]["host_syncs"]
                              / max(out["device"]["host_syncs"], 1), 1)
    if not out["decisions_match"]:
        # enforced, not just reported: CI's bench-smoke run must go red
        # when the device path stops reproducing the host decisions
        raise SystemExit(
            f"host/device refit decisions diverged: {decisions}")
    return out


def main(n_items: int) -> Dict:
    return {
        "observe_throughput": observe_throughput(n_items),
        "syncs": sync_axis(n_items),
    }


if __name__ == "__main__":
    from bench_io import write_bench_json
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n-items", type=int, default=200_000)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke size")
    args = ap.parse_args()
    n = min(args.n_items, 20_000) if args.quick else args.n_items
    out = main(n)
    write_bench_json("observe", out)
    print(json.dumps(out, indent=2))
