"""Fleet-scale arbitration bench: the legacy per-tenant Python loop vs
``TenantArbiter(fleet=True)`` stacked state, 10 → 5,000 tenants.

Twin arbiters replay the SAME ``multitenant_phased_ops`` stream (the
paper's five operating points fanned out across the whole fleet, each
physical tenant inheriting one logical workload's phased pattern) over
a pool tight enough that peaks generate real denial/eviction pressure —
so every arbitration round actually runs the donor-pricing loop, the
forecast surcharge, and executed transfers, not the everyone-is-happy
early exit. Per sweep point the bench reports

* **arbitration-decision latency per tick** — wall time of the per-tick
  decision path (due-scan + one arbitration round) for each mode, and
  the fleet speedup (the headline gate: >= 10x at 1,000 tenants),
* **decision parity** — the two modes' full ``TransferDecision``
  sequences compared field-for-field (bit-identical floats included);
  any mismatch fails the run,
* **hole fraction** — end-of-run unused pool fraction, identical by
  construction when decisions match,

plus a device-sketch **gate cell** proving dispatch accounting: driven
through ``observe``/``tick`` (the serving mode), the fleet's batched
drift gate and batched frontier scoring stay O(decision stages) per
tick — ``n_gate_launches + n_score_launches <= 2 * ticks`` — however
many tenants come due together, where legacy pays one gate launch per
due tenant.

``python benchmarks/fleet_bench.py --quick`` is the CI smoke size: a
small sweep that still asserts decision parity and the dispatch bounds,
exiting nonzero on any failure. The full run adds the 1,000/5,000
points and gates on the >= 10x speedup. Results go to
``BENCH_fleet.json``; ``run()`` returns CSV rows for
``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import ControllerConfig, PagePool, TenantArbiter
from repro.core.distribution import (PAPER_WORKLOADS,
                                     sample_lognormal_sizes)
from repro.core.forecast import DemandForecaster
from repro.core.slab_policy import default_memcached_schedule
from repro.memcached import SlabAllocator, multitenant_phased_ops

PAGE_SIZE = 1 << 14          # small pages: phased peaks overflow quota
SWEEP = (10, 50, 200, 1000, 5000)
QUICK_SWEEP = (10, 50)
SETS_PER_TENANT_ROUND = 6
DECISION_STAGES = 2          # batched drift gate + batched frontier score
SPEEDUP_FLOOR = 10.0         # at >= SPEEDUP_AT tenants (full run)
SPEEDUP_AT = 1000


def _name(i: int) -> str:
    return f"t{i:05d}"


def _rounds_for(n: int) -> int:
    # enough rounds to fill the forecast ring: the legacy loop's
    # per-candidate ACF cost is what the batched stage amortizes, and
    # it only shows once the rings carry real history
    return 8 if n <= 1000 else 6


def fleet_stream(n_tenants: int, n_sets: int, seed: int
                 ) -> List[Tuple[int, object]]:
    """The paper's phased multi-tenant stream fanned out to a fleet.

    ``multitenant_phased_ops`` interleaves one stream per operating
    point; each set op is routed round-robin to one of the physical
    tenants backing that operating point (``logical + W*k mod n``), so
    every fleet tenant sees one workload's sizes and phase. Deletes
    follow their key to whichever tenant stored it.
    """
    w = len(PAPER_WORKLOADS)
    base = multitenant_phased_ops(PAPER_WORKLOADS, n_sets=n_sets,
                                  trough_mix=0.5, seed=seed)
    cycles = max(1, -(-n_tenants // w))
    cnt = [0] * w
    home: Dict[Tuple[int, str], int] = {}
    out: List[Tuple[int, object]] = []
    for op in base:
        k = (op.tenant, op.key)
        if op.op == "set" and k not in home:
            home[k] = (op.tenant + w * cnt[op.tenant]) % n_tenants
            cnt[op.tenant] = (cnt[op.tenant] + 1) % cycles
        out.append((home[k], op))
    return out


def build_arbiter(n_tenants: int, *, fleet: bool,
                  check_every: int = 10**9,
                  device: bool = False) -> TenantArbiter:
    pool = PagePool(2 * n_tenants, page_size=PAGE_SIZE)
    forecast = DemandForecaster(ring=12, min_confidence=0.05)
    cfg = ControllerConfig(page_size=PAGE_SIZE, check_every=check_every,
                           min_items_between_refits=2 * check_every,
                           device=device)
    arb = TenantArbiter(pool, controller_config=cfg,
                        arbitrate_every=10**9,   # explicit cadence below
                        forecast=forecast, fleet=fleet,
                        fleet_capacity=max(8, n_tenants))
    classes = default_memcached_schedule(page_size=PAGE_SIZE)
    for i in range(n_tenants):
        name = _name(i)
        arb.register(name, SlabAllocator(classes, page_size=PAGE_SIZE,
                                         page_pool=pool, tenant=name))
    pool.equal_partition(floor=1)
    return arb


def decisions_sig(arb: TenantArbiter) -> List[Tuple]:
    """Every TransferDecision, every field — exact floats, no rounding:
    the parity gate is bit-identity, not closeness."""
    return [(d.approved, d.reason, d.donor, d.recipient, d.benefit,
             d.cost, d.forecast_penalty, d.evicted_items,
             d.evicted_bytes, d.at_op) for d in arb.decisions]


def _hole_frac(arb: TenantArbiter) -> float:
    pool_bytes = arb.pool.total_pages * PAGE_SIZE
    live = sum(t.allocator.stats().item_bytes
               for t in arb.tenants.values())
    return (pool_bytes - live) / pool_bytes


def _drive(arb: TenantArbiter, chunks) -> List[float]:
    """Feed one chunk per tick (untimed: identical traffic cost both
    modes), then time the decision path — due-scan + one arbitration
    round — which is what fleet mode vectorizes."""
    tick_s: List[float] = []
    for chunk in chunks:
        for phys, op in chunk:
            name = _name(phys)
            if op.op == "set":
                arb.set(name, op.key, op.size)
            elif op.op == "delete":
                arb.delete(name, op.key)
            else:
                if not arb.get(name, op.key):
                    arb.set(name, op.key, op.size)
        t0 = time.perf_counter()
        arb.tick(0)
        arb.arbitrate()
        tick_s.append(time.perf_counter() - t0)
    return tick_s


def bench_cell(n_tenants: int, *, seed: int = 7) -> Dict:
    """One sweep point: twin arbiters, same stream, timed decisions."""
    rounds = _rounds_for(n_tenants)
    stream = fleet_stream(n_tenants,
                          rounds * n_tenants * SETS_PER_TENANT_ROUND,
                          seed)
    per = len(stream) // rounds
    chunks = [stream[i * per:(i + 1) * per] for i in range(rounds)]
    side: Dict[str, Dict] = {}
    sigs: Dict[str, List] = {}
    for mode, fleet in (("legacy", False), ("fleet", True)):
        arb = build_arbiter(n_tenants, fleet=fleet)
        tick_s = _drive(arb, chunks)
        sigs[mode] = decisions_sig(arb)
        side[mode] = {
            "ms_per_tick": 1e3 * sum(tick_s) / len(tick_s),
            "n_decisions": len(arb.decisions),
            "n_transfers": arb.n_transfers,
            "hole_frac": _hole_frac(arb),
            "n_gate_launches": arb.n_gate_launches,
            "n_score_launches": arb.n_score_launches,
        }
    return {
        "n_tenants": n_tenants,
        "n_ops": len(stream),
        "ticks": rounds,
        "legacy": side["legacy"],
        "fleet": side["fleet"],
        "speedup": (side["legacy"]["ms_per_tick"]
                    / max(side["fleet"]["ms_per_tick"], 1e-9)),
        "decisions_match": sigs["legacy"] == sigs["fleet"],
    }


def gate_cell(n_tenants: int = 24, *, rounds: int = 8,
              seed: int = 7, guard: bool = False) -> Dict:
    """Device-sketch dispatch accounting: ``observe``/``tick`` driven
    (the serving mode), all tenants coming due together each check
    window. Fleet must hold ``gate + score launches <= 2 * ticks``;
    refit verdicts must agree with legacy (drift to float tolerance —
    the batched gate and the fused solo gate reduce in different
    launch shapes)."""
    from contextlib import nullcontext

    from repro.analysis.guards import no_implicit_transfers

    w = len(PAPER_WORKLOADS)
    side: Dict[str, Dict] = {}
    for mode, fleet in (("legacy", False), ("fleet", True)):
        arb = build_arbiter(n_tenants, fleet=fleet, check_every=128,
                            device=True)
        rng = np.random.default_rng(seed)
        # --guard arms the transfer sanitizer for the whole drive: any
        # sync outside a deliberate_sync seam aborts instead of hiding
        # a per-tenant readback inside the batched-gate timings
        with no_implicit_transfers() if guard else nullcontext():
            for r in range(rounds):
                for i in range(n_tenants):
                    wl = PAPER_WORKLOADS[i % w]
                    mu = wl.mu * (1.6 if (r // 2) % 2 else 1.0)  # drift
                    sizes = sample_lognormal_sizes(rng, 64, mu, wl.sigma,
                                                   max_size=PAGE_SIZE)
                    arb.observe(_name(i), sizes)
                arb.tick(1)
        side[mode] = {
            "refit_sig": [
                (n, d.approved, d.reason, round(float(d.drift), 6))
                for n in sorted(arb.tenants)
                for d in arb.tenants[n].controller.decisions],
            "n_refits": sum(t.controller.n_refits
                            for t in arb.tenants.values()),
            "n_checks": sum(len(t.controller.decisions)
                            for t in arb.tenants.values()),
            "n_gate_launches": arb.n_gate_launches,
            "n_score_launches": arb.n_score_launches,
        }
    fleet_dispatches = (side["fleet"]["n_gate_launches"]
                       + side["fleet"]["n_score_launches"])
    return {
        "n_tenants": n_tenants,
        "ticks": rounds,
        "legacy": {k: v for k, v in side["legacy"].items()
                   if k != "refit_sig"},
        "fleet": {k: v for k, v in side["fleet"].items()
                  if k != "refit_sig"},
        "fleet_dispatches_per_tick": fleet_dispatches / rounds,
        "dispatch_bound_ok": (
            fleet_dispatches <= DECISION_STAGES * rounds
            and side["fleet"]["n_gate_launches"] >= 1),
        "refits_match": (side["legacy"]["refit_sig"]
                         == side["fleet"]["refit_sig"]),
    }


def run_sweep(sweep=SWEEP, *, seed: int = 7, guard: bool = False) -> Dict:
    cells: Dict[str, Dict] = {}
    for n in sweep:
        t0 = time.perf_counter()
        cell = bench_cell(n, seed=seed)
        cell["seconds"] = round(time.perf_counter() - t0, 3)
        cells[str(n)] = cell
    gate = gate_cell(16 if max(sweep) <= 200 else 24,
                     rounds=6 if max(sweep) <= 200 else 8, seed=seed,
                     guard=guard)
    failures: List[str] = []
    for n, cell in cells.items():
        if not cell["decisions_match"]:
            failures.append(f"n={n}: decision sequences diverge")
    if not gate["dispatch_bound_ok"]:
        failures.append(
            f"gate cell: {gate['fleet_dispatches_per_tick']:.2f} "
            f"dispatches/tick exceeds {DECISION_STAGES} stages "
            "(or the gate never batched)")
    if not gate["refits_match"]:
        failures.append("gate cell: refit verdicts diverge")
    for n, cell in cells.items():
        if int(n) >= SPEEDUP_AT and cell["speedup"] < SPEEDUP_FLOOR:
            failures.append(
                f"n={n}: speedup {cell['speedup']:.1f}x < "
                f"{SPEEDUP_FLOOR:.0f}x")
    return {"page_size": PAGE_SIZE, "sweep": list(sweep),
            "sets_per_tenant_per_tick": SETS_PER_TENANT_ROUND,
            "decision_stages": DECISION_STAGES, "guarded": guard,
            "cells": cells, "gate_cell": gate, "failures": failures}


def run() -> List[Tuple[str, float, str]]:
    out = run_sweep((10, 50, 200))
    rows = []
    for n, cell in out["cells"].items():
        rows.append((
            f"n{n}", cell["fleet"]["ms_per_tick"] * 1e3,
            f"speedup={cell['speedup']:.1f}x;"
            f"match={cell['decisions_match']};"
            f"transfers={cell['fleet']['n_transfers']}"))
    g = out["gate_cell"]
    rows.append(("gate_cell", 0.0,
                 f"dispatches_per_tick={g['fleet_dispatches_per_tick']:.2f};"
                 f"bound_ok={g['dispatch_bound_ok']};"
                 f"refits_match={g['refits_match']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small sweep, parity + dispatch gates")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--guard", action="store_true",
                    help="arm repro.analysis.guards.no_implicit_transfers "
                         "around the device-sketch gate cell")
    args = ap.parse_args(argv)
    sweep = QUICK_SWEEP if args.quick else SWEEP
    out = run_sweep(sweep, seed=args.seed, guard=args.guard)
    from bench_io import write_bench_json
    write_bench_json("fleet", out)
    print(json.dumps(out, indent=2, default=str))
    if out["failures"]:
        for f in out["failures"]:
            print(f"[fleet] FAIL {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
