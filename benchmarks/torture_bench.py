"""Torture bench: the scenario matrix, scored by its WORST case.

Every other bench in this repo reports means on traffic we chose; this
one replays the ``repro.scenarios`` matrix — trace replay through the
CSV parser, chaos events (tenant join/leave, flash crowds,
forecast-defeating size steps, TTL storms), and the adversarially-found
drift fixture — through the real ``SlabController`` + ``TenantArbiter``
+ ``SlabAllocator`` stack and a ``KVSlabPool`` under the token-quota
arbiter, under both the reactive and the forecast policy. What goes in
``BENCH_torture.json`` is the **worst case across the matrix**: max
mean/peak hole fraction, max cumulative waste, max forecast-miss refits
(reactive refits chasing a shock), and the total count of invariant
violations (conservation, sketch mass, dispatch accounting, KV token
accounting) — which must be ZERO; any violation exits nonzero, which is
the CI gate.

``python benchmarks/torture_bench.py --quick`` is the CI smoke size;
``--scenario`` / ``--axis`` narrow the matrix (the CI job shards on
these); ``run()`` returns CSV rows for ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.core import ControllerConfig, PagePool, TenantArbiter
from repro.core.distribution import PAPER_WORKLOADS
from repro.core.forecast import DemandForecaster
from repro.core.slab_policy import default_memcached_schedule
from repro.memcached import SlabAllocator, multitenant_phased_ops
from repro.scenarios import (FlashCrowd, SizeStep, TenantJoin, TenantLeave,
                             TTLStorm, WORST_FIXTURE, apply_chaos,
                             check_all, check_kv_pool, format_trace,
                             load_fixture, parse_trace, replay_fixture,
                             tenants_of)

PAGE_SIZE = 1 << 16       # same arbitration quantum as multitenant_bench
PAGES_PER_KSET = 3        # pool scaled to stream length: genuine contention
N_SETS = 20_000
K = 6
SCENARIOS = ("trace_replay", "join_leave", "flash_crowd", "size_step",
             "ttl_storm", "adversarial_drift", "kv_chaos")
# policy columns; "fleet" = the forecast policy decided by
# TenantArbiter(fleet=True) stacked state (decision-identical to
# "forecast" — the fleet-consistency invariant is what it tortures)
AXES = ("reactive", "forecast", "fleet")


def make_stream(scenario: str, *, n_sets: int, n_tenants: int = 3,
                seed: int = 7):
    """One scenario's op stream + its chaos marks.

    Every scenario starts from the same out-of-phase multi-tenant base
    stream; ``trace_replay`` routes it through the CSV writer + parser
    (so the full trace path is under torture too), the chaos scenarios
    perturb it with one event family each — sized/timed against the
    stream so each hits the mechanism it is named for.
    """
    workloads = PAPER_WORKLOADS[:n_tenants]
    base = multitenant_phased_ops(workloads, n_sets=n_sets,
                                  trough_mix=0.5, seed=seed)
    n = len(base)
    if scenario == "trace_replay":
        ops = parse_trace(format_trace(base))
        assert ops == base, "trace round-trip drifted from the base stream"
        return ops, []
    if scenario == "join_leave":
        events = [
            TenantJoin(at=n // 4, tenant=n_tenants,
                       workload=PAPER_WORKLOADS[-1], rate=0.4,
                       lifetime=max(200, n // 6)),
            TenantLeave(at=2 * n // 3, tenant=0, flush=True),
        ]
    elif scenario == "flash_crowd":
        events = [FlashCrowd(at=n // 3, duration=max(100, n // 6),
                             tenant=1, boost=3)]
    elif scenario == "size_step":
        # One aperiodic step for every tenant: the seasonal-naive
        # forecast keeps replaying the pre-step sizes — the refits this
        # forces are exactly what forecast_miss_refits counts.
        events = [SizeStep(at=n // 2,
                           workload=PAPER_WORKLOADS[n_tenants % len(
                               PAPER_WORKLOADS)])]
    elif scenario == "ttl_storm":
        events = [TTLStorm(at=n // 2, frac=0.6)]
    else:
        raise ValueError(f"unknown stream scenario {scenario!r}")
    res = apply_chaos(base, events, seed=seed)
    return res.ops, res.marks


def _build_arbiter(n_tenants: int, *, total_pages: int, axis: str,
                   check_every: int, fleet: bool = False
                   ) -> TenantArbiter:
    fleet = fleet or axis == "fleet"
    forecast = (DemandForecaster(ring=16)
                if axis in ("forecast", "fleet") else None)
    cfg = ControllerConfig(
        k=K, page_size=PAGE_SIZE, check_every=check_every,
        drift_threshold=0.12, min_items_between_refits=2 * check_every,
        amortization_windows=8.0, cost_weight=0.1, forecast=forecast)
    pool = PagePool(total_pages, page_size=PAGE_SIZE)
    arb = TenantArbiter(pool, controller_config=cfg,
                        arbitrate_every=max(500, check_every // 2),
                        amortization_windows=8.0, cost_weight=0.1,
                        forecast=forecast, fleet=fleet)
    classes = default_memcached_schedule(page_size=PAGE_SIZE)
    for t in range(n_tenants):
        name = f"tenant{t}"
        alloc = SlabAllocator(classes, page_size=PAGE_SIZE,
                              page_pool=pool, tenant=name)
        arb.register(name, alloc,
                     floor_pages=max(1, total_pages // (4 * n_tenants)))
    pool.equal_partition()
    return arb


def drive(ops, marks, *, n_tenants: int, total_pages: int, axis: str,
          check_every: int, sample_every: int = 250,
          fleet: bool = False) -> Dict:
    """Replay one scenario stream through the arbitrated stack,
    checking every invariant at every sample point. Chaos marks are
    fed to ``TenantArbiter.note_event`` as they are crossed, so the
    forecast-miss accounting lines up with the injections. ``fleet``
    routes the same stream through ``TenantArbiter(fleet=True)`` —
    chaos churn over stacked rows, with the fleet-consistency
    invariant checked at every sample point."""
    arb = _build_arbiter(n_tenants, total_pages=total_pages, axis=axis,
                         check_every=check_every, fleet=fleet)
    pool_bytes = total_pages * PAGE_SIZE
    marks = sorted(marks)
    mark_i = 0
    hole_fracs: List[float] = []
    cum_waste = 0
    violations: List[str] = []
    since_sample = 0
    for i, op in enumerate(ops):
        while mark_i < len(marks) and marks[mark_i][0] <= i:
            arb.note_event(marks[mark_i][1])
            mark_i += 1
        name = f"tenant{op.tenant}"
        if name not in arb.tenants:        # chaos joiner: register live
            alloc = SlabAllocator(
                default_memcached_schedule(page_size=PAGE_SIZE),
                page_size=PAGE_SIZE, page_pool=arb.pool, tenant=name)
            arb.register(name, alloc, floor_pages=1)
        if op.op == "set":
            arb.set(name, op.key, op.size)
        elif op.op == "get":
            if not arb.get(name, op.key):
                arb.set(name, op.key, op.size)     # read-through refill
        else:
            arb.delete(name, op.key)
        since_sample += 1
        if since_sample >= sample_every:
            since_sample = 0
            live = sum(t.allocator.stats().item_bytes
                       for t in arb.tenants.values())
            hole_fracs.append((pool_bytes - live) / pool_bytes)
            cum_waste += sum(t.allocator.stats().waste
                             for t in arb.tenants.values()) * sample_every
            violations.extend(check_all(
                pool=arb.pool,
                sketches=[t.controller.sketch
                          for t in arb.tenants.values()],
                arbiter=arb))
    violations.extend(check_all(
        pool=arb.pool,
        sketches=[t.controller.sketch for t in arb.tenants.values()],
        arbiter=arb))
    return {
        "n_ops": len(ops),
        "mean_hole_frac": (sum(hole_fracs) / max(len(hole_fracs), 1)),
        "peak_hole_frac": max(hole_fracs, default=0.0),
        "cum_waste_byte_ops": int(cum_waste),
        "n_refits": sum(t.controller.n_refits
                        for t in arb.tenants.values()),
        "forecast_miss_refits": arb.forecast_miss_refits(),
        "n_transfers": arb.n_transfers,
        "n_events": len(arb.events),
        "violations": violations,
    }


def drive_adversarial(*, n_sets: int, axis: str, check_every: int,
                      fixture: Optional[str] = None) -> Dict:
    """The adversarial-drift scenario: replay the checked-in worst
    fixture allocator-free for its exact regret numbers, then drive its
    size stream through a single-tenant arbitrated allocator (unique
    keys; the pool evicts) for hole/invariant torture."""
    path = fixture or WORST_FIXTURE
    rec = load_fixture(path)
    result = replay_fixture(path, strict=False)
    sizes = rec["schedule"].sizes()[:max(n_sets, 2 * check_every)]
    from repro.memcached.traffic import TenantOp
    ops = [TenantOp(0, "set", f"k{i}", int(s))
           for i, s in enumerate(sizes.tolist())]
    # every segment boundary is an event the forecaster cannot see
    fracs = [f for _, f in rec["schedule"].segments]
    total = sum(fracs)
    marks, acc = [], 0.0
    for f in fracs[:-1]:
        acc += f / total
        marks.append((int(acc * len(ops)), "drift-segment"))
    total_pages = max(12, PAGES_PER_KSET * len(ops) // 2000)
    out = drive(ops, marks, n_tenants=1, total_pages=total_pages,
                axis=axis, check_every=check_every)
    out.update({
        "fixture": os.path.basename(path),
        "regret_bytes": result.regret,
        "regret_recorded": rec["regret"],
        "regret_matches_fixture": result.regret == rec["regret"],
        "adaptive_waste": result.adaptive_waste,
        "oracle_waste": result.oracle_waste,
    })
    return out


def drive_kv(*, n_sets: int, axis: str, check_every: int,
             seed: int = 7) -> Dict:
    """The serving-layer scenario: a ``KVSlabPool`` under the
    token-quota arbiter, driven by a chaos-perturbed length stream
    (flash crowd + size step). Sets allocate, deletes free; quota and
    token-conservation invariants are checked throughout."""
    from repro.serving import KVSlabPool, token_quota_arbiter
    workloads = PAPER_WORKLOADS[:2]
    base = multitenant_phased_ops(workloads, n_sets=n_sets,
                                  trough_mix=0.5, seed=seed)
    n = len(base)
    res = apply_chaos(base, [
        FlashCrowd(at=n // 3, duration=max(100, n // 6), tenant=0, boost=3),
        SizeStep(at=2 * n // 3, factor=1.7),
    ], seed=seed)
    forecast = (DemandForecaster(ring=16)
                if axis in ("forecast", "fleet") else None)
    cfg = ControllerConfig(k=K, check_every=check_every, align=128,
                           min_chunk=128, page_size=1 << 13,
                           forecast=forecast)
    kv = KVSlabPool(n_sets * 160, [256, 512, 1024, 2048, 4096, 8192],
                    controller_config=cfg)
    for t in tenants_of(base, []):
        kv.register_tenant(f"stream{t}", quota_tokens=n_sets * 80)
    arb = token_quota_arbiter(kv, arbitrate_every=max(500, check_every),
                              fleet=axis == "fleet")
    live: Dict[str, int] = {}
    next_id = 0
    n_alloc = n_denied = 0
    violations: List[str] = []
    marks = sorted(res.marks)
    mark_i = 0
    for i, op in enumerate(res.ops):
        while mark_i < len(marks) and marks[mark_i][0] <= i:
            arb.note_event(marks[mark_i][1])
            mark_i += 1
        stream = f"stream{op.tenant}"
        if op.op == "set" and op.key not in live:
            a = kv.alloc(next_id, max(1, op.size), tenant=stream)
            if a is None:
                n_denied += 1
            else:
                live[op.key] = next_id
                n_alloc += 1
            next_id += 1
        elif op.op == "delete" and op.key in live:
            kv.free(live.pop(op.key))
        arb.tick(1)
        if i % 250 == 0:
            violations.extend(check_all(pool=arb.pool,
                                        sketches=[kv.controller.sketch],
                                        kv_pool=kv, arbiter=arb))
    violations.extend(check_all(pool=arb.pool,
                                sketches=[kv.controller.sketch],
                                kv_pool=kv, arbiter=arb))
    s = kv.stats()
    return {
        "n_ops": len(res.ops),
        "n_alloc": n_alloc,
        "n_denied": n_denied,
        "mean_hole_frac": s.waste_fraction,
        "peak_hole_frac": s.waste_fraction,
        "cum_waste_byte_ops": int(s.waste_tokens) * len(res.ops),
        "n_refits": kv.controller.n_refits,
        "forecast_miss_refits": kv.controller.forecast_miss_refits(),
        "n_transfers": arb.n_transfers,
        "n_events": len(arb.events),
        "violations": violations,
    }


def run_matrix(*, n_sets: int = N_SETS, scenarios=SCENARIOS, axes=AXES,
               seed: int = 7) -> Dict:
    """The full scenario × policy matrix + the worst-case rollup."""
    check_every = max(300, n_sets // 10)
    n_tenants = 3
    total_pages = max(12, PAGES_PER_KSET * n_sets // 1000)
    cells: Dict[str, Dict] = {}
    for scenario in scenarios:
        for axis in axes:
            key = f"{scenario}/{axis}"
            t0 = time.perf_counter()
            if scenario == "adversarial_drift":
                cell = drive_adversarial(n_sets=n_sets, axis=axis,
                                         check_every=check_every)
            elif scenario == "kv_chaos":
                cell = drive_kv(n_sets=n_sets, axis=axis,
                                check_every=check_every, seed=seed)
            else:
                ops, marks = make_stream(scenario, n_sets=n_sets,
                                         n_tenants=n_tenants, seed=seed)
                cell = drive(ops, marks, n_tenants=n_tenants,
                             total_pages=total_pages, axis=axis,
                             check_every=check_every)
            cell["seconds"] = round(time.perf_counter() - t0, 3)
            cells[key] = cell
    worst = {
        "worst_mean_hole_frac": max(
            (c["mean_hole_frac"], k) for k, c in cells.items()),
        "worst_peak_hole_frac": max(
            (c["peak_hole_frac"], k) for k, c in cells.items()),
        "worst_cum_waste_byte_ops": max(
            (c["cum_waste_byte_ops"], k) for k, c in cells.items()),
        "worst_forecast_miss_refits": max(
            (c["forecast_miss_refits"], k) for k, c in cells.items()),
        "total_invariant_violations": sum(
            len(c["violations"]) for c in cells.values()),
    }
    return {"n_sets": n_sets, "k": K, "page_size": PAGE_SIZE,
            "scenarios": list(scenarios), "axes": list(axes),
            "worst_case": worst, "cells": cells}


def run(n_sets: int = 6000) -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    out = run_matrix(n_sets=n_sets)
    dt = (time.perf_counter() - t0) * 1e6 / max(
        sum(c["n_ops"] for c in out["cells"].values()), 1)
    w = out["worst_case"]
    return [(
        "torture_matrix", dt,
        f"worst_mean_hole={w['worst_mean_hole_frac'][0]:.4f}"
        f"@{w['worst_mean_hole_frac'][1]};"
        f"worst_miss_refits={w['worst_forecast_miss_refits'][0]}"
        f"@{w['worst_forecast_miss_refits'][1]};"
        f"violations={w['total_invariant_violations']}")]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke size")
    ap.add_argument("--n-sets", type=int, default=N_SETS)
    ap.add_argument("--scenario", choices=SCENARIOS + ("all",),
                    default="all", help="run one scenario row")
    ap.add_argument("--axis", choices=AXES + ("all",), default="all",
                    help="run one policy column")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    n_sets = min(args.n_sets, 3000) if args.quick else args.n_sets
    scenarios = (SCENARIOS if args.scenario == "all"
                 else (args.scenario,))
    axes = AXES if args.axis == "all" else (args.axis,)
    out = run_matrix(n_sets=n_sets, scenarios=scenarios, axes=axes,
                     seed=args.seed)
    from bench_io import write_bench_json
    if args.scenario == "all" and args.axis == "all":
        write_bench_json("torture", out)
    else:
        # sharded runs (the CI matrix) write under benchmarks/artifacts/
        # instead of littering the repo root with one file per shard
        art = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "artifacts")
        os.makedirs(art, exist_ok=True)
        name = f"torture_{args.scenario}_{args.axis}"
        write_bench_json(name, out,
                         path=os.path.join(art, f"BENCH_{name}.json"))
    print(json.dumps(out, indent=2, default=str))
    n_viol = out["worst_case"]["total_invariant_violations"]
    if n_viol:
        print(f"[torture] {n_viol} INVARIANT VIOLATIONS", file=sys.stderr)
        for key, cell in out["cells"].items():
            for v in cell["violations"]:
                print(f"[torture]   {key}: {v}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
