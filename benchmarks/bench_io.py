"""Benchmark-output persistence.

Every top-level bench run writes its JSON payload to
``BENCH_<name>.json`` at the repo root (in addition to stdout), so the
trajectory of headline numbers accumulates run over run instead of
scrolling away — the CI bench-smoke job uploads these files as
artifacts. Pass ``path`` to redirect, or delete the file freely: it is
an artifact, not a source file.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Optional


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write_bench_json(name: str, payload, *,
                     path: Optional[str] = None) -> str:
    """Write ``payload`` as ``BENCH_<name>.json`` at the repo root;
    returns the path (also echoed to stderr so stdout stays valid
    JSON for piping).

    The write is atomic (temp file in the same directory + rename): an
    interrupted bench run leaves either the previous artifact or the
    new one, never a truncated JSON that breaks the next CI compare.
    """
    out = path or os.path.join(repo_root(), f"BENCH_{name}.json")
    tmp = f"{out}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=2, default=str)
            f.write("\n")
        os.replace(tmp, out)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    print(f"[bench] wrote {out}", file=sys.stderr)
    return out
