"""Benchmark: paper §6.4 — lower sigma => more waste recovered."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import (SlabPolicy, sample_lognormal_sizes, size_histogram,
                        waste_exact)

SIGMAS = (5.0, 10.0, 20.0, 40.0, 80.0, 160.0)
MU = 1210.0


def run(n_items: int = 200_000) -> List[Tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)
    baseline = np.asarray([944, 1184, 1480, 1856, 2320])
    for sigma in SIGMAS:
        sizes = sample_lognormal_sizes(rng, n_items, MU, sigma)
        support, freqs = size_histogram(sizes)
        base = baseline.copy()
        base[-1] = max(base[-1], support.max())
        t0 = time.perf_counter()
        sched = SlabPolicy(seed=1).fit(support, freqs, k=len(base),
                                       baseline=base, method="dp")
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"sigma_{sigma:g}", dt,
                     f"recovered={sched.recovered_frac:.4f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")
