"""Offline serving bench: batched one-dispatch-per-tick decode vs the
legacy per-request loop over the same slab KV pool.

Twin ``OfflineHarness`` runs (``mode="batched"`` / ``mode="legacy"``)
replay the SAME open-loop workload — Poisson arrivals with log-normal
prompt/output lengths, or a tenant-tagged trace replayed through
``scenarios.trace.trace_requests`` — against identical pools. Per sweep
point the bench reports

* **throughput** — generated tokens per wall-second for each mode and
  the batched/legacy speedup (headline gate: batched >= legacy at
  batch >= 64; the legacy loop pays one jitted dispatch per active
  request per tick, the batched step pays ONE),
* **bit-parity** — generated token streams AND the decision
  fingerprint (ticks, completions, rejections, realloc copies/tokens,
  refits, admission denials) compared exactly; any mismatch fails the
  run,
* **dispatch accounting** — ``n_decode_dispatches <= ticks`` for the
  batched mode (the O(ticks) contract, CI-gated),

plus an **admission cell** — two tenant streams with out-of-phase
arrival peaks over a deliberately tight pool, static half-pool quotas
vs the forecast-driven ``token_quota_arbiter`` moving quota between
peaks — reporting rejected requests and p99 queue delay per policy,
and a **trace cell** — ``synthetic_trace_ops`` round-tripped through
``write_trace``/``parse_trace`` and replayed via ``trace_requests``
(key-hash downsampling preserved), with the same parity + dispatch
gates. ``--trace FILE`` replays a trace file you supply (e.g. one the
scenario torture suite wrote) instead of the synthetic stream.

``python benchmarks/serving_bench.py --quick`` is the CI smoke size:
it still asserts bit-parity, the dispatch bound, and the batch-64
throughput gate, exiting nonzero on any failure. Results go to
``BENCH_serving.json``; ``run()`` returns CSV rows for
``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.scenarios import (parse_trace, synthetic_trace_ops,
                             trace_requests, write_trace)
from repro.serving import (KVSlabPool, OfflineHarness, Request,
                           lognormal_request_workload, token_quota_arbiter)

CLASSES = (128, 256, 512, 1024)
POOL_TOKENS = 32768          # throughput cells: roomy, admission rare
SWEEP = (16, 64, 128)        # max_batch sweep points
QUICK_SWEEP = (16, 64)
N_REQUESTS = {False: 160, True: 72}       # keyed by quick
ARRIVAL_RATE = 4.0           # requests per tick (open loop)
SPEEDUP_AT = 64              # batched >= legacy from this batch size up

# admission cell: pool tight enough that one tenant's peak cannot fit
# in a static half-pool quota, so the arbiter has real work to do
ADM_POOL_TOKENS = 8192
ADM_PER_TENANT = 40
ADM_PHASE_GAP = 30.0         # ticks between the two tenants' peaks


def make_workload(n: int, seed: int) -> List[Request]:
    """Deterministic per seed — rebuilt fresh for every run because the
    harness mutates Request.decoded in place."""
    rng = np.random.default_rng(seed)
    return lognormal_request_workload(
        rng, n, prompt_mean=96.0, prompt_std=64.0,
        output_mean=10.0, output_std=5.0, arrival_rate=ARRIVAL_RATE)


def _fresh(mode: str, batch: int, *, pool_tokens: int = POOL_TOKENS,
           quotas: Optional[Dict[str, int]] = None,
           with_arbiter: bool = False) -> OfflineHarness:
    pool = KVSlabPool(pool_tokens, CLASSES)
    for name, q in (quotas or {}).items():
        pool.register_tenant(name, quota_tokens=q)
    arb = None
    if with_arbiter:
        arb = token_quota_arbiter(pool, unit_tokens=512,
                                  arbitrate_every=2)
    return OfflineHarness(pool, max_batch=batch, mode=mode, arbiter=arb)


def _warmup(batch: int) -> None:
    """Compile both modes' step functions at this batch size so the
    timed cells measure steady-state dispatch, not tracing."""
    for mode in ("batched", "legacy"):
        h = _fresh(mode, batch)
        h.run([Request(rid=0, prompt_len=8, output_len=2)], max_ticks=8)


def _side(res, wall: float) -> Dict:
    return {
        "wall_s": round(wall, 4),
        "tokens_per_s": round(res.generated_tokens / max(wall, 1e-9), 1),
        "generated_tokens": res.generated_tokens,
        "ticks": res.ticks,
        "decode_dispatches": res.n_decode_dispatches,
        "prefill_dispatches": res.n_prefill_dispatches,
        "completed": res.completed,
        "rejected": res.rejected,
        "realloc_copies": res.realloc_copies,
        "queue_delay_p50": round(res.queue_delay_p50, 3),
        "queue_delay_p99": round(res.queue_delay_p99, 3),
        "mean_waste_fraction": round(res.mean_waste_fraction, 4),
    }


def _twin_run(batch: int, workload_of, *, pool_tokens: int = POOL_TOKENS
              ) -> Dict:
    """Batched + legacy over identical workloads/pools; parity and the
    dispatch bound are computed here, throughput gates at the caller."""
    side: Dict[str, Dict] = {}
    results = {}
    for mode in ("batched", "legacy"):
        h = _fresh(mode, batch, pool_tokens=pool_tokens)
        wl = workload_of()
        t0 = time.perf_counter()
        res = h.run(wl)
        wall = time.perf_counter() - t0
        results[mode] = res
        side[mode] = _side(res, wall)
    ra, rb = results["batched"], results["legacy"]
    return {
        "batch": batch,
        "batched": side["batched"],
        "legacy": side["legacy"],
        "speedup": round(side["legacy"]["wall_s"]
                         / max(side["batched"]["wall_s"], 1e-9), 2),
        "decisions_match": ra.decisions() == rb.decisions(),
        "tokens_match": ra.tokens == rb.tokens,
        "dispatch_bound_ok": ra.n_decode_dispatches <= ra.ticks,
    }


def parity_cell(batch: int, n_requests: int, *, seed: int) -> Dict:
    _warmup(batch)
    cell = _twin_run(batch, lambda: make_workload(n_requests, seed))
    cell["n_requests"] = n_requests
    return cell


# -- admission: static quotas vs arbiter-managed -----------------------------

def admission_workload(seed: int) -> List[Request]:
    """Two tenant streams with out-of-phase peaks: tenant ``a`` arrives
    hot from tick 0, tenant ``b``'s identical burst lands
    ``ADM_PHASE_GAP`` ticks later — the KV analogue of the paper's
    phased multi-tenant traffic."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for k, (tenant, phase) in enumerate((("a", 0.0),
                                         ("b", ADM_PHASE_GAP))):
        prompts = np.clip(rng.lognormal(5.2, 0.5, ADM_PER_TENANT),
                          16, 1024).astype(int)
        outputs = np.clip(rng.lognormal(2.2, 0.5, ADM_PER_TENANT),
                          1, 64).astype(int)
        arrivals = phase + np.cumsum(
            rng.exponential(1.0 / ARRIVAL_RATE, ADM_PER_TENANT))
        for i in range(ADM_PER_TENANT):
            reqs.append(Request(rid=1000 * k + i,
                                prompt_len=int(prompts[i]),
                                output_len=int(outputs[i]),
                                arrival=float(arrivals[i]),
                                tenant=tenant))
    return reqs


def admission_cell(batch: int, *, seed: int) -> Dict:
    """Static half-pool quotas vs the token-quota arbiter over the same
    phased two-tenant stream (both batched mode): with static quotas a
    peaking tenant rejects against its half of the pool while the other
    half idles; the arbiter moves quota toward the observed peak."""
    quotas = {"a": ADM_POOL_TOKENS // 2, "b": ADM_POOL_TOKENS // 2}
    side: Dict[str, Dict] = {}
    for policy, with_arb in (("static", False), ("arbiter", True)):
        h = _fresh("batched", batch, pool_tokens=ADM_POOL_TOKENS,
                   quotas=quotas, with_arbiter=with_arb)
        t0 = time.perf_counter()
        res = h.run(admission_workload(seed))
        wall = time.perf_counter() - t0
        side[policy] = _side(res, wall)
        side[policy]["admission_denials"] = res.n_admission_denials
    return {
        "batch": batch,
        "pool_tokens": ADM_POOL_TOKENS,
        "quota_tokens": quotas,
        "n_requests": 2 * ADM_PER_TENANT,
        "static": side["static"],
        "arbiter": side["arbiter"],
        "rejected_delta": (side["arbiter"]["rejected"]
                          - side["static"]["rejected"]),
    }


# -- trace replay ------------------------------------------------------------

def trace_cell(batch: int, *, seed: int, keep: float = 1.0,
               trace_path: Optional[str] = None,
               max_requests: int = 64) -> Dict:
    """Replay a memcached-side trace through the serving harness.

    Default: ``synthetic_trace_ops`` round-tripped through
    ``write_trace``/``parse_trace`` (the same fixture path the scenario
    torture suite replays), converted by ``trace_requests`` — key-hash
    downsampling (``keep``) included so a thinned replay keeps exactly
    the keys the memcached-side replay kept. ``trace_path`` replays an
    existing trace file instead."""
    if trace_path is None:
        ops = synthetic_trace_ops("phased", n_ops=800, n_tenants=2,
                                  seed=seed)
        fd, path = tempfile.mkstemp(suffix=".trace")
        os.close(fd)
        try:
            write_trace(path, ops)
            ops = parse_trace(path)
        finally:
            os.unlink(path)
        source = "synthetic-roundtrip"
    else:
        ops = parse_trace(trace_path)
        source = trace_path
    reqs = trace_requests(ops, ops_per_tick=16.0, bytes_per_token=64,
                          output_max=8, keep=keep, seed=seed,
                          max_requests=max_requests)

    def replay() -> List[Request]:
        return [Request(rid=r.rid, prompt_len=r.prompt_len,
                        output_len=r.output_len, arrival=r.arrival,
                        tenant=r.tenant) for r in reqs]

    cell = _twin_run(batch, replay)
    cell.update(source=source, keep=keep, n_requests=len(reqs),
                n_tenants=len({r.tenant for r in reqs}))
    return cell


# -- driver ------------------------------------------------------------------

def run_sweep(sweep=SWEEP, *, quick: bool = False, seed: int = 7,
              trace: Optional[str] = None) -> Dict:
    n_requests = N_REQUESTS[quick]
    cells: Dict[str, Dict] = {}
    for b in sweep:
        t0 = time.perf_counter()
        cell = parity_cell(b, n_requests, seed=seed)
        cell["seconds"] = round(time.perf_counter() - t0, 3)
        cells[str(b)] = cell
    adm = admission_cell(max(sweep), seed=seed)
    trc = trace_cell(min(max(sweep), 64), seed=seed, trace_path=trace,
                     max_requests=48 if quick else 96)

    failures: List[str] = []
    for b, cell in list(cells.items()) + [("trace", trc)]:
        if not cell["decisions_match"]:
            failures.append(f"{b}: decision fingerprints diverge")
        if not cell["tokens_match"]:
            failures.append(f"{b}: generated token streams diverge")
        if not cell["dispatch_bound_ok"]:
            failures.append(
                f"{b}: {cell['batched']['decode_dispatches']} decode "
                f"dispatches > {cell['batched']['ticks']} ticks")
    for b, cell in cells.items():
        if int(b) >= SPEEDUP_AT and cell["speedup"] < 1.0:
            failures.append(
                f"{b}: batched {cell['batched']['tokens_per_s']:.0f} "
                f"tok/s < legacy {cell['legacy']['tokens_per_s']:.0f} "
                f"(speedup {cell['speedup']:.2f}x)")
    if adm["arbiter"]["rejected"] > adm["static"]["rejected"]:
        failures.append(
            f"admission: arbiter rejected {adm['arbiter']['rejected']} "
            f"> static {adm['static']['rejected']}")
    return {"classes": list(CLASSES), "pool_tokens": POOL_TOKENS,
            "sweep": list(sweep), "n_requests": n_requests,
            "arrival_rate": ARRIVAL_RATE, "quick": quick,
            "cells": cells, "admission_cell": adm, "trace_cell": trc,
            "failures": failures}


def run() -> List[Tuple[str, float, str]]:
    out = run_sweep(QUICK_SWEEP, quick=True)
    rows = []
    for b, cell in out["cells"].items():
        rows.append((
            f"b{b}", 1e6 * cell["batched"]["wall_s"],
            f"tok_s={cell['batched']['tokens_per_s']:.0f};"
            f"speedup={cell['speedup']:.2f}x;"
            f"parity={cell['decisions_match'] and cell['tokens_match']};"
            f"dispatches={cell['batched']['decode_dispatches']}/"
            f"{cell['batched']['ticks']}t"))
    adm = out["admission_cell"]
    rows.append(("admission", 0.0,
                 f"static_rej={adm['static']['rejected']};"
                 f"arbiter_rej={adm['arbiter']['rejected']};"
                 f"static_p99={adm['static']['queue_delay_p99']};"
                 f"arbiter_p99={adm['arbiter']['queue_delay_p99']}"))
    trc = out["trace_cell"]
    rows.append(("trace", 1e6 * trc["batched"]["wall_s"],
                 f"n={trc['n_requests']};"
                 f"parity={trc['decisions_match'] and trc['tokens_match']}"))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small sweep, parity + dispatch + "
                         "batch-64 throughput gates")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--trace", type=str, default=None,
                    help="replay this trace file through the harness "
                         "instead of the synthetic round-trip")
    ap.add_argument("--keep", type=float, default=1.0,
                    help="key-hash downsampling rate for the trace cell")
    args = ap.parse_args(argv)
    sweep = QUICK_SWEEP if args.quick else SWEEP
    out = run_sweep(sweep, quick=args.quick, seed=args.seed,
                    trace=args.trace)
    if args.keep != 1.0:
        out["trace_cell_downsampled"] = trace_cell(
            min(max(sweep), 64), seed=args.seed, keep=args.keep,
            trace_path=args.trace)
    from bench_io import write_bench_json
    write_bench_json("serving", out)
    print(json.dumps(out, indent=2, default=str))
    if out["failures"]:
        for f in out["failures"]:
            print(f"[serving] FAIL {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
