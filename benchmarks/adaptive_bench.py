"""Benchmark: the paper's loop, closed — adaptive slab control under drift.

Replays non-stationary item-size traffic (phase shift between two paper
operating points, gradual drift, diurnal mixture) through the memcached
simulator under three policies:

* ``default``  — memcached's stock 1.25-geometric schedule, never changed,
* ``static``   — the paper's learned schedule, fit once on the warmup
                 prefix and frozen (the repo's old offline-only story),
* ``adaptive`` — the same initial fit plus the online ``SlabController``
                 (decayed sketch -> drift detection -> cost-gated refit ->
                 live ``reconfigure`` with slabs-reassign semantics).

Learned schedules are deployed with the stock geometric tail above their
span (`schedule_with_default_tail`) — as a real memcached would — so a
shifted workload degrades into coarse default classes instead of being
rejected. Waste is charged per insert against the schedule active at that
moment (chunk - item, or a full page for unstorable items — the same
charging rule the optimizers use), so the trajectory reflects when each
policy adapted, not just where it ended.

``python benchmarks/adaptive_bench.py`` emits the full comparison,
trajectories included, as JSON.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (PAGE_SIZE, ControllerConfig, SlabController,
                        SlabPolicy, default_memcached_schedule,
                        schedule_with_default_tail, size_histogram)
from repro.core.distribution import PAPER_WORKLOADS
from repro.memcached import (SlabAllocator, diurnal_traffic, drift_traffic,
                             phase_shift_traffic)

K = 6                  # learned class budget (paper's Table-1 shape)
WARMUP_FRAC = 0.1      # prefix the static/adaptive schedules are fit on


def _controller(chunks, n_items: int) -> SlabController:
    cadence = max(1000, n_items // 40)
    return SlabController(chunks, config=ControllerConfig(
        k=K, check_every=cadence, half_life=2.0 * cadence,
        drift_threshold=0.12, min_items_between_refits=2 * cadence,
        min_rel_improvement=0.02,
        # phase-shifted cache traffic: evicted victims hold the stale
        # distribution and are rarely re-referenced, so a migration
        # byte costs far less than a recurring waste byte
        amortization_windows=8.0, cost_weight=0.1))


def drive(sizes: np.ndarray, chunks, *,
          controller: Optional[SlabController] = None,
          n_checkpoints: int = 60,
          page_size: int = PAGE_SIZE) -> Dict:
    """Replay ``sizes`` through a live allocator, charging waste against
    the schedule active at each insert; optionally run the controller."""
    alloc = SlabAllocator(chunks, page_size=page_size)
    csizes = alloc.chunk_sizes
    cum_waste = 0
    cum_bytes = 0
    every = max(1, len(sizes) // n_checkpoints)
    trajectory: List[Dict] = []
    refit_events: List[Dict] = []
    for i, s in enumerate(np.asarray(sizes).tolist()):
        s = int(s)
        idx = int(np.searchsorted(csizes, s, side="left"))
        cum_waste += (int(csizes[idx]) - s if idx < len(csizes)
                      else page_size - s)
        cum_bytes += s
        alloc.set(str(i), s)
        if controller is not None:
            controller.observe(s)
            decision = controller.maybe_refit(
                cost_bytes_fn=lambda c: alloc.migration_cost_bytes(
                    schedule_with_default_tail(c, page_size=page_size)))
            if decision is not None and decision.approved:
                deployed = schedule_with_default_tail(decision.chunks,
                                                      page_size=page_size)
                report = alloc.reconfigure(deployed)
                controller.set_chunks(deployed)   # controller sees what's live
                csizes = alloc.chunk_sizes
                refit_events.append({
                    "at_item": i, "drift": round(decision.drift, 4),
                    "classes": decision.chunks.tolist(),
                    "evicted_items": report.evicted_items,
                    "evicted_bytes": report.evicted_bytes,
                    "reassigned_pages": report.reassigned_pages})
        if (i + 1) % every == 0 or i + 1 == len(sizes):
            trajectory.append({
                "item": i + 1,
                "cum_waste_frac": round(cum_waste / max(cum_bytes, 1), 6)})
    st = alloc.stats()
    return {
        "cum_waste_bytes": int(cum_waste),
        "cum_item_bytes": int(cum_bytes),
        "cum_waste_frac": cum_waste / max(cum_bytes, 1),
        "final_resident_waste_frac": st.waste_fraction,
        "n_rejected": st.n_rejected,
        "n_reassigned_pages": st.n_reassigned_pages,
        "migration_evictions": st.migration_evictions,
        "n_refits": len(refit_events),
        "refit_events": refit_events,
        "trajectory": trajectory,
    }


def compare(sizes: np.ndarray, *, page_size: int = PAGE_SIZE
            ) -> Dict[str, Dict]:
    """default-static vs learned-static vs adaptive on one size stream."""
    warmup = sizes[:max(1, int(len(sizes) * WARMUP_FRAC))]
    support, freqs = size_histogram(warmup)
    fit = SlabPolicy(page_size=page_size).fit(support, freqs, K,
                                              method="dp")
    learned = schedule_with_default_tail(fit.chunk_sizes,
                                         page_size=page_size)
    out = {
        "default": drive(sizes, default_memcached_schedule(
            page_size=page_size), page_size=page_size),
        "static": drive(sizes, learned, page_size=page_size),
        # the controller's current-schedule view must match what is
        # deployed (the tailed schedule), or its waste comparisons
        # page-charge items the allocator actually stores in the tail
        "adaptive": drive(sizes, learned,
                          controller=_controller(learned, len(sizes)),
                          page_size=page_size),
    }
    for cfg in out.values():
        del cfg["trajectory"][:-1]   # CSV rows don't need the curve
    return out


def scenarios(n_items: int) -> List[Tuple[str, np.ndarray]]:
    a, b = PAPER_WORKLOADS[0], PAPER_WORKLOADS[2]
    return [
        ("phase_shift", phase_shift_traffic(a, b, n_items=n_items, seed=7)),
        ("gradual_drift", drift_traffic(a, b, n_items=n_items, seed=7)),
        ("diurnal", diurnal_traffic(a, b, n_items=n_items,
                                    period=n_items // 2, seed=7)),
    ]


def run(n_items: int = 60_000) -> List[Tuple[str, float, str]]:
    rows = []
    for scenario, sizes in scenarios(n_items):
        t0 = time.perf_counter()
        res = compare(sizes)
        dt = (time.perf_counter() - t0) * 1e6 / (3 * n_items)
        rows.append((
            scenario, dt,
            f"default={res['default']['cum_waste_frac']:.4f};"
            f"static={res['static']['cum_waste_frac']:.4f};"
            f"adaptive={res['adaptive']['cum_waste_frac']:.4f};"
            f"refits={res['adaptive']['n_refits']};"
            f"migration_evictions="
            f"{res['adaptive']['migration_evictions']}"))
    return rows


def main(n_items: int = 120_000) -> Dict:
    """Full comparison with trajectories, as JSON on stdout."""
    out = {"n_items": n_items, "k": K, "warmup_frac": WARMUP_FRAC,
           "scenarios": {}}
    for scenario, sizes in scenarios(n_items):
        warmup = sizes[:max(1, int(len(sizes) * WARMUP_FRAC))]
        support, freqs = size_histogram(warmup)
        fit = SlabPolicy().fit(support, freqs, K, method="dp")
        learned = schedule_with_default_tail(fit.chunk_sizes)
        out["scenarios"][scenario] = {
            "default": drive(sizes, default_memcached_schedule()),
            "static": drive(sizes, learned),
            "adaptive": drive(sizes, learned,
                              controller=_controller(learned, len(sizes))),
        }
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
