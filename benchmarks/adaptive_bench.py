"""Benchmark: the paper's loop, closed — adaptive slab control under drift.

Replays non-stationary item-size traffic (phase shift between two paper
operating points, gradual drift, diurnal mixture) through the memcached
simulator under three policies:

* ``default``  — memcached's stock 1.25-geometric schedule, never changed,
* ``static``   — the paper's learned schedule, fit once on the warmup
                 prefix and frozen (the repo's old offline-only story),
* ``adaptive`` — the same initial fit plus the online ``SlabController``
                 (decayed sketch -> drift detection -> cost-gated refit ->
                 live ``reconfigure`` with slabs-reassign semantics).

Learned schedules are deployed with the stock geometric tail above their
span (`schedule_with_default_tail`) — as a real memcached would — so a
shifted workload degrades into coarse default classes instead of being
rejected. Waste is charged per insert against the schedule active at that
moment (chunk - item, or a full page for unstorable items — the same
charging rule the optimizers use), so the trajectory reflects when each
policy adapted, not just where it ended.

A second axis (``--policy``): the same adaptive loop under each
eviction policy (``repro.memcached.eviction``) on single-tenant
``zipfian_rereference`` traffic — Zipf re-references with a mid-stream
tail shift, replayed through a memory-limited allocator with
read-through refills. The wholesale (``coldest``) cost model charges
the full payload of the stale phase-one tail and vetoes refits toward
the new tail sizes; the cost-aware policies price those dead residents
near zero, approve the refits, and keep the referenced working set
resident (measured as the referenced-payload hole fraction, see
``SlabAllocator.referenced_bytes``).

``python benchmarks/adaptive_bench.py`` emits the full comparison,
trajectories included, as JSON; ``--policy ranked`` (or ``all``) runs
the eviction-policy axis; ``--quick`` is the CI smoke size.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import (PAGE_SIZE, ControllerConfig, SlabController,
                        SlabPolicy, default_memcached_schedule,
                        schedule_with_default_tail, size_histogram,
                        uncovered_charge)
from repro.core.distribution import PAPER_WORKLOADS
from repro.memcached import (SlabAllocator, diurnal_traffic, drift_traffic,
                             make_policy, phase_shift_traffic,
                             zipfian_rereference_ops)

K = 6                  # learned class budget (paper's Table-1 shape)
WARMUP_FRAC = 0.1      # prefix the static/adaptive schedules are fit on
POLICIES = ("coldest", "segmented", "ranked")


def charge_waste(chunk_sizes, size: int, page_size: int) -> int:
    """The insert-charging rule every driver here shares: chunk - item
    for storable sizes, ceil(size/page) whole pages for unstorable ones
    (the same rule the optimizers score with — never negative, even for
    items larger than a page)."""
    idx = int(np.searchsorted(chunk_sizes, size, side="left"))
    if idx < len(chunk_sizes):
        return int(chunk_sizes[idx]) - size
    return int(uncovered_charge(size, page_size=page_size))


def _controller(chunks, n_items: int) -> SlabController:
    cadence = max(1000, n_items // 40)
    return SlabController(chunks, config=ControllerConfig(
        k=K, check_every=cadence, half_life=2.0 * cadence,
        drift_threshold=0.12, min_items_between_refits=2 * cadence,
        min_rel_improvement=0.02,
        # phase-shifted cache traffic: evicted victims hold the stale
        # distribution and are rarely re-referenced, so a migration
        # byte costs far less than a recurring waste byte
        amortization_windows=8.0, cost_weight=0.1))


def drive(sizes: np.ndarray, chunks, *,
          controller: Optional[SlabController] = None,
          n_checkpoints: int = 60,
          page_size: int = PAGE_SIZE) -> Dict:
    """Replay ``sizes`` through a live allocator, charging waste against
    the schedule active at each insert; optionally run the controller."""
    alloc = SlabAllocator(chunks, page_size=page_size)
    csizes = alloc.chunk_sizes
    cum_waste = 0
    cum_bytes = 0
    every = max(1, len(sizes) // n_checkpoints)
    trajectory: List[Dict] = []
    refit_events: List[Dict] = []
    for i, s in enumerate(np.asarray(sizes).tolist()):
        s = int(s)
        cum_waste += charge_waste(csizes, s, page_size)
        cum_bytes += s
        alloc.set(str(i), s)
        if controller is not None:
            controller.observe(s)
            decision = controller.maybe_refit(
                cost_bytes_fn=lambda c: alloc.migration_cost_bytes(
                    schedule_with_default_tail(c, page_size=page_size)))
            if decision is not None and decision.approved:
                deployed = schedule_with_default_tail(decision.chunks,
                                                      page_size=page_size)
                report = alloc.reconfigure(deployed)
                controller.set_chunks(deployed)   # controller sees what's live
                csizes = alloc.chunk_sizes
                refit_events.append({
                    "at_item": i, "drift": round(decision.drift, 4),
                    "classes": decision.chunks.tolist(),
                    "evicted_items": report.evicted_items,
                    "evicted_bytes": report.evicted_bytes,
                    "reassigned_pages": report.reassigned_pages})
        if (i + 1) % every == 0 or i + 1 == len(sizes):
            trajectory.append({
                "item": i + 1,
                "cum_waste_frac": round(cum_waste / max(cum_bytes, 1), 6)})
    st = alloc.stats()
    return {
        "cum_waste_bytes": int(cum_waste),
        "cum_item_bytes": int(cum_bytes),
        "cum_waste_frac": cum_waste / max(cum_bytes, 1),
        "final_resident_waste_frac": st.waste_fraction,
        "n_rejected": st.n_rejected,
        "n_reassigned_pages": st.n_reassigned_pages,
        "migration_evictions": st.migration_evictions,
        "n_refits": len(refit_events),
        "refit_events": refit_events,
        "trajectory": trajectory,
    }


def compare(sizes: np.ndarray, *, page_size: int = PAGE_SIZE
            ) -> Dict[str, Dict]:
    """default-static vs learned-static vs adaptive on one size stream."""
    warmup = sizes[:max(1, int(len(sizes) * WARMUP_FRAC))]
    support, freqs = size_histogram(warmup)
    fit = SlabPolicy(page_size=page_size).fit(support, freqs, K,
                                              method="dp")
    learned = schedule_with_default_tail(fit.chunk_sizes,
                                         page_size=page_size)
    out = {
        "default": drive(sizes, default_memcached_schedule(
            page_size=page_size), page_size=page_size),
        "static": drive(sizes, learned, page_size=page_size),
        # the controller's current-schedule view must match what is
        # deployed (the tailed schedule), or its waste comparisons
        # page-charge items the allocator actually stores in the tail
        "adaptive": drive(sizes, learned,
                          controller=_controller(learned, len(sizes)),
                          page_size=page_size),
    }
    for cfg in out.values():
        del cfg["trajectory"][:-1]   # CSV rows don't need the curve
    return out


def drive_ops(ops, chunks, *, policy: str = "coldest",
              controller: Optional[SlabController] = None,
              mem_pages: int = 24, page_size: int = PAGE_SIZE,
              liveness_window: int = 2000,
              sample_every: int = 250) -> Dict:
    """Replay a get/set op stream (read-through refills on misses)
    through a memory-limited allocator under one eviction policy,
    optionally running the adaptive controller. Holes are measured
    against *referenced* payload (``SlabAllocator.referenced_bytes``)
    so hoarded dead bytes count as holes — see multitenant_bench."""
    alloc = SlabAllocator(chunks, page_size=page_size,
                          mem_limit=mem_pages * page_size,
                          eviction_policy=make_policy(policy))
    pool_bytes = mem_pages * page_size
    hole_fracs: List[float] = []
    n_miss = 0
    since = 0
    cum_waste = 0
    cum_bytes = 0

    def store(key: str, size: int) -> None:
        nonlocal cum_waste, cum_bytes
        cum_waste += charge_waste(alloc.chunk_sizes, size, page_size)
        cum_bytes += size
        alloc.set(key, size)
        if controller is not None:
            controller.observe(size)
            decision = controller.maybe_refit(
                cost_bytes_fn=lambda c: alloc.migration_cost_bytes(
                    schedule_with_default_tail(c, page_size=page_size)))
            if decision is not None and decision.approved:
                deployed = schedule_with_default_tail(decision.chunks,
                                                      page_size=page_size)
                alloc.reconfigure(deployed)
                controller.set_chunks(deployed)

    for op in ops:
        if op.op == "get":
            if not alloc.get(op.key):
                n_miss += 1
                store(op.key, op.size)      # read-through refill
        else:
            store(op.key, op.size)
        since += 1
        if since >= sample_every:
            since = 0
            hole_fracs.append(
                (pool_bytes - alloc.referenced_bytes(liveness_window))
                / pool_bytes)
    st = alloc.stats()
    return {
        "policy": policy,
        "cum_waste_frac": cum_waste / max(cum_bytes, 1),
        "mean_hole_frac": sum(hole_fracs) / max(len(hole_fracs), 1),
        "n_miss": n_miss,
        "n_evicted": st.n_evicted,
        "migration_evictions": st.migration_evictions,
        "evicted_hot_bytes": st.evicted_hot_bytes,
        "reused_after_evict": st.reused_after_evict,
        "n_refits": 0 if controller is None else controller.n_refits,
    }


def policy_axis(n_ops: int = 60_000, *,
                policies: Tuple[str, ...] = POLICIES,
                seed: int = 7) -> Dict[str, Dict]:
    """default vs segmented vs ranked on single-tenant Zipf
    re-reference traffic with a mid-stream tail shift, adaptive
    controller running (cost_weight=1.0: the wholesale model must veto
    on its own honesty, not a hand-tuned discount).

    The tail shift is deliberately *mild* (mean size x1.4): savings do
    not swamp the migration cost, so the refit decision comes down to
    how honestly the eviction policy prices the stale phase-one tail —
    the wholesale model vetoes (``cost-exceeds-savings``), the
    cost-aware models approve and the cumulative insert-charged waste
    drops. The headline here is ``cum_waste_frac``; the multitenant
    bench owns the hole-fraction story."""
    import dataclasses as _dc
    a = PAPER_WORKLOADS[0]
    alt = [_dc.replace(a, mu=a.mu * 1.4)]
    ops = zipfian_rereference_ops([a], n_ops=n_ops, shift_at=0.4,
                                  alt_workloads=alt, seed=seed)
    page = 1 << 16                     # 64 KiB pages (multitenant_bench's
    #                                    arbitration quantum): items are
    #                                    0.5-8 KiB, pressure is the point
    mem_pages = max(12, n_ops // 350)  # ~1/3 of the Zipf working set
    cadence = max(500, n_ops // 40)
    out = {}
    for p in policies:
        chunks = default_memcached_schedule(page_size=page)
        ctl = SlabController(chunks, config=ControllerConfig(
            k=K, page_size=page, check_every=cadence,
            half_life=2.0 * cadence, drift_threshold=0.12,
            min_items_between_refits=2 * cadence,
            amortization_windows=8.0, cost_weight=1.0))
        out[p] = drive_ops(ops, chunks, policy=p, controller=ctl,
                           mem_pages=mem_pages, page_size=page)
    return out


def scenarios(n_items: int) -> List[Tuple[str, np.ndarray]]:
    a, b = PAPER_WORKLOADS[0], PAPER_WORKLOADS[2]
    return [
        ("phase_shift", phase_shift_traffic(a, b, n_items=n_items, seed=7)),
        ("gradual_drift", drift_traffic(a, b, n_items=n_items, seed=7)),
        ("diurnal", diurnal_traffic(a, b, n_items=n_items,
                                    period=n_items // 2, seed=7)),
    ]


def run(n_items: int = 60_000) -> List[Tuple[str, float, str]]:
    rows = []
    for scenario, sizes in scenarios(n_items):
        t0 = time.perf_counter()
        res = compare(sizes)
        dt = (time.perf_counter() - t0) * 1e6 / (3 * n_items)
        rows.append((
            scenario, dt,
            f"default={res['default']['cum_waste_frac']:.4f};"
            f"static={res['static']['cum_waste_frac']:.4f};"
            f"adaptive={res['adaptive']['cum_waste_frac']:.4f};"
            f"refits={res['adaptive']['n_refits']};"
            f"migration_evictions="
            f"{res['adaptive']['migration_evictions']}"))
    t0 = time.perf_counter()
    pol = policy_axis(n_items, policies=("coldest", "ranked"))
    dt = (time.perf_counter() - t0) * 1e6 / (2 * n_items)
    rows.append((
        "zipfian_rereference_policy_axis", dt,
        f"waste_coldest={pol['coldest']['cum_waste_frac']:.4f};"
        f"waste_ranked={pol['ranked']['cum_waste_frac']:.4f};"
        f"refits_coldest={pol['coldest']['n_refits']};"
        f"refits_ranked={pol['ranked']['n_refits']};"
        f"reused_after_evict_ranked={pol['ranked']['reused_after_evict']}"))
    return rows


def main(n_items: int = 120_000) -> Dict:
    """Full comparison with trajectories, as JSON on stdout."""
    out = {"n_items": n_items, "k": K, "warmup_frac": WARMUP_FRAC,
           "scenarios": {}}
    for scenario, sizes in scenarios(n_items):
        warmup = sizes[:max(1, int(len(sizes) * WARMUP_FRAC))]
        support, freqs = size_histogram(warmup)
        fit = SlabPolicy().fit(support, freqs, K, method="dp")
        learned = schedule_with_default_tail(fit.chunk_sizes)
        out["scenarios"][scenario] = {
            "default": drive(sizes, default_memcached_schedule()),
            "static": drive(sizes, learned),
            "adaptive": drive(sizes, learned,
                              controller=_controller(learned, len(sizes))),
        }
    return out


if __name__ == "__main__":
    from bench_io import write_bench_json
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--policy", choices=POLICIES + ("all",), default=None,
                    help="run the eviction-policy axis instead of the "
                         "default/static/adaptive comparison")
    ap.add_argument("--device-observe", action="store_true",
                    help="host vs device observe path: same refit "
                         "decisions, host syncs counted per refit window")
    ap.add_argument("--forecast", action="store_true",
                    help="reactive vs predictive refits on the diurnal "
                         "workload (forecast_bench's controller axis)")
    ap.add_argument("--n-items", type=int, default=120_000)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke size (covers both axes)")
    args = ap.parse_args()
    if args.device_observe:
        from observe_bench import sync_axis
        n = min(args.n_items, 20_000) if args.quick else args.n_items
        out = sync_axis(n)
        # axis-specific artifact: never clobber the headline
        # mode-comparison trajectory with a different schema
        write_bench_json("adaptive_sync", out)
        print(json.dumps(out, indent=2))
        raise SystemExit(0)
    if args.forecast:
        from forecast_bench import controller_axis
        n = min(args.n_items, 24_000) if args.quick else args.n_items
        out = controller_axis(n)
        write_bench_json("adaptive_forecast", out)
        print(json.dumps(out, indent=2))
        raise SystemExit(0)
    if args.quick:
        n = min(args.n_items, 6000)
        full = main(n)
        out = {"scenarios": {s: {m: full["scenarios"][s][m]["cum_waste_frac"]
                                 for m in ("default", "static", "adaptive")}
                             for s in full["scenarios"]},
               "policy_axis": {p: {"cum_waste_frac":
                                   round(r["cum_waste_frac"], 4),
                                   "n_refits": r["n_refits"]}
                               for p, r in policy_axis(n).items()}}
    elif args.policy is not None:
        policies = POLICIES if args.policy == "all" else tuple(
            dict.fromkeys(("coldest", args.policy)))
        out = policy_axis(args.n_items, policies=policies)
    else:
        out = main(args.n_items)
    write_bench_json("adaptive", out)
    print(json.dumps(out, indent=2))
