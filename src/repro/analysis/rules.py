"""The pluggable rule registry and the five core slablint rules.

A rule is a callable ``run(project) -> list[Finding]`` registered via
``@rule``. Adding a rule means writing one function; the CLI, baseline
and JSON plumbing pick it up automatically.

Precision notes (shared by HS001/RT001, which use the taint engine):

* Taint is intra-procedural and flow-insensitive across branches but
  forward in program order (loop bodies get two passes so taint
  introduced late in a body reaches sinks earlier in it).
* Sources: calls rooted at ``jnp``/``jax``/``lax``, calls to the
  curated device-producing surface (:data:`DEVICE_FNS`), calls to any
  function the project knows is jax.jit-wrapped, method calls on
  tainted values, and the device-buffer attributes
  (:data:`TAINTED_ATTRS`). Function *parameters* are not tainted — a
  deliberate precision tradeoff documented in docs/static_analysis.md.
* Sinks lexically inside ``with deliberate_sync(...):`` are skipped:
  the static view and the runtime guard (:mod:`repro.analysis.guards`)
  agree on what a deliberate sync is.
"""
from __future__ import annotations

import ast
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.analysis.callgraph import (FunctionInfo, ModuleInfo, Project,
                                      _dotted, is_jit_expr)
from repro.analysis.findings import Finding

RULES: Dict[str, dict] = {}


def rule(rule_id: str, name: str, hint: str) -> Callable:
    def register(fn: Callable) -> Callable:
        RULES[rule_id] = {"id": rule_id, "name": name, "hint": hint,
                          "run": fn}
        return fn
    return register


def run_rules(project: Project,
              only: Optional[Set[str]] = None) -> List[Finding]:
    out: List[Finding] = []
    for rid, r in sorted(RULES.items()):
        if only and rid not in only:
            continue
        out.extend(r["run"](project))
    out.sort(key=lambda f: (f.path, f.line, f.rule_id))
    return out


# ---------------------------------------------------------------------------
# Taint engine (HS001 + RT001c share it)
# ---------------------------------------------------------------------------

# Curated device-producing call surface: the dispatch-discipline APIs
# whose results live in accelerator memory.
DEVICE_FNS = {
    "histogram_distance_device", "_dense_distance", "drift_gate_fleet",
    "waste_eval", "waste_eval_fleet", "waste_eval_pallas",
    "waste_eval_fleet_pallas", "waste_eval_ref", "waste_eval_fleet_ref",
    "sketch_update", "sketch_update_pallas", "sketch_update_ref",
    "sketch_window_pallas", "sketch_window_ref", "flush_window",
    "observe_window", "slab_decode_attention",
    "slab_decode_attention_pallas", "slab_decode_attention_ref",
    "waste_jax", "waste_batch_jax",
}
DEVICE_ROOTS = {"jnp", "jax", "lax", "_jnp"}
TAINTED_ATTRS = {"weights_device", "support_device", "_weights"}
SHAPE_FNS = {"zeros", "ones", "full", "empty", "arange", "tile",
             "repeat", "broadcast_to", "reshape", "eye", "linspace"}
HOST_CASTS = {"float", "int", "bool"}
ITEM_SINKS = {"item", "tolist"}


def _call_root(func: ast.AST) -> Optional[str]:
    d = _dotted(func)
    return d.split(".")[0] if d else None


def _bare_callee(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


class TaintWalk:
    """One pass over a function body: tainted names, sinks, shape
    hazards. ``sinks`` entries are ``(node, symbol, tainted_ok)``."""

    def __init__(self, mod: ModuleInfo, jitted: Set[str]):
        self.mod = mod
        self.jitted = jitted
        self.tainted: Set[str] = set()
        self.host_derived: Set[str] = set()   # int(x)/float(x) of tainted
        self.sinks: List[Tuple[ast.AST, str]] = []
        self.shape_hazards: List[Tuple[ast.AST, str]] = []
        self.allow = 0                        # deliberate_sync depth
        self._seen_sinks: Set[int] = set()    # loop bodies scan twice

    # -- expression taint -------------------------------------------------
    def is_tainted(self, e: ast.AST) -> bool:
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in TAINTED_ATTRS:
                return True
            return self.is_tainted(e.value)
        if isinstance(e, ast.Subscript):
            return self.is_tainted(e.value)
        if isinstance(e, (ast.BinOp,)):
            return self.is_tainted(e.left) or self.is_tainted(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.is_tainted(e.operand)
        if isinstance(e, ast.Compare):
            return self.is_tainted(e.left) or any(
                self.is_tainted(c) for c in e.comparators)
        if isinstance(e, (ast.Tuple, ast.List)):
            return any(self.is_tainted(x) for x in e.elts)
        if isinstance(e, ast.IfExp):
            return self.is_tainted(e.body) or self.is_tainted(e.orelse)
        if isinstance(e, ast.Starred):
            return self.is_tainted(e.value)
        if isinstance(e, ast.Call):
            return self.call_produces_device(e)
        return False

    def call_produces_device(self, call: ast.Call) -> bool:
        root = _call_root(call.func)
        if root in DEVICE_ROOTS and not self._is_device_get(call.func):
            return True
        name = _bare_callee(call.func)
        if name in DEVICE_FNS or (name in self.jitted):
            return True
        # curried transforms: jax.vmap(f)(x), jit(f)(x) — the outer
        # call's result is device-valued iff the inner factory is
        if isinstance(call.func, ast.Call):
            return self.call_produces_device(call.func)
        # method on a tainted value stays tainted (x.sum(), x.astype())
        if isinstance(call.func, ast.Attribute) and self.is_tainted(
                call.func.value):
            return name not in ITEM_SINKS
        return False

    @staticmethod
    def _is_device_get(func: ast.AST) -> bool:
        d = _dotted(func)
        return bool(d and d.split(".")[-1] == "device_get")

    # -- sinks ------------------------------------------------------------
    def _check_call(self, call: ast.Call) -> None:
        name = _bare_callee(call.func)
        root = _call_root(call.func)
        args_tainted = any(self.is_tainted(a) for a in call.args)
        if isinstance(call.func, ast.Name) and name in HOST_CASTS \
                and args_tainted:
            self._sink(call, name)
        elif isinstance(call.func, ast.Attribute) \
                and name in ITEM_SINKS \
                and self.is_tainted(call.func.value):
            self._sink(call, name)
        elif root == "np" and name in ("asarray", "array") and args_tainted:
            self._sink(call, f"np.{name}")
        elif self._is_device_get(call.func) and args_tainted:
            self._sink(call, "device_get")
        elif root in DEVICE_ROOTS and name in SHAPE_FNS:
            for a in list(call.args) + [k.value for k in call.keywords]:
                if any(isinstance(n, ast.Name)
                       and n.id in self.host_derived
                       for n in ast.walk(a)):
                    if id(call) not in self._seen_sinks:
                        self._seen_sinks.add(id(call))
                        self.shape_hazards.append((call, name))
                    break

    def _sink(self, node: ast.AST, symbol: str) -> None:
        if not self.allow and id(node) not in self._seen_sinks:
            self._seen_sinks.add(id(node))
            self.sinks.append((node, symbol))

    def _scan_exprs(self, stmt: ast.stmt) -> None:
        """Sink-check every call in ``stmt`` that is not inside a nested
        function definition (those are separate functions)."""
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node is not stmt:
                continue
            if isinstance(node, ast.Call):
                self._check_call(node)

    # -- statements -------------------------------------------------------
    def _assign_target(self, target: ast.AST, tainted: bool,
                       host: bool) -> None:
        if isinstance(target, ast.Name):
            (self.tainted.add if tainted else
             self.tainted.discard)(target.id)
            if host:
                self.host_derived.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._assign_target(el, tainted, host)

    def _value_is_host_cast(self, value: ast.AST) -> bool:
        return (isinstance(value, ast.Call)
                and isinstance(value.func, ast.Name)
                and value.func.id in HOST_CASTS
                and any(self.is_tainted(a) for a in value.args))

    def run(self, body: List[ast.stmt]) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, ast.With):
            deliberate = any(
                isinstance(item.context_expr, ast.Call)
                and (_dotted(item.context_expr.func) or "").split(".")[-1]
                == "deliberate_sync"
                for item in stmt.items)
            if deliberate:
                self.allow += 1
            for s in stmt.body:
                self._stmt(s)
            if deliberate:
                self.allow -= 1
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_exprs(ast.Expr(stmt.iter))
            if self.is_tainted(stmt.iter):
                self._assign_target(stmt.target, True, False)
            for _ in range(2):            # crude fixpoint for carried taint
                for s in stmt.body:
                    self._stmt(s)
            for s in stmt.orelse:
                self._stmt(s)
            return
        if isinstance(stmt, ast.While):
            self._scan_exprs(ast.Expr(stmt.test))
            for _ in range(2):
                for s in stmt.body:
                    self._stmt(s)
            for s in stmt.orelse:
                self._stmt(s)
            return
        if isinstance(stmt, (ast.If,)):
            self._scan_exprs(ast.Expr(stmt.test))
            for s in stmt.body + stmt.orelse:
                self._stmt(s)
            return
        if isinstance(stmt, ast.Try):
            for s in (stmt.body + stmt.orelse + stmt.finalbody
                      + [h for hh in stmt.handlers for h in hh.body]):
                self._stmt(s)
            return
        # leaf statements: sink-check, then propagate assignment taint
        self._scan_exprs(stmt)
        if isinstance(stmt, ast.Assign):
            t = self.is_tainted(stmt.value)
            h = self._value_is_host_cast(stmt.value)
            for target in stmt.targets:
                self._assign_target(target, t, h)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._assign_target(stmt.target, self.is_tainted(stmt.value),
                                self._value_is_host_cast(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            if self.is_tainted(stmt.value):
                self._assign_target(stmt.target, True, False)


def _taint_function(fn: FunctionInfo, project: Project,
                    jitted: Set[str]) -> TaintWalk:
    walk = TaintWalk(project.modules[fn.path], jitted)
    walk.run(fn.node.body)
    return walk


# ---------------------------------------------------------------------------
# HS001 — host sync in hot path
# ---------------------------------------------------------------------------

@rule("HS001", "host-sync-in-hot-path",
      "wrap a deliberate cadence-boundary readback in "
      "`with deliberate_sync(...):` (repro.analysis.guards), or move the "
      "scalar pull off the hot path")
def host_sync_in_hot_path(project: Project) -> List[Finding]:
    out: List[Finding] = []
    hot = project.hot_reachable()
    for key in sorted(hot):
        fn = project.functions[key]
        walk = _taint_function(fn, project,
                               project.jitted_names(fn.path))
        for node, symbol in walk.sinks:
            out.append(Finding(
                rule_id="HS001", path=fn.path, line=node.lineno,
                qualname=fn.qualname, symbol=symbol,
                message=(f"`{symbol}` materialises a traced/device value "
                         f"on host inside hot path `{fn.qualname}`"),
                hint=RULES["HS001"]["hint"]))
    return out


# ---------------------------------------------------------------------------
# DN001 — donation
# ---------------------------------------------------------------------------

# First-positional-parameter names that denote a large carried device
# buffer: jitting such a function without donation doubles its live
# footprint and forces a copy per dispatch.
CARRY_PARAMS = {"state", "carry", "buf", "buffers", "sketch", "fleet"}


def _first_param(node) -> Optional[str]:
    args = node.args
    pos = list(args.posonlyargs) + list(args.args)
    if pos and pos[0].arg in ("self", "cls"):
        pos = pos[1:]
    return pos[0].arg if pos else None


@rule("DN001", "undonated-carry-buffer",
      "pass donate_argnums=(0,) to jax.jit (or baseline it if every "
      "caller genuinely retains the input buffer)")
def donation(project: Project) -> List[Finding]:
    out: List[Finding] = []

    def check(fn_node, mod: ModuleInfo, qual: str, donates: bool) -> None:
        first = _first_param(fn_node)
        if donates or first not in CARRY_PARAMS:
            return
        out.append(Finding(
            rule_id="DN001", path=mod.path, line=fn_node.lineno,
            qualname=qual, symbol=getattr(fn_node, "name", "<lambda>"),
            message=(f"jax.jit of `{qual}` carries buffer param "
                     f"`{first}` without donate_argnums"),
            hint=RULES["DN001"]["hint"]))

    for fn in project.functions.values():
        if fn.jitted:
            check(fn.node, project.modules[fn.path], fn.qualname,
                  fn.jit_donates)
    # call-form: jax.jit(local_fn, ...) / jax.jit(lambda: ...)
    for mod in project.modules.values():
        local_defs = {f.name: f for f in mod.functions}
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            j, donates, call = is_jit_expr(node, mod.aliases)
            if not j or call is not node or not node.args:
                continue
            wrapped = node.args[0]
            if isinstance(wrapped, ast.Name) and wrapped.id in local_defs:
                f = local_defs[wrapped.id]
                if f.jitted:       # decorator form already checked
                    continue
                check(f.node, mod, f.qualname, donates)
            elif isinstance(wrapped, ast.Lambda):
                check(wrapped, mod, "<lambda>", donates)
    # de-dup (a def can be reached via decorator and call form)
    seen: Set[str] = set()
    uniq = []
    for f in out:
        if f.fingerprint not in seen:
            seen.add(f.fingerprint)
            uniq.append(f)
    return uniq


# ---------------------------------------------------------------------------
# RT001 — retrace hazards
# ---------------------------------------------------------------------------

@rule("RT001", "retrace-hazard",
      "hoist jit out of the loop behind a keyed cache, close over "
      "hashable config only, and keep runtime-derived scalars out of "
      "shapes/static_argnums")
def retrace_hazard(project: Project) -> List[Finding]:
    out: List[Finding] = []

    # (a) jax.jit applied inside a loop body: a fresh callable (and a
    # fresh trace) per iteration.
    for mod in project.modules.values():
        loops: List[ast.AST] = [n for n in ast.walk(mod.tree)
                                if isinstance(n, (ast.For, ast.While))]
        for loop in loops:
            for node in ast.walk(loop):
                if isinstance(node, ast.Call):
                    j, _, call = is_jit_expr(node, mod.aliases)
                    if j and call is node:
                        out.append(Finding(
                            rule_id="RT001", path=mod.path,
                            line=node.lineno, qualname="<loop>",
                            symbol="jit-in-loop",
                            message=("jax.jit applied inside a loop "
                                     "body retraces every iteration"),
                            hint=RULES["RT001"]["hint"]))

    # (b) jitted closure capturing an enclosing mutable literal: the
    # trace bakes in a snapshot; later mutation is silently ignored (or
    # forces a retrace under static hashing).
    for fn in project.functions.values():
        node = fn.node
        mutable_locals: Set[str] = set()
        for stmt in ast.walk(node):
            if isinstance(stmt, ast.Assign) and isinstance(
                    stmt.value, (ast.List, ast.Dict, ast.Set,
                                 ast.ListComp, ast.DictComp, ast.SetComp)):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        mutable_locals.add(t.id)
        if not mutable_locals:
            continue
        for inner in ast.walk(node):
            if inner is node or not isinstance(
                    inner, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not any(is_jit_expr(d, project.modules[fn.path].aliases)[0]
                       for d in inner.decorator_list):
                continue
            params = {a.arg for a in (inner.args.posonlyargs
                                      + inner.args.args
                                      + inner.args.kwonlyargs)}
            inner_locals = {t.id for s in ast.walk(inner)
                            if isinstance(s, ast.Assign)
                            for t in s.targets if isinstance(t, ast.Name)}
            for ref in ast.walk(inner):
                if isinstance(ref, ast.Name) and isinstance(
                        ref.ctx, ast.Load) \
                        and ref.id in mutable_locals \
                        and ref.id not in params \
                        and ref.id not in inner_locals:
                    out.append(Finding(
                        rule_id="RT001", path=fn.path, line=inner.lineno,
                        qualname=f"{fn.qualname}.{inner.name}",
                        symbol=f"closure:{ref.id}",
                        message=(f"jitted closure `{inner.name}` "
                                 f"captures mutable `{ref.id}` from "
                                 f"`{fn.qualname}` — trace won't see "
                                 "mutations"),
                        hint=RULES["RT001"]["hint"]))
                    break

    # (c) runtime-derived host scalar flowing into a shape: every new
    # value is a new static shape, i.e. a silent retrace.
    hot = project.hot_reachable()
    for key in sorted(hot):
        fn = project.functions[key]
        walk = _taint_function(fn, project,
                               project.jitted_names(fn.path))
        for node, symbol in walk.shape_hazards:
            out.append(Finding(
                rule_id="RT001", path=fn.path, line=node.lineno,
                qualname=fn.qualname, symbol=f"shape:{symbol}",
                message=(f"runtime-derived scalar feeds `{symbol}` "
                         f"shape in hot path `{fn.qualname}` — "
                         "retraces on every new value"),
                hint=RULES["RT001"]["hint"]))
    return out


# ---------------------------------------------------------------------------
# KC001 — kernel contract
# ---------------------------------------------------------------------------

def _param_names(node) -> Tuple[List[str], List[str]]:
    a = node.args
    pos = [p.arg for p in list(a.posonlyargs) + list(a.args)]
    kw = [p.arg for p in a.kwonlyargs]
    return pos, kw


def _index_map_exprs(call: ast.Call, local_defs: Dict[str, ast.AST]
                     ) -> List[ast.AST]:
    """Return-expression nodes of a BlockSpec's index map, if any."""
    cand: Optional[ast.AST] = None
    if len(call.args) >= 2:
        cand = call.args[1]
    for k in call.keywords:
        if k.arg == "index_map":
            cand = k.value
    if cand is None:
        return []
    if isinstance(cand, ast.Lambda):
        return [cand.body]
    if isinstance(cand, ast.Name) and cand.id in local_defs:
        return [r.value for r in ast.walk(local_defs[cand.id])
                if isinstance(r, ast.Return) and r.value is not None]
    return []


def _element_unclamped(el: ast.AST) -> bool:
    """Arithmetic in an index-map coordinate without a clamp can run
    past the declared BlockSpec bounds."""
    has_arith = any(isinstance(n, ast.BinOp)
                    and not isinstance(n.op, (ast.Mod, ast.FloorDiv))
                    for n in ast.walk(el))
    if not has_arith:
        return False
    for n in ast.walk(el):
        if isinstance(n, ast.Call):
            name = _bare_callee(n.func)
            if name in ("minimum", "min", "clip", "clamp", "mod",
                        "remainder", "where"):
                return False
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod):
            return False
    return True


@rule("KC001", "kernel-contract",
      "every *_pallas kernel needs an interpret= fallback, a *_ref jnp "
      "oracle with a matching signature, and clamped index-map "
      "arithmetic (jnp.minimum/clip/%)")
def kernel_contract(project: Project) -> List[Finding]:
    out: List[Finding] = []
    kernel_mods = {p: m for p, m in project.modules.items()
                   if "kernels/" in p or p.startswith("kernels")
                   or "/kernels/" in f"/{p}"}
    if not kernel_mods:
        return out
    # all _ref defs anywhere in the kernel package(s)
    refs: Dict[str, ast.AST] = {}
    for mod in kernel_mods.values():
        for fn in mod.functions:
            if fn.name.endswith("_ref") and fn.class_name is None:
                refs[fn.name] = fn.node
    for path, mod in kernel_mods.items():
        for fn in mod.functions:
            if not fn.name.endswith("_pallas") or fn.class_name:
                continue
            pos, kw = _param_names(fn.node)
            if "interpret" not in pos + kw:
                out.append(Finding(
                    rule_id="KC001", path=path, line=fn.node.lineno,
                    qualname=fn.qualname, symbol="interpret",
                    message=(f"kernel `{fn.name}` has no interpret= "
                             "fallback parameter"),
                    hint=RULES["KC001"]["hint"]))
            ref_name = fn.name[:-len("_pallas")] + "_ref"
            ref = refs.get(ref_name)
            if ref is None:
                out.append(Finding(
                    rule_id="KC001", path=path, line=fn.node.lineno,
                    qualname=fn.qualname, symbol="ref-missing",
                    message=(f"kernel `{fn.name}` has no `{ref_name}` "
                             "jnp oracle in the kernels package"),
                    hint=RULES["KC001"]["hint"]))
            else:
                rpos, rkw = _param_names(ref)
                if rpos != pos or not set(rkw) <= set(kw):
                    out.append(Finding(
                        rule_id="KC001", path=path, line=fn.node.lineno,
                        qualname=fn.qualname, symbol="ref-signature",
                        message=(f"`{ref_name}` signature ({rpos}, "
                                 f"kwonly {rkw}) does not match "
                                 f"`{fn.name}` ({pos}, kwonly {kw})"),
                        hint=RULES["KC001"]["hint"]))
            # index-map bounds inside this kernel wrapper
            local_defs = {n.name: n for n in ast.walk(fn.node)
                          if isinstance(n, ast.FunctionDef)}
            for node in ast.walk(fn.node):
                if not (isinstance(node, ast.Call)
                        and _bare_callee(node.func) == "BlockSpec"):
                    continue
                for ret in _index_map_exprs(node, local_defs):
                    elements = (ret.elts if isinstance(ret, ast.Tuple)
                                else [ret])
                    for el in elements:
                        if _element_unclamped(el):
                            out.append(Finding(
                                rule_id="KC001", path=path,
                                line=node.lineno, qualname=fn.qualname,
                                symbol="index-map-bounds",
                                message=("BlockSpec index map does "
                                         "arithmetic without a clamp — "
                                         "can exceed declared bounds"),
                                hint=RULES["KC001"]["hint"]))
    return out


# ---------------------------------------------------------------------------
# CC001 — counter coverage
# ---------------------------------------------------------------------------

COUNTER_MODULES = ("observe", "controller", "arbiter", "fleet",
                   "forecast", "slab_allocator", "kv_slab_pool",
                   "scheduler", "serve")
COUNTER_SUFFIXES = ("_syncs", "_dispatches", "_launches", "_count")


def _is_counter_name(name: str) -> bool:
    return name.startswith("n_") or name.endswith(COUNTER_SUFFIXES)


@rule("CC001", "counter-coverage",
      "read the counter from a test or scenarios/invariants.py checker "
      "(an unread counter is an unenforced contract), or delete it")
def counter_coverage(project: Project) -> List[Finding]:
    out: List[Finding] = []
    corpus = project.reader_corpus
    for path, mod in project.modules.items():
        stem = path.rsplit("/", 1)[-1][:-3]
        if not any(tag in stem for tag in COUNTER_MODULES):
            continue
        counters: Dict[str, Tuple[int, str]] = {}   # name -> (line, qual)
        declared: Dict[str, Tuple[str, int]] = {}   # @hot_path counters
        for fn in mod.functions:
            for c in fn.hot_counters:
                declared[c] = (fn.qualname, fn.node.lineno)
            if fn.name != "__init__" and fn.class_name is None:
                continue
            for stmt in ast.walk(fn.node):
                if isinstance(stmt, ast.Assign) \
                        and isinstance(stmt.value, ast.Constant) \
                        and stmt.value.value == 0:
                    for t in stmt.targets:
                        if isinstance(t, ast.Attribute) \
                                and isinstance(t.value, ast.Name) \
                                and t.value.id == "self" \
                                and _is_counter_name(t.attr):
                            counters.setdefault(
                                t.attr,
                                (stmt.lineno, fn.class_name or ""))
        for node in mod.tree.body:       # dataclass-style class counters
            if not isinstance(node, ast.ClassDef):
                continue
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) \
                        and isinstance(stmt.target, ast.Name) \
                        and isinstance(stmt.value, ast.Constant) \
                        and stmt.value.value == 0 \
                        and _is_counter_name(stmt.target.id):
                    counters.setdefault(stmt.target.id,
                                        (stmt.lineno, node.name))
        for name, (line, cls) in sorted(counters.items()):
            if name not in corpus:
                out.append(Finding(
                    rule_id="CC001", path=path, line=line,
                    qualname=cls or "<module>", symbol=name,
                    message=(f"counter `{name}` is never read by any "
                             "test or invariants checker"),
                    hint=RULES["CC001"]["hint"]))
        for name, (qual, line) in sorted(declared.items()):
            # the annotation itself is one occurrence; a backing counter
            # (self.x = 0 / x += 1) means the name appears again
            if name not in counters and mod.source.count(name) <= 1:
                out.append(Finding(
                    rule_id="CC001", path=path, line=line,
                    qualname=qual, symbol=name,
                    message=(f"@hot_path declares guard counter "
                             f"`{name}` that does not exist in "
                             f"{path}"),
                    hint="fix the counters=() annotation"))
    return out
