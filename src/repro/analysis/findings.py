"""Finding records and stable fingerprints for baseline suppression."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location.

    ``fingerprint`` deliberately excludes the line number so baselines
    survive unrelated edits: two identical sinks in one function share
    a fingerprint (suppressing "this function deliberately does X" is
    the right granularity). ``path`` is relative to the scan root and
    posix-flavoured so baselines are machine-independent.
    """
    rule_id: str          # e.g. "HS001"
    path: str             # scan-root-relative posix path
    line: int             # 1-based
    qualname: str         # enclosing function ("<module>" at top level)
    symbol: str           # what tripped: "float", "np.asarray", fn name
    message: str
    hint: str
    suppressed: bool = False
    justification: Optional[str] = None

    @property
    def fingerprint(self) -> str:
        return f"{self.rule_id}:{self.path}:{self.qualname}:{self.symbol}"

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def render(self) -> str:
        tag = " [suppressed]" if self.suppressed else ""
        out = (f"{self.path}:{self.line}: {self.rule_id}{tag} "
               f"[{self.qualname}] {self.message}")
        if self.hint:
            out += f"\n    hint: {self.hint}"
        if self.suppressed and self.justification:
            out += f"\n    baseline: {self.justification}"
        return out
