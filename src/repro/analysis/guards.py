"""Runtime transfer-guard sanitizer — the dynamic half of slablint.

:func:`no_implicit_transfers` arms two layers around a code region:

* jax's native ``transfer_guard_device_to_host("disallow")`` — the real
  enforcement on TPU, where any implicit device→host copy raises. On
  the CPU backend this guard is inert (host-resident arrays are
  "transferred" zero-copy), so additionally
* a software layer patches the concrete ``jax.Array`` implementation's
  host-materialising methods (``__float__``/``__int__``/``__bool__``/
  ``__index__``/``item``/``tolist``) to raise :class:`GuardViolation`
  while armed. This catches the common accidental syncs on every
  backend. Known hole: ``np.asarray(x)`` reaches CPU array memory via
  the buffer protocol and cannot be intercepted from Python — the
  native guard covers it on TPU, and slablint's HS001 covers it
  statically everywhere.

Donation-discard warnings are escalated to errors while armed, so a
fused window whose donated buffer silently stopped being donated fails
loudly (again: emitted on TPU; CPU jax does not warn).

:func:`deliberate_sync` is the escape hatch *both* halves recognise:
statically, HS001 skips sinks inside ``with deliberate_sync(...):``;
dynamically it suspends the software patches, enters the native
``"allow"`` scope, and logs the label to :data:`SYNC_LOG`. When no
guard is armed it is a true no-op that never imports jax — host-only
modules can use it freely.
"""
from __future__ import annotations

import contextlib
import threading
import warnings
from typing import Iterator, List, Optional

__all__ = ["GuardViolation", "no_implicit_transfers", "deliberate_sync",
           "SYNC_LOG", "guards_armed"]


class GuardViolation(RuntimeError):
    """An implicit device→host materialisation inside a guarded region."""


# One process-wide state: benches and tests arm guards around serial
# regions; the lock only protects arm/disarm bookkeeping.
_LOCK = threading.Lock()
_DEPTH = 0          # no_implicit_transfers nesting
_SYNC_DEPTH = 0     # deliberate_sync nesting (while armed)
_SAVED: dict = {}   # patched attr -> original
SYNC_LOG: List[Optional[str]] = []   # labels of deliberate syncs seen


def guards_armed() -> bool:
    return _DEPTH > 0


def _array_cls():
    import jax.numpy as jnp
    return type(jnp.zeros(0))


_PATCHED = ("__float__", "__int__", "__bool__", "__index__", "item",
            "tolist")


def _install_patches() -> None:
    cls = _array_cls()
    for name in _PATCHED:
        orig = getattr(cls, name)
        _SAVED[name] = orig

        def patched(self, *a, __orig=orig, __name=name, **kw):
            if _SYNC_DEPTH > 0:
                return __orig(self, *a, **kw)
            raise GuardViolation(
                f"implicit host sync: `{__name}` on a jax array inside "
                "a no_implicit_transfers region — wrap a deliberate "
                "cadence-boundary readback in deliberate_sync(...)")

        setattr(cls, name, patched)


def _remove_patches() -> None:
    cls = _array_cls()
    for name, orig in _SAVED.items():
        setattr(cls, name, orig)
    _SAVED.clear()


@contextlib.contextmanager
def no_implicit_transfers(*, donation_errors: bool = True
                          ) -> Iterator[None]:
    """Arm the transfer-guard sanitizer around a code region."""
    global _DEPTH
    import jax
    with _LOCK:
        _DEPTH += 1
        if _DEPTH == 1:
            _install_patches()
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            if donation_errors:
                with warnings.catch_warnings():
                    warnings.filterwarnings(
                        "error", message=".*[Dd]onat.*")
                    yield
            else:
                yield
    finally:
        with _LOCK:
            _DEPTH -= 1
            if _DEPTH == 0:
                _remove_patches()


@contextlib.contextmanager
def deliberate_sync(label: Optional[str] = None) -> Iterator[None]:
    """Mark a deliberate device→host readback (cadence boundaries).

    No-op when no guard is armed — never imports jax, so host-only
    sketches can run through it with zero overhead.
    """
    global _SYNC_DEPTH
    if _DEPTH == 0:
        yield
        return
    import jax
    with _LOCK:
        _SYNC_DEPTH += 1
        SYNC_LOG.append(label)
    try:
        with jax.transfer_guard_device_to_host("allow"):
            yield
    finally:
        with _LOCK:
            _SYNC_DEPTH -= 1
