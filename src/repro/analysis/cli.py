"""``python -m repro.analysis`` — run slablint over a source tree.

Exit status: 0 when every finding is baseline-suppressed (or none),
1 when unsuppressed findings remain and ``--check`` was passed,
2 on usage errors. Stdlib-only: the lint CI job needs no jax.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Set

from repro.analysis import baseline as baseline_mod
from repro.analysis.callgraph import Project
from repro.analysis.findings import Finding
from repro.analysis.rules import RULES, run_rules


def run_check(root, *, tests_root=None,
              only: Optional[Set[str]] = None) -> List[Finding]:
    """Scan ``root`` and return raw (un-baselined) findings."""
    root = Path(root)
    if tests_root is None:
        for cand in (root.parent / "tests", root / "tests",
                     Path("tests")):
            if cand.is_dir():
                tests_root = cand
                break
    project = Project.scan(root, tests_root=tests_root)
    return run_rules(project, only=only)


def check_source(source: str,
                 only: Optional[Set[str]] = None) -> List[str]:
    """Rule ids firing on a source snippet — the doctest-friendly API.

    >>> check_source("import jax\\n@jax.jit\\ndef f(state): return state")
    ['DN001']
    """
    project = Project.from_source(source)
    return sorted({f.rule_id for f in run_rules(project, only=only)})


def _default_baseline(root: Path) -> Path:
    for cand in (Path.cwd() / baseline_mod.DEFAULT_NAME,
                 root.parent / baseline_mod.DEFAULT_NAME):
        if cand.is_file():
            return cand
    return Path.cwd() / baseline_mod.DEFAULT_NAME


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="slablint: dispatch-discipline static analysis")
    ap.add_argument("root", nargs="?", default="src",
                    help="source tree to scan (default: src)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any unsuppressed finding")
    ap.add_argument("--json", metavar="PATH",
                    help="write all findings (incl. suppressed) as JSON")
    ap.add_argument("--baseline", metavar="PATH",
                    help=f"baseline file (default: ./"
                         f"{baseline_mod.DEFAULT_NAME})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="suppress every current finding (keeps existing "
                         "justifications; new entries get TODO markers)")
    ap.add_argument("--tests", metavar="PATH",
                    help="tests dir for counter-coverage readers "
                         "(default: <root>/../tests)")
    ap.add_argument("--rules", metavar="IDS",
                    help="comma-separated rule ids to run "
                         f"(known: {','.join(sorted(RULES))})")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, r in sorted(RULES.items()):
            print(f"{rid}  {r['name']}")
        return 0

    root = Path(args.root)
    if not root.is_dir():
        print(f"slablint: no such directory: {root}", file=sys.stderr)
        return 2
    only = None
    if args.rules:
        only = {r.strip().upper() for r in args.rules.split(",")}
        unknown = only - set(RULES)
        if unknown:
            print(f"slablint: unknown rules: {sorted(unknown)}",
                  file=sys.stderr)
            return 2

    findings = run_check(root, tests_root=args.tests, only=only)
    bl_path = Path(args.baseline) if args.baseline else \
        _default_baseline(root)
    old = baseline_mod.load(bl_path)

    if args.write_baseline:
        baseline_mod.write(bl_path, findings, old)
        print(f"slablint: wrote {len({f.fingerprint for f in findings})} "
              f"suppressions to {bl_path}")
        return 0

    findings, stale = baseline_mod.apply(findings, old)
    unsuppressed = [f for f in findings if not f.suppressed]
    for f in findings:
        print(f.render())
    for fp in stale:
        print(f"stale baseline entry (no longer fires): {fp}")
    if args.json:
        Path(args.json).write_text(json.dumps(
            {"findings": [f.to_json() for f in findings],
             "stale_baseline": stale,
             "n_unsuppressed": len(unsuppressed)}, indent=2))
    n_sup = len(findings) - len(unsuppressed)
    print(f"slablint: {len(findings)} finding(s), {n_sup} suppressed, "
          f"{len(unsuppressed)} unsuppressed, {len(stale)} stale "
          f"baseline entr{'y' if len(stale) == 1 else 'ies'}")
    if args.check and (unsuppressed or stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
