"""AST project model: functions, imports, jit sites, hot reachability.

Pure stdlib. The model is deliberately conservative where it matters
for soundness of the hot-path walk and documentedly imprecise where
precision would require type inference:

* plain-name calls ``foo()`` link to *every* scanned module-level
  function named ``foo`` (imports are not chased across renames);
* attribute calls ``obj.m()`` link to every scanned method named ``m``
  unless ``obj`` is a recognisably external module alias (``np.`` /
  ``jnp.`` / ``functools.`` ...). Yes, that links ``d.get(k)`` to
  ``TenantArbiter.get`` — over-approximation keeps the reachability
  walk sound, and the rules it feeds only fire on concrete sinks;
* a nested ``def`` is reachable from its enclosing function (defining
  a closure inside a hot path makes the closure hot).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

# Top-level packages we treat as external libraries: attribute calls on
# these aliases are never project method calls.
EXTERNAL_PACKAGES = {
    "numpy", "jax", "jaxlib", "functools", "itertools", "collections",
    "dataclasses", "typing", "math", "os", "sys", "time", "logging",
    "warnings", "random", "json", "re", "csv", "argparse", "pathlib",
    "contextlib", "threading", "queue", "heapq", "bisect", "pytest",
}


@dataclasses.dataclass
class FunctionInfo:
    path: str                 # scan-root-relative posix path
    qualname: str             # "Class.method", "fn", "outer.inner"
    name: str                 # bare name
    node: ast.AST             # FunctionDef / AsyncFunctionDef
    class_name: Optional[str]
    hot_seed: bool
    jitted: bool              # carries a jax.jit decorator
    jit_donates: bool         # ... with donate_argnums/argnames
    callees: List[str] = dataclasses.field(default_factory=list)
    hot_counters: Tuple[str, ...] = ()

    @property
    def key(self) -> str:
        return f"{self.path}::{self.qualname}"


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.numpy.sum' for nested Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_jit_expr(node: ast.AST, aliases: Dict[str, str]
                ) -> Tuple[bool, bool, Optional[ast.Call]]:
    """Classify ``node`` as a jax.jit application.

    Returns ``(is_jit, has_donate, call_node)`` where ``call_node`` is
    the Call carrying keyword args (donate/static), if any. Handles
    ``jit`` / ``jax.jit`` bare, called, and via ``functools.partial``.
    """
    def names_jit(n: ast.AST) -> bool:
        d = _dotted(n)
        if d is None:
            return False
        if d in ("jit", "jax.jit"):
            return True
        full = aliases.get(d.split(".")[0])
        return bool(full and (full + d[len(d.split(".")[0]):]) == "jax.jit")

    if names_jit(node):
        return True, False, None
    if isinstance(node, ast.Call):
        if names_jit(node.func):
            donate = any(k.arg and k.arg.startswith("donate")
                         for k in node.keywords)
            return True, donate, node
        d = _dotted(node.func)
        if d and d.split(".")[-1] == "partial" and node.args:
            if names_jit(node.args[0]):
                donate = any(k.arg and k.arg.startswith("donate")
                             for k in node.keywords)
                return True, donate, node
    return False, False, None


def _is_hot_decorator(dec: ast.AST) -> Tuple[bool, Tuple[str, ...]]:
    """(is hot_path decorator, declared counters=(...) string literals)."""
    target = dec.func if isinstance(dec, ast.Call) else dec
    d = _dotted(target)
    if not (d and d.split(".")[-1] == "hot_path"):
        return False, ()
    counters: List[str] = []
    if isinstance(dec, ast.Call):
        for k in dec.keywords:
            if k.arg == "counters" and isinstance(k.value,
                                                  (ast.Tuple, ast.List)):
                counters = [e.value for e in k.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)]
    return True, tuple(counters)


class ModuleInfo:
    def __init__(self, path: str, tree: ast.Module, source: str):
        self.path = path                    # root-relative posix
        self.tree = tree
        self.source = source
        self.aliases: Dict[str, str] = {}   # local name -> dotted origin
        self.functions: List[FunctionInfo] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}")

    def is_external(self, base: str) -> bool:
        origin = self.aliases.get(base, base)
        return origin.split(".")[0] in EXTERNAL_PACKAGES


class _FnCollector(ast.NodeVisitor):
    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.stack: List[str] = []
        self.class_stack: List[str] = []

    def _visit_fn(self, node) -> None:
        qual = ".".join(self.stack + [node.name])
        hot, counters = False, ()
        jitted = donates = False
        for dec in node.decorator_list:
            h, c = _is_hot_decorator(dec)
            if h:
                hot, counters = True, c
            j, d, _ = is_jit_expr(dec, self.mod.aliases)
            if j:
                jitted, donates = True, donates or d
        info = FunctionInfo(
            path=self.mod.path, qualname=qual, name=node.name, node=node,
            class_name=self.class_stack[-1] if self.class_stack else None,
            hot_seed=hot, jitted=jitted, jit_donates=donates,
            hot_counters=counters)
        self.mod.functions.append(info)
        self.stack.append(node.name)
        self.generic_visit(node)
        self.stack.pop()

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_fn

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.class_stack.append(node.name)
        self.generic_visit(node)
        self.class_stack.pop()
        self.stack.pop()


class Project:
    """All scanned modules plus the indexes the rules query."""

    def __init__(self) -> None:
        self.modules: Dict[str, ModuleInfo] = {}      # path -> ModuleInfo
        self.functions: Dict[str, FunctionInfo] = {}  # key -> info
        self.reader_corpus: str = ""   # tests + invariants source text

    # -- construction -----------------------------------------------------
    @classmethod
    def scan(cls, root: Path, tests_root: Optional[Path] = None
             ) -> "Project":
        proj = cls()
        root = Path(root)
        for py in sorted(root.rglob("*.py")):
            if "__pycache__" in py.parts:
                continue
            rel = py.relative_to(root).as_posix()
            proj.add_source(py.read_text(), rel)
        readers: List[str] = []
        if tests_root and Path(tests_root).is_dir():
            for py in sorted(Path(tests_root).rglob("*.py")):
                if "__pycache__" not in py.parts:
                    readers.append(py.read_text())
        readers.extend(m.source for p, m in proj.modules.items()
                       if p.endswith("invariants.py"))
        proj.reader_corpus = "\n".join(readers)
        proj._link()
        return proj

    @classmethod
    def from_source(cls, source: str, path: str = "<snippet>") -> "Project":
        proj = cls()
        proj.add_source(source, path)
        proj._link()
        return proj

    def add_source(self, source: str, path: str) -> None:
        tree = ast.parse(source)
        mod = ModuleInfo(path, tree, source)
        _FnCollector(mod).visit(tree)
        self.modules[path] = mod
        for fn in mod.functions:
            self.functions[fn.key] = fn

    # -- linking ----------------------------------------------------------
    def _link(self) -> None:
        by_name: Dict[str, List[str]] = {}
        for fn in self.functions.values():
            by_name.setdefault(fn.name, []).append(fn.key)
        for fn in self.functions.values():
            mod = self.modules[fn.path]
            callees: Set[str] = set()
            # nested defs are reachable from their definer
            for child in ast.iter_child_nodes(fn.node):
                self._collect_nested(child, fn, callees)
            for node in ast.walk(fn.node):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                if isinstance(f, ast.Name):
                    callees.update(by_name.get(f.id, ()))
                    # renamed imports: `from m import g as h; h()` -> g
                    origin = mod.aliases.get(f.id)
                    if origin:
                        callees.update(
                            by_name.get(origin.split(".")[-1], ()))
                elif isinstance(f, ast.Attribute):
                    base = f.value
                    if isinstance(base, ast.Name) and mod.is_external(
                            base.id):
                        continue
                    callees.update(by_name.get(f.attr, ()))
            callees.discard(fn.key)
            fn.callees = sorted(callees)

    def _collect_nested(self, node: ast.AST, parent: FunctionInfo,
                        out: Set[str]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            key = f"{parent.path}::{parent.qualname}.{node.name}"
            if key in self.functions:
                out.add(key)
            return  # grandchildren belong to the child
        for child in ast.iter_child_nodes(node):
            self._collect_nested(child, parent, out)

    # -- queries ----------------------------------------------------------
    def hot_seeds(self) -> List[FunctionInfo]:
        return [f for f in self.functions.values() if f.hot_seed]

    def hot_reachable(self) -> Set[str]:
        """Keys of every function reachable from a ``@hot_path`` seed."""
        frontier = [f.key for f in self.hot_seeds()]
        seen: Set[str] = set(frontier)
        while frontier:
            key = frontier.pop()
            for callee in self.functions[key].callees:
                if callee not in seen:
                    seen.add(callee)
                    frontier.append(callee)
        return seen

    def jitted_names(self, path: str) -> Set[str]:
        """Bare names known to be jax.jit-wrapped *in module* ``path``
        (decorator form or ``name = jax.jit(fn)`` assignments). Scoped
        per module: generic names like ``fn`` must not taint unrelated
        calls elsewhere. Cross-module device producers belong in the
        curated DEVICE_FNS surface instead."""
        mod = self.modules[path]
        out = {f.name for f in mod.functions if f.jitted}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                j, _, _ = is_jit_expr(node.value, mod.aliases)
                if j:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out.add(t.id)
        return out
