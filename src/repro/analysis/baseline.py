"""Baseline file: deliberate, justified suppressions.

Format — one fingerprint per line, justification after ``#``::

    DN001:repro/kernels/sketch_update.py:sketch_update_pallas:sketch_update_pallas  # callers retain state

Unlisted findings are *unsuppressed* and fail ``--check``; listed
fingerprints that no longer fire are reported as stale so the file
can't rot into a wildcard.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Tuple

from repro.analysis.findings import Finding

DEFAULT_NAME = ".slablint-baseline"


def load(path: Path) -> Dict[str, str]:
    """fingerprint -> justification ('' if none)."""
    out: Dict[str, str] = {}
    if not Path(path).is_file():
        return out
    for raw in Path(path).read_text().splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        fp, _, just = line.partition("#")
        out[fp.strip()] = just.strip()
    return out


def apply(findings: List[Finding], baseline: Dict[str, str]
          ) -> Tuple[List[Finding], List[str]]:
    """Mark suppressed findings; return (findings, stale fingerprints)."""
    seen = set()
    out: List[Finding] = []
    for f in findings:
        just = baseline.get(f.fingerprint)
        if just is not None:
            seen.add(f.fingerprint)
            f = Finding(**{**f.__dict__, "suppressed": True,
                           "justification": just or None})
        out.append(f)
    stale = sorted(set(baseline) - seen)
    return out, stale


def write(path: Path, findings: List[Finding],
          old: Dict[str, str]) -> None:
    """Write every current finding's fingerprint, keeping existing
    justifications and flagging new entries for a human to justify."""
    lines = ["# slablint baseline — every line is a deliberate,",
             "# justified suppression. Regenerate with --write-baseline;",
             "# keep justifications current.", ""]
    done = set()
    for f in findings:
        fp = f.fingerprint
        if fp in done:
            continue
        done.add(fp)
        just = old.get(fp, "") or "TODO: justify"
        lines.append(f"{fp}  # {just}")
    Path(path).write_text("\n".join(lines) + "\n")
