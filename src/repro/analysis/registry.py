"""The hot-path registry: one source of truth for "what counts as hot".

``@hot_path`` marks a function or method as dispatch-sensitive — part
of the observe/tick/fused-window surface whose cost model assumes no
implicit host syncs. Both halves of the discipline read it:

* slablint's HS001/RT001 rules seed their call-graph reachability walk
  from these decorators (statically, from the AST — importing the
  decorated module is never required);
* runtime accounting can introspect :data:`HOT_PATHS` to know which
  dispatch counters (``counters=...``) guard each path, and tests can
  assert the registry matches the objects they exercise.

The decorator is deliberately **zero-overhead**: it registers the
function and returns it *unchanged* — no wrapper frame — because
several hot paths (``observe``) are called per item inside benchmarked
loops. This module is stdlib-only.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

# qualified name -> {"fn": callable, "label": str, "counters": tuple}
HOT_PATHS: Dict[str, dict] = {}


def hot_path(fn: Optional[Callable] = None, *, label: Optional[str] = None,
             counters: Tuple[str, ...] = ()) -> Callable:
    """Register ``fn`` as a dispatch-discipline hot path.

    Usable bare (``@hot_path``) or with arguments
    (``@hot_path(counters=("n_dispatches",))``). ``counters`` names the
    stat counters whose accounting guards this path at runtime; CC001
    cross-checks that they exist and are read by tests.
    """
    def register(f: Callable) -> Callable:
        key = label or f"{f.__module__}.{f.__qualname__}"
        HOT_PATHS[key] = {"fn": f, "label": key,
                          "counters": tuple(counters)}
        f.__hot_path__ = key
        return f

    if fn is None:
        return register
    return register(fn)


def hot_path_counters() -> Dict[str, Tuple[str, ...]]:
    """Map of registered hot-path label -> declared guard counters."""
    return {k: v["counters"] for k, v in HOT_PATHS.items()}
