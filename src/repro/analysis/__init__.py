"""slablint: dispatch-discipline static analysis for the jit/Pallas paths.

The device pipeline's performance model rests on contracts — one fused
launch per cadence window, donated sketch buffers, no implicit host
syncs, no silent retraces — that runtime counters (``n_dispatches``,
``WINDOW_TRACE_COUNT``) only check late, in benches, on specific
inputs. This package checks them at lint time, on every line:

* :mod:`repro.analysis.registry` — the ``@hot_path`` decorator, the one
  source of truth for which functions are dispatch-sensitive.
* :mod:`repro.analysis.callgraph` — AST call graph + hot reachability.
* :mod:`repro.analysis.rules` — the pluggable rule registry (HS001
  host-sync, DN001 donation, RT001 retrace hazard, KC001 kernel
  contract, CC001 counter coverage).
* :mod:`repro.analysis.baseline` — deliberate-suppression file support.
* :mod:`repro.analysis.cli` — ``python -m repro.analysis``.
* :mod:`repro.analysis.guards` — the *runtime* half: a transfer-guard
  sanitizer (:func:`guards.no_implicit_transfers`) and the
  :func:`guards.deliberate_sync` escape hatch the static rules
  recognise.

Everything except ``guards`` is stdlib-only so the lint CI job needs no
jax install; ``guards`` imports jax lazily and only when armed.
"""
from repro.analysis.findings import Finding
from repro.analysis.registry import HOT_PATHS, hot_path
from repro.analysis.cli import check_source, run_check

__all__ = ["Finding", "HOT_PATHS", "hot_path", "check_source",
           "run_check"]
