"""Cross-tenant resource arbitration — the Memshare-style layer above
the per-tenant controllers.

The paper learns one slab schedule from one traffic pattern; a
production fleet serves N applications with divergent size distributions
out of ONE physical resource pool. PR 1 built the single-tenant loop
(observe → drift → refit → reconfigure); this module adds the missing
arbitration layer the ROADMAP names: each tenant keeps its own
:class:`~repro.core.controller.SlabController` adapting its own
schedule, while a global :class:`TenantArbiter` redistributes resource
*units* between tenants as their demand peaks move out of phase.

Three pieces:

* :class:`ResourcePool` — the shared physical pool, parameterized by
  resource *kind*: memcached arbitrates 64 KiB **pages**
  (:class:`PagePool`, ``kind="pages"``), serving arbitrates **KV token
  quota** units (``kind="kv_tokens"``, see
  ``repro.serving.kv_slab_pool.token_quota_arbiter``). Every unit is
  tenant-tagged; per-tenant ``quota`` (None = first-come-first-served)
  and ``floor`` (units an arbiter may never drain below) bound what
  arbitration can do. The conservation invariant —
  ``free + sum(owned) == total`` — holds after every operation and is
  checked by :attr:`ResourcePool.conserved`.
* :class:`TenantArbiter` — owns the per-tenant controllers and the
  transfer loop. Every ``arbitrate_every`` operations it scores the
  best donor → recipient unit transfer with the controller's own cost
  model (see below) and executes approved transfers as a quota move
  plus a ``release_page`` on the donor (memcached ``slabs reassign``
  eviction semantics, across tenants instead of across classes).
* :class:`TransferDecision` — one scored transfer verdict, approved or
  not, mirroring :class:`~repro.core.controller.RefitDecision`.

Transfer cost model (the controller's model, applied across tenants):
a unit granted to the recipient retains up to one unit of payload the
recipient is currently evicting, window after window —
``benefit = min(pressure_bytes, unit_size) * amortization_windows`` —
while the donor pays ONCE the payload bytes resident on its cheapest
reclaimable unit, weighted by ``cost_weight`` (the same migration-byte
: waste-byte exchange rate ``ControllerConfig`` uses). A transfer is
approved only when ``benefit > cost``, the donor stays at or above its
floor, and total units are conserved.

Forecast-aware donor selection (``forecast=``): with an active
:class:`~repro.core.forecast.DemandForecaster`, each arbitration round
records every tenant's window demand into the forecaster, and a donor
whose forecast says its demand is about to GROW is surcharged the
predicted growth bytes — pages are not taken from a tenant heading
into its peak, which is exactly the reclaim-then-bounce-back loop
Memshare's reactive arbitration suffers (counted in ``n_bounced``:
approved transfers whose recipient donated within ``bounce_window``
ops). ``forecast=None`` or :class:`~repro.core.forecast.Reactive`
reproduces the reactive decisions bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.guards import deliberate_sync
from repro.analysis.registry import hot_path
from repro.core.controller import (ControllerConfig, ScoreRequest,
                                   SlabController, _score_frontier,
                                   score_requests)
from repro.core.distribution import PAGE_SIZE


# ---------------------------------------------------------------------------
# ResourcePool (PagePool is the kind="pages" instantiation)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TenantPages:
    """Per-tenant unit-ownership record inside a :class:`ResourcePool`."""

    owned: int = 0               # units currently held by this tenant
    quota: Optional[int] = None  # max owned (None: unlimited / FCFS)
    floor: int = 0               # arbiter may never drop quota below this
    n_denied: int = 0            # acquire() refusals (pressure signal)


class ResourcePool:
    """A shared physical pool of same-sized units with tenant-tagged
    ownership, parameterized by resource kind.

    Units are handed out one at a time via :meth:`acquire` and returned
    via :meth:`release`; the pool never forgets who holds what, so the
    conservation invariant ``free_units + sum(owned) == total_units``
    is maintained by construction and exposed as :attr:`conserved`.

    ``quota`` caps what a tenant may hold (``None`` disables the cap —
    the pooled, first-come-first-served baseline); ``floor`` is the
    starvation guard honoured by :meth:`move_quota`. ``unit_size`` is
    the physical size of one unit in the kind's own currency (bytes for
    pages, tokens for KV quota) — every pressure/benefit/cost number
    the arbiter computes is in that currency.

    The page-flavoured aliases (``total_pages`` / ``free_pages`` /
    ``pages_in_use`` / ``page_size``) are kept on the base class so the
    memcached layer and its tests read naturally; they are the same
    counters.
    """

    def __init__(self, total_units: int, *, unit_size: int = PAGE_SIZE,
                 kind: str = "units"):
        if total_units <= 0:
            raise ValueError(f"total_units must be positive: {total_units}")
        self.total_units = int(total_units)
        self.unit_size = int(unit_size)
        self.kind = kind
        self.free_units = int(total_units)
        self._tenants: Dict[str, TenantPages] = {}

    # -- registration --------------------------------------------------------
    def register(self, tenant: str, *, quota: Optional[int] = None,
                 floor: int = 0) -> TenantPages:
        """Add ``tenant`` (idempotent; later calls may tighten quota/floor)."""
        rec = self._tenants.get(tenant)
        if rec is None:
            rec = TenantPages(quota=quota, floor=floor)
            self._tenants[tenant] = rec
        else:
            if quota is not None:
                rec.quota = quota
            if floor:
                rec.floor = floor
        return rec

    def unregister(self, tenant: str, *, force: bool = False) -> None:
        """Remove ``tenant`` from the pool. The tenant must own nothing
        (drain with ``release``/``release_page`` first) unless
        ``force=True``, which returns any still-owned units to the free
        pool — conservation holds either way."""
        rec = self._tenants[tenant]
        if rec.owned:
            if not force:
                raise ValueError(
                    f"tenant {tenant!r} still owns {rec.owned} "
                    f"{self.kind}; drain first or pass force=True")
            self.free_units += rec.owned
            rec.owned = 0
        del self._tenants[tenant]

    def equal_partition(self, *, floor: Optional[int] = None) -> None:
        """Set every registered tenant's quota to an equal share of the
        pool (remainder units go to the earliest-registered tenants)."""
        names = list(self._tenants)
        if not names:
            raise ValueError("no tenants registered")
        share, rem = divmod(self.total_units, len(names))
        for i, name in enumerate(names):
            rec = self._tenants[name]
            rec.quota = share + (1 if i < rem else 0)
            if floor is not None:
                rec.floor = floor

    # -- unit movement -------------------------------------------------------
    def acquire(self, tenant: str) -> bool:
        """Hand one free unit to ``tenant``; False when the pool is empty
        or the tenant is at quota (counted in ``n_denied``)."""
        rec = self._tenants[tenant]
        if self.free_units <= 0 or (rec.quota is not None
                                    and rec.owned >= rec.quota):
            rec.n_denied += 1
            return False
        self.free_units -= 1
        rec.owned += 1
        return True

    def release(self, tenant: str) -> None:
        """``tenant`` returns one owned unit to the free pool."""
        rec = self._tenants[tenant]
        if rec.owned <= 0:
            raise ValueError(f"tenant {tenant!r} owns no {self.kind}")
        rec.owned -= 1
        self.free_units += 1

    def set_owned(self, tenant: str, owned: int) -> None:
        """Re-sync one tenant's ownership from an external usage source
        (the KV token-quota adapter measures real token usage each
        round rather than brokering every alloc through the pool).
        Conservation is preserved: the free counter absorbs the delta.
        Growth is CLAMPED to the units currently free — per-tenant
        syncs arrive in arbitrary order, so a grower may be observed
        before the shrinker that funds it; the arbiter's sync pass
        runs twice, and the second pass completes any clamped growth
        (raising here instead would crash arbitration on exactly the
        out-of-phase handoff it exists for)."""
        rec = self._tenants[tenant]
        owned = int(owned)
        if owned < 0:
            raise ValueError(f"owned must be non-negative, got {owned}")
        delta = min(owned - rec.owned, self.free_units)
        rec.owned += delta
        self.free_units -= delta

    def move_quota(self, donor: str, recipient: str, units: int = 1) -> None:
        """Shift ``units`` of quota donor → recipient (the arbiter's
        bookkeeping half of a transfer). The donor must be
        quota-managed and stays at or above its floor — the starvation
        guard; an unmanaged recipient (``quota=None``) simply keeps its
        unlimited grab rights and only the donor shrinks."""
        self.shrink_quota(donor, units)
        r = self._tenants[recipient]
        if r.quota is not None:
            r.quota += units

    def shrink_quota(self, tenant: str, units: int = 1) -> None:
        """Lower a tenant's quota, refusing to cross its floor."""
        rec = self._tenants[tenant]
        if rec.quota is None:
            raise ValueError(
                f"tenant {tenant!r} is not quota-managed "
                "(register with quota= or call equal_partition)")
        if rec.quota - units < rec.floor:
            raise ValueError(
                f"transfer would drain {tenant!r} below its floor "
                f"({rec.quota}-{units} < {rec.floor})")
        rec.quota -= units

    # -- views ---------------------------------------------------------------
    def owned(self, tenant: str) -> int:
        return self._tenants[tenant].owned

    def quota(self, tenant: str) -> Optional[int]:
        return self._tenants[tenant].quota

    def tenants(self) -> Dict[str, TenantPages]:
        return dict(self._tenants)

    @property
    def units_in_use(self) -> int:
        return sum(rec.owned for rec in self._tenants.values())

    @property
    def conserved(self) -> bool:
        """The invariant every transfer must preserve."""
        return self.free_units + self.units_in_use == self.total_units

    # -- page-flavoured aliases (memcached reads naturally) ------------------
    @property
    def total_pages(self) -> int:
        return self.total_units

    @property
    def free_pages(self) -> int:
        return self.free_units

    @property
    def pages_in_use(self) -> int:
        return self.units_in_use

    @property
    def page_size(self) -> int:
        return self.unit_size


class PagePool(ResourcePool):
    """The ``kind="pages"`` pool memcached tenants share (the original
    arbitration quantum: one slab page of ``page_size`` bytes)."""

    def __init__(self, total_pages: int, *, page_size: int = PAGE_SIZE):
        super().__init__(total_pages, unit_size=page_size, kind="pages")


# ---------------------------------------------------------------------------
# TenantArbiter
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TransferDecision:
    """One scored donor → recipient unit-transfer verdict."""

    approved: bool
    reason: str                  # "transfer" | why it was declined
    donor: Optional[str]
    recipient: Optional[str]
    benefit: float               # amortized payload bytes retained
    cost: float                  # weighted eviction bytes charged to donor
    evicted_items: int           # donor items actually evicted (approved)
    evicted_bytes: int
    at_op: int                   # arbiter op clock when decided
    forecast_penalty: float = 0.0  # demand-growth surcharge in the cost


@dataclasses.dataclass
class _Tenant:
    name: str
    allocator: "object"            # SlabAllocator-shaped (duck-typed)
    controller: SlabController
    # window baselines for the pressure signal
    evicted_bytes0: int = 0
    denials0: int = 0
    pressure: float = 0.0
    # demand-forecast stream state
    window_demand_bytes: float = 0.0   # set payload since last round
    last_donated_at: int = -1          # op clock of last approved donation
    # fleet mode: stacked-state row + duck-hooks cached at registration
    row: int = -1
    sync_owned_fn: Optional[object] = None
    demand_fn: Optional[object] = None
    apply_quota_fn: Optional[object] = None


class TenantArbiter:
    """Global resource arbiter over per-tenant slab controllers.

    Each registered tenant brings an allocator attached to the shared
    :class:`ResourcePool` and gets its own
    :class:`~repro.core.controller.SlabController` (intra-tenant
    schedule adaptation continues exactly as in the single-tenant
    loop). The arbiter adds the inter-tenant axis: route ``set`` /
    ``delete`` traffic through :meth:`set` / :meth:`delete` (or drive
    the cadence externally with :meth:`tick` — the serving layer's
    mode) and every ``arbitrate_every`` ops it runs :meth:`arbitrate`,
    which

    1. measures per-tenant *pressure* — payload bytes lost to capacity
       evictions plus unit-denial mass since the last round,
    2. picks the highest-pressure tenant as recipient and the tenant
       with the cheapest reclaimable unit as donor — where "cheapest"
       is the eviction-policy-priced reclaim cost PLUS, under an
       active forecast, the tenant's predicted demand growth (don't
       take units a tenant is about to need),
    3. scores the transfer with the controller's cost model
       (``benefit = min(pressure, unit_size) * amortization_windows``
       vs ``cost = cost_weight * donor_release_cost + growth
       surcharge``), and
    4. executes approved transfers: quota moves donor → recipient and
       the donor's cheapest unit is reclaimed
       (``release_page``, memcached ``slabs reassign`` eviction
       semantics) back into the shared free pool for the recipient to
       grab on demand.

    Guarantees (tested in ``tests/test_multitenant.py`` /
    ``tests/test_forecast.py``):
    * units are conserved across every transfer (``pool.conserved``),
    * no transfer is approved when predicted benefit <= predicted cost,
    * no donor is ever drained below its registered ``floor_pages``,
    * ``forecast=None`` / ``Reactive`` decisions match the
      pre-forecast arbiter exactly.
    """

    def __init__(self, pool: ResourcePool, *,
                 controller_config: Optional[ControllerConfig] = None,
                 arbitrate_every: int = 5000,
                 amortization_windows: float = 4.0,
                 cost_weight: float = 0.25,
                 max_transfers_per_round: int = 4,
                 tail_default: bool = True,
                 forecast=None,
                 forecast_horizon: int = 1,
                 forecast_min_confidence: float = 0.35,
                 forecast_weight: float = 1.0,
                 bounce_window: Optional[int] = None,
                 fleet: bool = False,
                 fleet_capacity: int = 8):
        self.pool = pool
        self.controller_config = controller_config
        self.arbitrate_every = int(arbitrate_every)
        self.amortization_windows = float(amortization_windows)
        self.cost_weight = float(cost_weight)
        self.max_transfers_per_round = int(max_transfers_per_round)
        self.tail_default = tail_default
        self.forecaster = forecast
        self._forecast_on = bool(getattr(forecast, "active", False))
        self.forecast_horizon = int(forecast_horizon)
        self.forecast_min_confidence = float(forecast_min_confidence)
        self.forecast_weight = float(forecast_weight)
        self.bounce_window = (2 * self.arbitrate_every
                              if bounce_window is None else int(bounce_window))
        self.tenants: Dict[str, _Tenant] = {}
        self.decisions: List[TransferDecision] = []
        self.events: List[Tuple[int, str]] = []   # (n_ops, label) marks
        self.n_transfers = 0
        self.n_bounced = 0       # recipient had donated within bounce_window
        # tick-granular admission gate (serving harness seam)
        self.n_admission_checks = 0
        self.n_admission_denials = 0
        self.n_ops = 0
        self._since_arbitrate = 0
        # Fleet-batched candidate scoring telemetry: every drain that
        # finds pending frontiers costs ONE waste_eval launch however
        # many tenants came due together.
        self.n_score_launches = 0
        self.n_frontiers_scored = 0
        # fleet=True: per-tenant state lives in stacked FleetState rows
        # (pressure, quotas, forecast rings, cadence mirrors, device
        # sketches) and every arbitration stage runs batched over the
        # whole fleet; the per-tenant loop above stays available as the
        # bit-exact oracle (fleet=False). n_gate_launches counts the
        # one-launch-per-tick batched drift gate.
        self.fleet = None
        self.n_gate_launches = 0
        self._by_row: Dict[int, _Tenant] = {}
        self._sorted_cache: Optional[List[_Tenant]] = None
        if fleet:
            from repro.core.fleet import FleetState
            self.fleet = FleetState(
                capacity=fleet_capacity,
                forecaster=self.forecaster if self._forecast_on else None)

    # -- registration --------------------------------------------------------
    def register(self, name: str, allocator, *,
                 controller: Optional[SlabController] = None,
                 floor_pages: int = 1,
                 quota: Optional[int] = None) -> SlabController:
        """Register one tenant. ``allocator`` must be attached to the
        arbiter's pool (``SlabAllocator(page_pool=pool, tenant=name)``,
        or a ``KVTenantQuotaView`` for the token-quota kind); a
        per-tenant controller is created from ``controller_config``
        when none is supplied. Returns the tenant's controller.

        Only quota-managed tenants can *donate* units — pass ``quota=``
        here or call ``pool.equal_partition()`` after registering
        everyone (unmanaged tenants can still receive)."""
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if getattr(allocator, "page_pool", None) is not self.pool:
            raise ValueError(
                f"allocator for {name!r} is not attached to this pool")
        if getattr(allocator, "tenant", None) != name:
            raise ValueError(
                f"allocator tenant tag {allocator.tenant!r} != {name!r}")
        self.pool.register(name, quota=quota, floor=floor_pages)
        row = -1
        if self.fleet is not None:
            row = self.fleet.alloc_row(name)
            self.fleet.adopt_pool_record(self.pool, name)
        if controller is None:
            cfg = self.controller_config or ControllerConfig(
                page_size=self.pool.unit_size)
            sketch = None
            if self.fleet is not None and cfg.device:
                sketch = self.fleet.sketch_view(row, cfg)
            controller = SlabController(allocator.chunk_sizes, config=cfg,
                                        sketch=sketch)
        t = _Tenant(name=name, allocator=allocator, controller=controller,
                    row=row,
                    sync_owned_fn=getattr(allocator, "sync_owned", None),
                    demand_fn=getattr(allocator, "current_demand_bytes",
                                      None),
                    apply_quota_fn=getattr(allocator, "apply_quota", None))
        self.tenants[name] = t
        self._sorted_cache = None
        if self.fleet is not None:
            self._by_row[row] = t
            self.fleet.check_every[row] = controller.config.check_every
            self.fleet.since_check[row] = controller._since_check
        return controller

    def remove(self, name: str, *, release_pages: bool = True):
        """Unregister one tenant — the leave half of join/leave churn.

        With ``release_pages`` (default) every unit the tenant still
        owns is drained back to the free pool through its allocator's
        ``release_page`` (evicting residents, the same reclaim path a
        transfer uses); allocators without one (KV quota views) fall
        back to a forced pool unregister, which frees the owned units
        directly. In fleet mode the tenant's row is zeroed and pushed
        on the free-list for the next joiner. Returns the tenant's
        controller (callers may want its decision log)."""
        t = self.tenants.pop(name)
        self._sorted_cache = None
        release = (getattr(t.allocator, "release_page", None)
                   if release_pages else None)
        while release is not None and self.pool.owned(name) > 0:
            release()
        self.pool.unregister(name, force=self.pool.owned(name) > 0)
        if self.fleet is not None:
            del self._by_row[t.row]
            self.fleet.free_row(name)
        return t.controller

    def _sorted_tenants(self) -> List[_Tenant]:
        """Tenants in sorted-name order — the legacy loop's selection
        order, cached until membership changes (the fleet pricing
        stages index arrays in exactly this order so argmax/lexsort
        tie-breaking lands on the same tenant the legacy scan picks)."""
        if self._sorted_cache is None:
            self._sorted_cache = [self.tenants[n]
                                  for n in sorted(self.tenants)]
        return self._sorted_cache

    # -- traffic -------------------------------------------------------------
    @hot_path(counters=("n_ops",))
    def set(self, name: str, key: str, value_size: int) -> bool:
        """Store one item for ``name``: feeds its allocator + sketch, runs
        the tenant's own refit pipeline, and the arbitration cadence."""
        t = self.tenants[name]
        stored = t.allocator.set(key, value_size)
        t.controller.observe(int(value_size) + t.allocator.item_overhead)
        if self.fleet is None:
            t.window_demand_bytes += float(value_size)
        else:
            self.fleet.window_demand[t.row] += float(value_size)
        self._maybe_refit_tenant(t)
        if self.fleet is not None:
            self.fleet.since_check[t.row] = t.controller._since_check
        self.n_ops += 1
        self._since_arbitrate += 1
        if self._since_arbitrate >= self.arbitrate_every:
            self.arbitrate()
        return stored

    @hot_path
    def observe(self, name: str, sizes, weights=None) -> None:
        """Feed externally-measured sizes into one tenant's sketch
        WITHOUT ticking the op cadence (pair with :meth:`tick` — the
        serving layer's mode). This is the observation route fleet mode
        requires: it keeps the stacked cadence mirror in sync, so the
        vectorized due-scan in :meth:`tick` sees the tenant come due.
        (Legacy mode scans every controller per tick, so direct
        ``controller.observe`` calls also work there.)"""
        t = self.tenants[name]
        t.controller.observe_many(sizes, weights)
        if self.fleet is not None:
            self.fleet.since_check[t.row] = t.controller._since_check

    @hot_path(counters=("n_ops",))
    def get(self, name: str, key: str) -> bool:
        """Look up one item (touch-on-get feeds the tenant's eviction
        policy — re-referenced items gain rank, so donor pages are
        carved from the residents the traffic stopped asking for);
        counts toward the arbitration cadence."""
        hit = self.tenants[name].allocator.get(key)
        self.n_ops += 1
        self._since_arbitrate += 1
        if self._since_arbitrate >= self.arbitrate_every:
            self.arbitrate()
        return hit

    @hot_path(counters=("n_ops",))
    def delete(self, name: str, key: str) -> bool:
        """Delete one item; counts toward the arbitration cadence (TTL
        churn frees the chunks that make cheap donors)."""
        deleted = self.tenants[name].allocator.delete(key)
        self.n_ops += 1
        self._since_arbitrate += 1
        if self._since_arbitrate >= self.arbitrate_every:
            self.arbitrate()
        return deleted

    @hot_path(counters=("n_score_launches", "n_gate_launches",
                        "n_frontiers_scored"))
    def tick(self, n: int = 1) -> None:
        """Advance the arbitration cadence by ``n`` operations that did
        NOT route through :meth:`set`/:meth:`get`/:meth:`delete` — the
        serving layer's mode, where traffic flows through
        ``KVSlabPool.alloc`` and the batcher just reports op counts.
        Every tenant whose controller came due (externally-fed sketches)
        gets its drift check here, with all pending candidate frontiers
        scored in ONE batched ``waste_eval`` launch. Fleet mode finds
        the due tenants with one vectorized mask over the stacked
        cadence mirror (kept in sync by :meth:`set`/:meth:`observe`)
        and batches their device drift gates into one launch."""
        self.n_ops += int(n)
        self._since_arbitrate += int(n)
        if self.fleet is None:
            self._drain_checks(self.tenants.values())
        else:
            self._drain_checks_fleet()
        if self._since_arbitrate >= self.arbitrate_every:
            self.arbitrate()

    @hot_path(counters=("n_admission_checks", "n_admission_denials"))
    def admission(self, name: str, units: int = 1) -> bool:
        """Tick-granular admission gate — the serving harness asks the
        arbiter BEFORE allocating for a new request: may tenant ``name``
        take ``units`` more of the pool's resource right now?

        Admitted when the tenant is unmanaged (``quota=None``) or its
        re-synced ownership plus the request fits its arbiter-assigned
        quota; the underlying allocator's own quota check stays the
        enforcement backstop (``apply_quota`` keeps the two in
        agreement). A denial is recorded on the tenant's pressure
        signal (``note_admission_denial`` on allocators that carry one,
        e.g. :class:`~repro.serving.kv_slab_pool.KVTenantQuotaView`),
        so the NEXT arbitration round sees the starvation and can move
        quota toward the stream — deny now, rebalance at cadence, admit
        later, instead of letting an over-quota stream fail deep in the
        allocator."""
        t = self.tenants.get(name)
        if t is None:
            raise KeyError(f"tenant {name!r} not registered")
        self.n_admission_checks += 1
        if t.sync_owned_fn is not None:
            t.sync_owned_fn()
        quota = self.pool.quota(name)
        if quota is None or self.pool.owned(name) + units <= quota:
            return True
        self.n_admission_denials += 1
        note = getattr(t.allocator, "note_admission_denial", None)
        if note is not None:
            note()
        return False

    def note_event(self, label: str, tenants: Optional[Sequence[str]] = None
                   ) -> None:
        """Mark an external event (chaos injection, deploy) on the
        arbiter clock and on every named tenant's controller (all
        tenants when ``tenants`` is None) — the torture harness feeds
        chaos marks through here so per-tenant
        ``forecast_miss_refits`` and the arbiter-level timeline agree."""
        self.events.append((self.n_ops, label))
        names = self.tenants.keys() if tenants is None else tenants
        for name in names:
            self.tenants[name].controller.note_event(label)

    def forecast_miss_refits(self, window: Optional[int] = None) -> int:
        """Sum of every tenant controller's post-event reactive refits
        (see :meth:`SlabController.forecast_miss_refits`)."""
        return sum(t.controller.forecast_miss_refits(window)
                   for t in self.tenants.values())

    def _deploy_schedule(self, chunks: np.ndarray) -> np.ndarray:
        if not self.tail_default:
            return np.asarray(chunks, dtype=np.int64)
        from repro.core.slab_policy import schedule_with_default_tail
        return schedule_with_default_tail(chunks,
                                          page_size=self.pool.unit_size)

    def _apply_refit(self, t: _Tenant, decision) -> None:
        if decision.approved:
            deployed = self._deploy_schedule(decision.chunks)
            t.allocator.reconfigure(deployed)
            t.controller.set_chunks(deployed)

    def _maybe_refit_tenant(self, t: _Tenant) -> None:
        self._drain_checks([t])

    def _drain_checks(self, tenants, drifts=None) -> None:
        """Run every due tenant's drift check, batching all surviving
        candidate frontiers into one fleet ``waste_eval`` launch.

        The gates (drift, cooldown, hysteresis, cost model) run in each
        tenant's own controller exactly as on the solo path; only the
        frontier *scoring* is pooled. A single pending frontier goes
        through the controller's own ``_score_frontier`` launch, so
        solo-tenant decisions stay bit-identical to ``maybe_refit``;
        with several pending tenants the fleet kernel scores every
        frontier row against its own histogram in one launch (padding
        is score-neutral — see ``score_requests``). ``drifts`` maps
        ``id(tenant)`` to a drift value precomputed by the fleet's
        batched gate launch (see :meth:`_batched_gate`)."""
        pending = []
        for t in tenants:
            if not t.controller.check_due:
                continue
            out = t.controller.begin_check(
                cost_bytes_fn=lambda c, _t=t:
                    _t.allocator.migration_cost_bytes(
                        self._deploy_schedule(c)),
                precomputed_drift=(None if drifts is None
                                   else drifts.get(id(t))))
            if out is None:
                continue
            if isinstance(out, ScoreRequest):
                pending.append((t, out))
            else:
                self._apply_refit(t, out)
        if not pending:
            return
        self.n_score_launches += 1
        self.n_frontiers_scored += len(pending)
        if len(pending) == 1:
            t, req = pending[0]
            scores = [_score_frontier(req.rows, req.support, req.freqs,
                                      page_size=req.page_size)]
        else:
            # group by page_size (a static kernel parameter); in
            # practice one group — one launch per tick
            by_ps: Dict[int, List] = {}
            for t, req in pending:
                by_ps.setdefault(req.page_size, []).append(req)
            scored = {}
            for reqs in by_ps.values():
                for req, s in zip(reqs, score_requests(reqs)):
                    scored[id(req)] = s
            self.n_score_launches += len(by_ps) - 1
            scores = [scored[id(req)] for _, req in pending]
        for (t, req), s in zip(pending, scores):
            self._apply_refit(t, t.controller.finish_check(req, s))

    def _drain_checks_fleet(self) -> None:
        """Fleet due-scan: one vectorized mask over the stacked cadence
        mirror picks the due rows; their device drift gates run as one
        batched launch; the surviving frontiers batch-score as usual."""
        f = self.fleet
        due_rows = np.nonzero(f.active
                              & (f.check_every > 0)
                              & (f.since_check >= f.check_every))[0]
        if due_rows.size == 0:
            return
        due = [self._by_row[int(r)] for r in due_rows]
        self._drain_checks(due, self._batched_gate(due))
        for t in due:
            f.since_check[t.row] = t.controller._since_check

    def _batched_gate(self, due) -> Optional[Dict[int, float]]:
        """One ``drift_gate_fleet`` launch + one vector readback for
        every due device-sketch tenant with an adopted reference.

        Returns ``id(tenant) -> drift`` for the gated tenants (others
        fall through to their controller's solo gate). A single ready
        tenant uses the solo fused flush+gate — same one-launch cost,
        and bit-identical to legacy, matching the score-launch idiom.
        Groups by (metric, grid) — one launch per group; fleets share
        a controller_config, so in practice one group, one launch."""
        ready = [t for t in due
                 if t.controller._device
                 and t.controller.reference is not None
                 and t.controller.sketch.n_observed > 0]
        if len(ready) < 2:
            return None
        groups: Dict[Tuple[str, int], List[_Tenant]] = {}
        for t in ready:
            key = (t.controller.config.drift_metric,
                   int(t.controller.sketch.num_buckets))
            groups.setdefault(key, []).append(t)
        from repro.kernels.fleet_gate import drift_gate_fleet
        import jax.numpy as jnp
        out: Dict[int, float] = {}
        for (metric, _), ts in groups.items():
            for t in ts:
                t.controller.sketch.flush_window()
            refs = jnp.stack([t.controller.reference for t in ts])
            live = jnp.stack([t.controller.sketch.weights_device
                              for t in ts])
            with deliberate_sync("arbiter.fleet-drift-gate"):
                vals = np.asarray(drift_gate_fleet(refs, live,
                                                   metric=metric))
            self.n_gate_launches += 1
            for t, v in zip(ts, vals):
                out[id(t)] = float(v)
        return out

    # -- arbitration ---------------------------------------------------------
    def _refresh_pressure(self) -> None:
        unit_size = self.pool.unit_size
        for t in self.tenants.values():
            ev = t.allocator.evicted_bytes - t.evicted_bytes0
            dn = t.allocator.n_page_denials - t.denials0
            # evicted payload measures what was lost, denial mass the
            # capacity shortfall; both terms always count so a tiny
            # eviction can never zero out a heavily-denied tenant
            t.pressure = float(ev) + float(dn) * unit_size

    def _reset_window(self) -> None:
        for t in self.tenants.values():
            t.evicted_bytes0 = t.allocator.evicted_bytes
            t.denials0 = t.allocator.n_page_denials
            t.window_demand_bytes = 0.0

    def _record_forecast_windows(self) -> None:
        """One demand window per tenant per arbitration round. The
        demand summary is the window's stored payload; an allocator may
        override it (``current_demand_bytes``) — the KV quota view
        reports live allocated tokens, which IS its demand."""
        for t in self.tenants.values():
            fn = getattr(t.allocator, "current_demand_bytes", None)
            demand = float(fn()) if fn is not None else t.window_demand_bytes
            self.forecaster.record_window(t.name, demand_bytes=demand)

    def _forecast_penalty(self, t: _Tenant) -> float:
        """Demand-growth surcharge on a candidate donor, in pool-
        currency bytes: the units this tenant's forecast says it is
        about to need are priced at full value, so reclaiming them now
        just to bounce them back next round never scores well."""
        if not self._forecast_on:
            return 0.0
        growth, conf = self.forecaster.demand_growth(
            t.name, self.forecast_horizon)
        if conf < self.forecast_min_confidence or growth <= 0.0:
            return 0.0
        return self.forecast_weight * float(growth)

    def _donor_release_cost(self, t: _Tenant) -> Optional[float]:
        """Predicted cost of the donor's cheapest reclaimable unit, or
        None when the tenant has nothing it may give (no unit above its
        floor). The number comes from the tenant allocator's eviction
        policy (``page_release_cost_bytes`` →
        ``EvictionPolicy.page_reclaim_cost_bytes``): under cost-aware
        policies a page full of never-re-referenced residents prices
        near zero, so reclaimed units come from the least-valuable
        residents fleet-wide — not merely the fewest-bytes page."""
        rec = self.pool._tenants[t.name]
        if rec.quota is None or rec.quota - 1 < rec.floor:
            return None         # unmanaged or at floor: may not donate
        if rec.owned < rec.quota:
            return 0            # unexercised quota: giving it away is free
        return t.allocator.page_release_cost_bytes()

    @hot_path(counters=("n_transfers", "n_bounced"))
    def arbitrate(self) -> List[TransferDecision]:
        """One arbitration round; returns this round's decisions."""
        if self.fleet is not None:
            return self._arbitrate_fleet()
        self._since_arbitrate = 0
        # Two passes: set_owned clamps growth to the units free at that
        # moment, so shrinking tenants must release first — the second
        # pass completes growth the first one clamped, whatever order
        # the tenants sync in.
        for _ in range(2):
            for t in self.tenants.values():
                sync = getattr(t.allocator, "sync_owned", None)
                if sync is not None:  # KV quota views measure usage here
                    sync()
        self._refresh_pressure()
        if self._forecast_on:
            self._record_forecast_windows()
        round_decisions: List[TransferDecision] = []
        unit_size = self.pool.unit_size
        names = sorted(self.tenants)
        for _ in range(self.max_transfers_per_round):
            recipient = max(
                (self.tenants[n] for n in names),
                key=lambda t: t.pressure)
            if recipient.pressure <= 0.0:
                break    # nobody is starved; no decision to record
            benefit = (min(recipient.pressure, float(unit_size))
                       * self.amortization_windows)
            # cheapest donor that may give a unit (floor respected),
            # ranked by release cost + forecast demand-growth surcharge
            donor = None
            donor_cost: Optional[float] = None
            donor_penalty = 0.0
            for n in names:
                t = self.tenants[n]
                if t is recipient:
                    continue
                base = self._donor_release_cost(t)
                if base is None:
                    continue
                pen = self._forecast_penalty(t)
                c = float(base) + pen
                if donor_cost is None or c < donor_cost or (
                        c == donor_cost and t.pressure < donor.pressure):
                    donor, donor_cost, donor_penalty = t, c, pen
            if donor is None:
                # nobody may donate: every other tenant is unmanaged,
                # at its floor, or holds nothing — the starvation guard
                round_decisions.append(self._decide(
                    False, "no-eligible-donor", None, recipient.name,
                    benefit, 0.0))
                break
            # the penalty is a demand-bytes surcharge, not an eviction
            # prediction — it is charged at full weight on top of the
            # discounted eviction cost
            cost = (self.cost_weight * float(donor_cost - donor_penalty)
                    + donor_penalty)
            if benefit <= cost:
                round_decisions.append(self._decide(
                    False, "cost-exceeds-benefit", donor.name,
                    recipient.name, benefit, cost,
                    forecast_penalty=donor_penalty))
                break
            # execute: quota follows the unit; the donor's cheapest unit
            # goes back to the shared free pool for the recipient to
            # grab on its next demand
            self.pool.move_quota(donor.name, recipient.name, 1)
            evicted_items = evicted_bytes = 0
            if self.pool.owned(donor.name) > self.pool.quota(donor.name):
                evicted_items, evicted_bytes = donor.allocator.release_page()
            for moved in (donor, recipient):
                apply_quota = getattr(moved.allocator, "apply_quota", None)
                if apply_quota is not None:   # KV views push quota back
                    apply_quota(self.pool.quota(moved.name))
            self.n_transfers += 1
            if (recipient.last_donated_at >= 0
                    and self.n_ops - recipient.last_donated_at
                    <= self.bounce_window):
                # the reactive blind spot made visible: this tenant gave
                # a unit away moments ago and is already buying it back
                self.n_bounced += 1
            donor.last_donated_at = self.n_ops
            round_decisions.append(self._decide(
                True, "transfer", donor.name, recipient.name, benefit,
                cost, evicted_items=evicted_items,
                evicted_bytes=evicted_bytes,
                forecast_penalty=donor_penalty))
            recipient.pressure = max(
                0.0, recipient.pressure - float(unit_size))
        self._reset_window()
        return round_decisions

    def _arbitrate_fleet(self) -> List[TransferDecision]:
        """One arbitration round over the stacked fleet state.

        Decision-for-decision (and bit-for-bit, on host sketches) the
        same as the legacy loop in :meth:`arbitrate`, with every
        O(n_tenants) Python pass replaced by one batched stage:

        * pressure refresh — two ``np.fromiter`` gathers of the
          allocator counters, then elementwise float64 (the exact ops
          ``_refresh_pressure`` runs per tenant),
        * forecast surcharge — one stacked ring push plus one batched
          ACF pass (:meth:`FleetState.demand_growth`, which shares its
          implementation with the scalar ``DemandForecaster``), once
          per round — legacy recomputes it per transfer iteration, but
          the rings don't change within a round, so once is identical,
        * donor pricing — ``page_release_cost_bytes`` (a pure query) is
          gathered once per round for the at-quota eligible tenants and
          cached; after an executed transfer only the donor's entry is
          invalidated (the one allocator that mutated). Selection is a
          stable lexsort on (cost, pressure, sorted-name position) —
          exactly the legacy scan's strict-< replacement rule.

        Transfers still execute one at a time through the pool (each
        changes the eligibility landscape for the next), so the
        decision *sequence* is the legacy sequence.
        """
        self._since_arbitrate = 0
        f = self.fleet
        for _ in range(2):      # same two clamped-growth sync passes
            for t in self.tenants.values():
                if t.sync_owned_fn is not None:
                    t.sync_owned_fn()
        ts = self._sorted_tenants()
        n = len(ts)
        if n == 0:
            return []
        unit = self.pool.unit_size
        rows = np.asarray([t.row for t in ts], dtype=np.int64)
        ev = np.fromiter((t.allocator.evicted_bytes for t in ts),
                         dtype=np.int64, count=n)
        dn = np.fromiter((t.allocator.n_page_denials for t in ts),
                         dtype=np.int64, count=n)
        press = ((ev - f.evicted0[rows]).astype(np.float64)
                 + (dn - f.denials0[rows]).astype(np.float64) * unit)
        if self._forecast_on:
            demand = f.window_demand[rows].copy()
            for i, t in enumerate(ts):
                if t.demand_fn is not None:
                    demand[i] = float(t.demand_fn())
            f.record_demand(rows, demand)
            growth, conf = f.demand_growth(rows, self.forecast_horizon)
            pen = np.where((conf >= self.forecast_min_confidence)
                           & (growth > 0.0),
                           self.forecast_weight * growth, 0.0)
        else:
            pen = np.zeros(n, dtype=np.float64)
        release_cost = np.full(n, np.nan)   # per-round pure-query cache
        has_cost = np.zeros(n, dtype=bool)
        round_decisions: List[TransferDecision] = []
        for _ in range(self.max_transfers_per_round):
            ri = int(np.argmax(press))      # first max == legacy's scan
            if press[ri] <= 0.0:
                break    # nobody is starved; no decision to record
            recipient = ts[ri]
            benefit = (min(float(press[ri]), float(unit))
                       * self.amortization_windows)
            q = f.quota[rows]
            can = (q >= 0) & (q - 1 >= f.floor[rows])
            can[ri] = False
            zero_cost = can & (f.owned[rows] < q)
            for i in np.nonzero(can & ~zero_cost & ~has_cost)[0]:
                c0 = ts[i].allocator.page_release_cost_bytes()
                release_cost[i] = np.nan if c0 is None else float(c0)
                has_cost[i] = True
            base = np.where(zero_cost, 0.0, release_cost)
            c = base + pen
            elig = can & ~np.isnan(base)
            if not elig.any():
                round_decisions.append(self._decide(
                    False, "no-eligible-donor", None, recipient.name,
                    benefit, 0.0))
                break
            idx = np.nonzero(elig)[0]
            # stable sort by (cost, pressure), position ascending within
            # ties — the legacy strict-< scan's winner
            di = int(idx[np.lexsort((press[idx], c[idx]))[0]])
            donor = ts[di]
            donor_cost = float(c[di])
            donor_penalty = float(pen[di])
            cost = (self.cost_weight * float(donor_cost - donor_penalty)
                    + donor_penalty)
            if benefit <= cost:
                round_decisions.append(self._decide(
                    False, "cost-exceeds-benefit", donor.name,
                    recipient.name, benefit, cost,
                    forecast_penalty=donor_penalty))
                break
            self.pool.move_quota(donor.name, recipient.name, 1)
            evicted_items = evicted_bytes = 0
            if self.pool.owned(donor.name) > self.pool.quota(donor.name):
                evicted_items, evicted_bytes = donor.allocator.release_page()
            for moved in (donor, recipient):
                if moved.apply_quota_fn is not None:
                    moved.apply_quota_fn(self.pool.quota(moved.name))
            self.n_transfers += 1
            if (f.last_donated[recipient.row] >= 0
                    and self.n_ops - f.last_donated[recipient.row]
                    <= self.bounce_window):
                self.n_bounced += 1
            f.last_donated[donor.row] = self.n_ops
            round_decisions.append(self._decide(
                True, "transfer", donor.name, recipient.name, benefit,
                cost, evicted_items=evicted_items,
                evicted_bytes=evicted_bytes,
                forecast_penalty=donor_penalty))
            press[ri] = max(0.0, float(press[ri]) - float(unit))
            has_cost[di] = False      # the one allocator that mutated
        f.pressure[rows] = press
        f.evicted0[rows] = np.fromiter(
            (t.allocator.evicted_bytes for t in ts), dtype=np.int64,
            count=n)
        f.denials0[rows] = np.fromiter(
            (t.allocator.n_page_denials for t in ts), dtype=np.int64,
            count=n)
        f.window_demand[rows] = 0.0
        return round_decisions

    def _decide(self, approved: bool, reason: str, donor: Optional[str],
                recipient: Optional[str], benefit: float, cost: float, *,
                evicted_items: int = 0, evicted_bytes: int = 0,
                forecast_penalty: float = 0.0) -> TransferDecision:
        d = TransferDecision(approved=approved, reason=reason, donor=donor,
                             recipient=recipient, benefit=benefit, cost=cost,
                             evicted_items=evicted_items,
                             evicted_bytes=evicted_bytes, at_op=self.n_ops,
                             forecast_penalty=forecast_penalty)
        self.decisions.append(d)
        return d

    # -- measurement ---------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        """Per-tenant snapshot: units owned/quota plus allocator stats."""
        out = {}
        for name, t in self.tenants.items():
            st = t.allocator.stats()
            out[name] = {
                "pages_owned": self.pool.owned(name),
                "quota": self.pool.quota(name),
                "n_resident": st.n_resident,
                "item_bytes": st.item_bytes,
                "waste": st.waste,
                "n_evicted": st.n_evicted,
                "evicted_bytes": st.evicted_bytes,
                "n_page_denials": st.n_page_denials,
                "n_refits": t.controller.n_refits,
                "migration_evictions": st.migration_evictions,
                "evicted_hot_bytes": st.evicted_hot_bytes,
                "reused_after_evict": st.reused_after_evict,
                "eviction_policy": st.eviction_policy,
            }
        return out
