"""Cross-tenant page arbitration — the Memshare-style layer above the
per-tenant controllers.

The paper learns one slab schedule from one traffic pattern; a
production fleet serves N applications with divergent size distributions
out of ONE physical page pool. PR 1 built the single-tenant loop
(observe → drift → refit → reconfigure); this module adds the missing
arbitration layer the ROADMAP names: each tenant keeps its own
:class:`~repro.core.controller.SlabController` adapting its own
schedule, while a global :class:`TenantArbiter` redistributes *pages*
between tenants as their demand peaks move out of phase.

Three pieces:

* :class:`PagePool` — the shared physical pool. Every page is
  tenant-tagged; per-tenant ``quota`` (None = first-come-first-served)
  and ``floor`` (pages an arbiter may never drain below) bound what
  arbitration can do. The conservation invariant —
  ``free + sum(owned) == total`` — holds after every operation and is
  checked by :attr:`PagePool.conserved`.
* :class:`TenantArbiter` — owns the per-tenant controllers and the
  transfer loop. Every ``arbitrate_every`` operations it scores the
  best donor → recipient page transfer with the controller's own cost
  model (see below) and executes approved transfers as a quota move
  plus a ``SlabAllocator.release_page`` on the donor (memcached
  ``slabs reassign`` eviction semantics, across tenants instead of
  across classes).
* :class:`TransferDecision` — one scored transfer verdict, approved or
  not, mirroring :class:`~repro.core.controller.RefitDecision`.

Transfer cost model (the controller's model, applied across tenants):
a page granted to the recipient retains up to one page of payload the
recipient is currently evicting, window after window —
``benefit = min(pressure_bytes, page_size) * amortization_windows`` —
while the donor pays ONCE the payload bytes resident on its cheapest
reclaimable page, weighted by ``cost_weight`` (the same migration-byte
: waste-byte exchange rate ``ControllerConfig`` uses). A transfer is
approved only when ``benefit > cost``, the donor stays at or above its
floor, and total pages are conserved.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.controller import ControllerConfig, SlabController
from repro.core.distribution import PAGE_SIZE


# ---------------------------------------------------------------------------
# PagePool
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TenantPages:
    """Per-tenant page-ownership record inside a :class:`PagePool`."""

    owned: int = 0               # pages currently held by this tenant
    quota: Optional[int] = None  # max owned (None: unlimited / FCFS)
    floor: int = 0               # arbiter may never drop quota below this
    n_denied: int = 0            # acquire() refusals (pressure signal)


class PagePool:
    """A shared physical page pool with tenant-tagged ownership.

    Pages are handed out one at a time via :meth:`acquire` and returned
    via :meth:`release`; the pool never forgets who holds what, so the
    conservation invariant ``free_pages + sum(owned) == total_pages``
    is maintained by construction and exposed as :attr:`conserved`.

    ``quota`` caps what a tenant may hold (``None`` disables the cap —
    the pooled, first-come-first-served baseline); ``floor`` is the
    starvation guard honoured by :meth:`move_quota`.
    """

    def __init__(self, total_pages: int, *, page_size: int = PAGE_SIZE):
        if total_pages <= 0:
            raise ValueError(f"total_pages must be positive: {total_pages}")
        self.total_pages = int(total_pages)
        self.page_size = int(page_size)
        self.free_pages = int(total_pages)
        self._tenants: Dict[str, TenantPages] = {}

    # -- registration --------------------------------------------------------
    def register(self, tenant: str, *, quota: Optional[int] = None,
                 floor: int = 0) -> TenantPages:
        """Add ``tenant`` (idempotent; later calls may tighten quota/floor)."""
        rec = self._tenants.get(tenant)
        if rec is None:
            rec = TenantPages(quota=quota, floor=floor)
            self._tenants[tenant] = rec
        else:
            if quota is not None:
                rec.quota = quota
            if floor:
                rec.floor = floor
        return rec

    def equal_partition(self, *, floor: Optional[int] = None) -> None:
        """Set every registered tenant's quota to an equal share of the
        pool (remainder pages go to the earliest-registered tenants)."""
        names = list(self._tenants)
        if not names:
            raise ValueError("no tenants registered")
        share, rem = divmod(self.total_pages, len(names))
        for i, name in enumerate(names):
            rec = self._tenants[name]
            rec.quota = share + (1 if i < rem else 0)
            if floor is not None:
                rec.floor = floor

    # -- page movement -------------------------------------------------------
    def acquire(self, tenant: str) -> bool:
        """Hand one free page to ``tenant``; False when the pool is empty
        or the tenant is at quota (counted in ``n_denied``)."""
        rec = self._tenants[tenant]
        if self.free_pages <= 0 or (rec.quota is not None
                                    and rec.owned >= rec.quota):
            rec.n_denied += 1
            return False
        self.free_pages -= 1
        rec.owned += 1
        return True

    def release(self, tenant: str) -> None:
        """``tenant`` returns one owned page to the free pool."""
        rec = self._tenants[tenant]
        if rec.owned <= 0:
            raise ValueError(f"tenant {tenant!r} owns no pages")
        rec.owned -= 1
        self.free_pages += 1

    def move_quota(self, donor: str, recipient: str, pages: int = 1) -> None:
        """Shift ``pages`` of quota donor → recipient (the arbiter's
        bookkeeping half of a transfer). The donor must be
        quota-managed and stays at or above its floor — the starvation
        guard; an unmanaged recipient (``quota=None``) simply keeps its
        unlimited grab rights and only the donor shrinks."""
        self.shrink_quota(donor, pages)
        r = self._tenants[recipient]
        if r.quota is not None:
            r.quota += pages

    def shrink_quota(self, tenant: str, pages: int = 1) -> None:
        """Lower a tenant's quota, refusing to cross its floor."""
        rec = self._tenants[tenant]
        if rec.quota is None:
            raise ValueError(
                f"tenant {tenant!r} is not quota-managed "
                "(register with quota= or call equal_partition)")
        if rec.quota - pages < rec.floor:
            raise ValueError(
                f"transfer would drain {tenant!r} below its floor "
                f"({rec.quota}-{pages} < {rec.floor})")
        rec.quota -= pages

    # -- views ---------------------------------------------------------------
    def owned(self, tenant: str) -> int:
        return self._tenants[tenant].owned

    def quota(self, tenant: str) -> Optional[int]:
        return self._tenants[tenant].quota

    def tenants(self) -> Dict[str, TenantPages]:
        return dict(self._tenants)

    @property
    def pages_in_use(self) -> int:
        return sum(rec.owned for rec in self._tenants.values())

    @property
    def conserved(self) -> bool:
        """The invariant every transfer must preserve."""
        return self.free_pages + self.pages_in_use == self.total_pages


# ---------------------------------------------------------------------------
# TenantArbiter
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TransferDecision:
    """One scored donor → recipient page-transfer verdict."""

    approved: bool
    reason: str                  # "transfer" | why it was declined
    donor: Optional[str]
    recipient: Optional[str]
    benefit: float               # amortized payload bytes retained
    cost: float                  # weighted eviction bytes charged to donor
    evicted_items: int           # donor items actually evicted (approved)
    evicted_bytes: int
    at_op: int                   # arbiter op clock when decided


@dataclasses.dataclass
class _Tenant:
    name: str
    allocator: "object"            # SlabAllocator-shaped (duck-typed)
    controller: SlabController
    # window baselines for the pressure signal
    evicted_bytes0: int = 0
    denials0: int = 0
    pressure: float = 0.0


class TenantArbiter:
    """Global page arbiter over per-tenant slab controllers.

    Each registered tenant brings a ``SlabAllocator`` attached to the
    shared :class:`PagePool` and gets its own
    :class:`~repro.core.controller.SlabController` (intra-tenant
    schedule adaptation continues exactly as in the single-tenant
    loop). The arbiter adds the inter-tenant axis: route ``set`` /
    ``delete`` traffic through :meth:`set` / :meth:`delete` and every
    ``arbitrate_every`` ops it runs :meth:`arbitrate`, which

    1. measures per-tenant *pressure* — payload bytes lost to capacity
       evictions plus page-denial mass since the last round,
    2. picks the highest-pressure tenant as recipient and the tenant
       with the cheapest reclaimable page as donor,
    3. scores the transfer with the controller's cost model
       (``benefit = min(pressure, page_size) * amortization_windows``
       vs ``cost = cost_weight * donor_release_cost_bytes``), and
    4. executes approved transfers: quota moves donor → recipient and
       the donor's cheapest page is reclaimed
       (:meth:`SlabAllocator.release_page`, memcached ``slabs
       reassign`` eviction semantics) back into the shared free pool
       for the recipient to grab on demand.

    Guarantees (tested in ``tests/test_multitenant.py``):
    * pages are conserved across every transfer (``pool.conserved``),
    * no transfer is approved when predicted benefit <= predicted cost,
    * no donor is ever drained below its registered ``floor_pages``.
    """

    def __init__(self, pool: PagePool, *,
                 controller_config: Optional[ControllerConfig] = None,
                 arbitrate_every: int = 5000,
                 amortization_windows: float = 4.0,
                 cost_weight: float = 0.25,
                 max_transfers_per_round: int = 4,
                 tail_default: bool = True):
        self.pool = pool
        self.controller_config = controller_config
        self.arbitrate_every = int(arbitrate_every)
        self.amortization_windows = float(amortization_windows)
        self.cost_weight = float(cost_weight)
        self.max_transfers_per_round = int(max_transfers_per_round)
        self.tail_default = tail_default
        self.tenants: Dict[str, _Tenant] = {}
        self.decisions: List[TransferDecision] = []
        self.n_transfers = 0
        self.n_ops = 0
        self._since_arbitrate = 0

    # -- registration --------------------------------------------------------
    def register(self, name: str, allocator, *,
                 controller: Optional[SlabController] = None,
                 floor_pages: int = 1,
                 quota: Optional[int] = None) -> SlabController:
        """Register one tenant. ``allocator`` must be attached to the
        arbiter's pool (``SlabAllocator(page_pool=pool, tenant=name)``);
        a per-tenant controller is created from ``controller_config``
        when none is supplied. Returns the tenant's controller.

        Only quota-managed tenants can *donate* pages — pass ``quota=``
        here or call ``pool.equal_partition()`` after registering
        everyone (unmanaged tenants can still receive)."""
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if getattr(allocator, "page_pool", None) is not self.pool:
            raise ValueError(
                f"allocator for {name!r} is not attached to this pool")
        if getattr(allocator, "tenant", None) != name:
            raise ValueError(
                f"allocator tenant tag {allocator.tenant!r} != {name!r}")
        self.pool.register(name, quota=quota, floor=floor_pages)
        if controller is None:
            cfg = self.controller_config or ControllerConfig(
                page_size=self.pool.page_size)
            controller = SlabController(allocator.chunk_sizes, config=cfg)
        self.tenants[name] = _Tenant(name=name, allocator=allocator,
                                     controller=controller)
        return controller

    # -- traffic -------------------------------------------------------------
    def set(self, name: str, key: str, value_size: int) -> bool:
        """Store one item for ``name``: feeds its allocator + sketch, runs
        the tenant's own refit pipeline, and the arbitration cadence."""
        t = self.tenants[name]
        stored = t.allocator.set(key, value_size)
        t.controller.observe(int(value_size) + t.allocator.item_overhead)
        self._maybe_refit_tenant(t)
        self.n_ops += 1
        self._since_arbitrate += 1
        if self._since_arbitrate >= self.arbitrate_every:
            self.arbitrate()
        return stored

    def get(self, name: str, key: str) -> bool:
        """Look up one item (touch-on-get feeds the tenant's eviction
        policy — re-referenced items gain rank, so donor pages are
        carved from the residents the traffic stopped asking for);
        counts toward the arbitration cadence."""
        hit = self.tenants[name].allocator.get(key)
        self.n_ops += 1
        self._since_arbitrate += 1
        if self._since_arbitrate >= self.arbitrate_every:
            self.arbitrate()
        return hit

    def delete(self, name: str, key: str) -> bool:
        """Delete one item; counts toward the arbitration cadence (TTL
        churn frees the chunks that make cheap donors)."""
        deleted = self.tenants[name].allocator.delete(key)
        self.n_ops += 1
        self._since_arbitrate += 1
        if self._since_arbitrate >= self.arbitrate_every:
            self.arbitrate()
        return deleted

    def _deploy_schedule(self, chunks: np.ndarray) -> np.ndarray:
        if not self.tail_default:
            return np.asarray(chunks, dtype=np.int64)
        from repro.core.slab_policy import schedule_with_default_tail
        return schedule_with_default_tail(chunks,
                                          page_size=self.pool.page_size)

    def _maybe_refit_tenant(self, t: _Tenant) -> None:
        decision = t.controller.maybe_refit(
            cost_bytes_fn=lambda c: t.allocator.migration_cost_bytes(
                self._deploy_schedule(c)))
        if decision is not None and decision.approved:
            deployed = self._deploy_schedule(decision.chunks)
            t.allocator.reconfigure(deployed)
            t.controller.set_chunks(deployed)

    # -- arbitration ---------------------------------------------------------
    def _refresh_pressure(self) -> None:
        page_size = self.pool.page_size
        for t in self.tenants.values():
            ev = t.allocator.evicted_bytes - t.evicted_bytes0
            dn = t.allocator.n_page_denials - t.denials0
            # evicted payload measures what was lost, denial mass the
            # capacity shortfall; both terms always count so a tiny
            # eviction can never zero out a heavily-denied tenant
            t.pressure = float(ev) + float(dn) * page_size

    def _reset_window(self) -> None:
        for t in self.tenants.values():
            t.evicted_bytes0 = t.allocator.evicted_bytes
            t.denials0 = t.allocator.n_page_denials

    def _donor_release_cost(self, t: _Tenant) -> Optional[float]:
        """Predicted cost of the donor's cheapest reclaimable page, or
        None when the tenant has nothing it may give (no page above its
        floor). The number comes from the tenant allocator's eviction
        policy (``page_release_cost_bytes`` →
        ``EvictionPolicy.page_reclaim_cost_bytes``): under cost-aware
        policies a page full of never-re-referenced residents prices
        near zero, so reclaimed pages come from the least-valuable
        residents fleet-wide — not merely the fewest-bytes page."""
        rec = self.pool._tenants[t.name]
        if rec.quota is None or rec.quota - 1 < rec.floor:
            return None         # unmanaged or at floor: may not donate
        if rec.owned < rec.quota:
            return 0            # unexercised quota: giving it away is free
        return t.allocator.page_release_cost_bytes()

    def arbitrate(self) -> List[TransferDecision]:
        """One arbitration round; returns this round's decisions."""
        self._since_arbitrate = 0
        self._refresh_pressure()
        round_decisions: List[TransferDecision] = []
        page_size = self.pool.page_size
        names = sorted(self.tenants)
        for _ in range(self.max_transfers_per_round):
            recipient = max(
                (self.tenants[n] for n in names),
                key=lambda t: t.pressure)
            if recipient.pressure <= 0.0:
                break    # nobody is starved; no decision to record
            benefit = (min(recipient.pressure, float(page_size))
                       * self.amortization_windows)
            # cheapest donor that may give a page (floor respected)
            donor = None
            donor_cost: Optional[int] = None
            for n in names:
                t = self.tenants[n]
                if t is recipient:
                    continue
                c = self._donor_release_cost(t)
                if c is None:
                    continue
                if donor_cost is None or c < donor_cost or (
                        c == donor_cost and t.pressure < donor.pressure):
                    donor, donor_cost = t, c
            if donor is None:
                # nobody may donate: every other tenant is unmanaged,
                # at its floor, or holds nothing — the starvation guard
                round_decisions.append(self._decide(
                    False, "no-eligible-donor", None, recipient.name,
                    benefit, 0.0))
                break
            cost = self.cost_weight * float(donor_cost)
            if benefit <= cost:
                round_decisions.append(self._decide(
                    False, "cost-exceeds-benefit", donor.name,
                    recipient.name, benefit, cost))
                break
            # execute: quota follows the page; the donor's cheapest page
            # goes back to the shared free pool for the recipient to
            # grab on its next demand
            self.pool.move_quota(donor.name, recipient.name, 1)
            evicted_items = evicted_bytes = 0
            if self.pool.owned(donor.name) > self.pool.quota(donor.name):
                evicted_items, evicted_bytes = donor.allocator.release_page()
            self.n_transfers += 1
            round_decisions.append(self._decide(
                True, "transfer", donor.name, recipient.name, benefit,
                cost, evicted_items=evicted_items,
                evicted_bytes=evicted_bytes))
            recipient.pressure = max(
                0.0, recipient.pressure - float(page_size))
        self._reset_window()
        return round_decisions

    def _decide(self, approved: bool, reason: str, donor: Optional[str],
                recipient: Optional[str], benefit: float, cost: float, *,
                evicted_items: int = 0, evicted_bytes: int = 0
                ) -> TransferDecision:
        d = TransferDecision(approved=approved, reason=reason, donor=donor,
                             recipient=recipient, benefit=benefit, cost=cost,
                             evicted_items=evicted_items,
                             evicted_bytes=evicted_bytes, at_op=self.n_ops)
        self.decisions.append(d)
        return d

    # -- measurement ---------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        """Per-tenant snapshot: pages owned/quota plus allocator stats."""
        out = {}
        for name, t in self.tenants.items():
            st = t.allocator.stats()
            out[name] = {
                "pages_owned": self.pool.owned(name),
                "quota": self.pool.quota(name),
                "n_resident": st.n_resident,
                "item_bytes": st.item_bytes,
                "waste": st.waste,
                "n_evicted": st.n_evicted,
                "evicted_bytes": st.evicted_bytes,
                "n_page_denials": st.n_page_denials,
                "n_refits": t.controller.n_refits,
                "migration_evictions": st.migration_evictions,
                "evicted_hot_bytes": st.evicted_hot_bytes,
                "reused_after_evict": st.reused_after_evict,
                "eviction_policy": st.eviction_policy,
            }
        return out
