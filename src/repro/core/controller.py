"""SlabController — the online half of the paper's loop.

The paper closes a loop: *analyse the pattern of sizes previously
entered, then re-configure the slab classes*. `SlabController` is that
loop as a reusable component shared by every allocator in this repo
(`repro.memcached.SlabAllocator`, `repro.serving.KVSlabPool`,
`repro.data` bucketing): it owns the live traffic sketch
(:class:`~repro.core.observe.DecayedSizeHistogram`), detects when the
schedule has gone stale (drift of the sketch vs. the fitting-time
reference histogram), and decides whether a refit pays for itself before
approving one.

Decision pipeline, run every ``check_every`` observations:

1. **drift gate** — ``histogram_distance(reference, live)`` must exceed
   ``drift_threshold`` (hysteresis part 1: small wobbles never trigger).
2. **cooldown** — at least ``min_items_between_refits`` observations must
   have passed since the last approved refit (hysteresis part 2: no
   refit storms while a phase transition is in flight).
3. **candidate frontier** — refit via ``SlabPolicy`` on the live sketch,
   then score {current, refit, covering-default} schedules in ONE batched
   evaluation through the Pallas kernel ``repro.kernels.ops.waste_eval``
   (compiled on TPU, interpret elsewhere), keeping the scoring hot path
   on-device.
4. **improvement gate** — the winner must beat the current schedule by
   ``min_rel_improvement`` (hysteresis part 3: ignore marginal wins).
5. **cost model** — reconfiguring a live cache is not free: the consumer
   reports predicted migration/eviction bytes via ``cost_bytes_fn`` (for
   `SlabAllocator.reconfigure` that is the resident bytes of victim
   classes). The refit is approved only when the predicted waste savings
   over ``amortization_windows`` sketch-windows of future traffic exceed
   ``cost_weight`` times that cost.

Approved refits update the controller's schedule and reset the reference
histogram to the fitting snapshot; the *consumer* applies the new chunks
to its own storage (`reconfigure` / `set_classes`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.analysis.guards import deliberate_sync
from repro.analysis.registry import hot_path
from repro.core.distribution import PAGE_SIZE
from repro.core.observe import (DecayedSizeHistogram, DeviceSizeSketch,
                                histogram_distance,
                                histogram_distance_device)


@dataclasses.dataclass
class ControllerConfig:
    """Knobs of the observe → detect → refit → reconfigure loop."""

    k: Optional[int] = None              # class budget (None: len(chunks))
    check_every: int = 2000              # observations between drift checks
    half_life: Optional[float] = None    # sketch half-life in observations
    #                                      (None: 2*check_every; inf: no decay)
    drift_threshold: float = 0.15        # min distance to consider a refit
    drift_metric: str = "l1"             # "l1" | "emd"
    min_items_between_refits: int = 4000  # cooldown after an approved refit
    min_rel_improvement: float = 0.02    # winner must beat current by this
    # The cost model compares two different kinds of bytes: predicted
    # waste savings accrue over ``amortization_windows`` sketch-masses of
    # FUTURE traffic (memory held hole-free, again and again), while
    # migration cost is paid ONCE (victims are evicted and at worst
    # refetched — and under drifted traffic the victim classes hold the
    # stale distribution, whose re-reference probability is low).
    # ``cost_weight`` is the explicit exchange rate; 1.0 treats one
    # evicted byte as as expensive as one never-saved waste byte
    # (maximally refit-averse), drift scenarios where old items go cold
    # typically want 0.05-0.25.
    amortization_windows: float = 4.0    # future windows that repay the cost
    cost_weight: float = 1.0             # migration byte : waste byte rate
    method: str = "dp"                   # SlabPolicy fit method
    page_size: int = PAGE_SIZE
    min_chunk: int = 48
    align: int = 1                       # chunk quantization grid (tokens/B)
    max_bins: int = 1 << 14              # sketch bin budget
    # Device-resident observe path: the sketch is a DeviceSizeSketch
    # (dense decayed bucket histogram updated by the Pallas sketch_update
    # kernel, one launch per observe_many batch) and the drift gate runs
    # on device via histogram_distance_device — the sketch is only
    # materialized on host when a refit is actually being evaluated.
    device: bool = False                 # device-resident observe sketch
    device_buckets: int = 1 << 13        # dense bucket count
    device_bucket_width: int = 1         # bucket grid (serving: align)
    # Single-launch observe windows: observe_many batches buffer on
    # host and the whole cadence window folds into the sketch in ONE
    # fused dispatch at the drift check — which also emits the drift
    # scalar, so a window costs 1 dispatch + (at most) 1 scalar sync.
    # False restores the one-launch-per-batch device path.
    fused_observe: bool = True           # device path: buffer + fuse
    # Predictive refit seam: a DemandForecaster makes the drift gate
    # fire on the FORECAST mixture — when the live sketch is still
    # covered but the forecaster (periodicity detected over the ring of
    # per-check sketch snapshots) says the mixture at +forecast_horizon
    # checks has drifted past the threshold, candidate schedules are
    # scored against a live/forecast blend and the winner is
    # pre-positioned before the peak. None or forecast.Reactive keeps
    # today's reactive behaviour bit-for-bit (no recording, no extra
    # syncs, identical decisions). Anti-thrash hysteresis: predictive
    # refits share the cooldown, must clear min_rel_improvement on the
    # BLEND (a wrong forecast is diluted by the live half), and need
    # forecast_min_confidence autocorrelation.
    forecast: Optional[object] = None    # DemandForecaster | Reactive | None
    forecast_horizon: int = 1            # checks of lead time
    forecast_min_confidence: float = 0.35  # autocorr gate for predictive
    forecast_blend: float = 0.5          # forecast share of scoring mixture
    forecast_stream: Optional[str] = None  # stream key in a shared forecaster


@dataclasses.dataclass
class RefitDecision:
    """One drift-check verdict (returned whether or not a refit happened)."""

    approved: bool
    reason: str                      # "refit" | why it was declined
    drift: float
    chunks: Optional[np.ndarray]     # winning schedule (approved or not)
    current_waste: int               # exact waste of current chunks on sketch
    candidate_waste: int             # exact waste of winner on sketch
    predicted_savings: float         # bytes saved over amortization horizon
    predicted_cost: float            # weighted migration bytes
    at_observation: int              # controller clock when decided
    predictive: bool = False         # decided on the FORECAST mixture
    forecast_drift: float = 0.0      # distance(reference, forecast mixture)


@dataclasses.dataclass
class ScoreRequest:
    """A candidate frontier whose gates all passed, waiting for waste
    scores — the seam that lets :class:`~repro.core.arbiter.TenantArbiter`
    batch many tenants' frontiers into one ``waste_eval`` launch.

    Produced by :meth:`SlabController.begin_check`; hand the scores for
    ``rows`` (row 0 is the current schedule) to
    :meth:`SlabController.finish_check` to complete the decision.
    """

    rows: List[np.ndarray]           # candidate schedules, row 0 = current
    support: np.ndarray              # histogram the frontier is scored on
    freqs: np.ndarray
    page_size: int
    drift: float
    cost_bytes_fn: Optional[Callable[[np.ndarray], float]]
    predictive: bool = False
    forecast_drift: float = 0.0
    new_reference: object = None     # blend reference (predictive path)


def device_sketch_kwargs(config: ControllerConfig) -> dict:
    """The :class:`~repro.core.observe.DeviceSizeSketch` constructor
    kwargs a controller with ``config`` uses — shared with
    :meth:`repro.core.fleet.FleetState.sketch_view` so a fleet-stacked
    sketch row is configured exactly like a solo controller's sketch."""
    half_life = config.half_life
    if half_life is None:
        half_life = 2.0 * config.check_every
    if not np.isfinite(half_life):
        half_life = None        # undecayed: full-history histogram
    return dict(half_life=half_life, num_buckets=config.device_buckets,
                bucket_width=config.device_bucket_width,
                window=config.fused_observe)


def _quantize_up(chunks: np.ndarray, align: int) -> np.ndarray:
    chunks = np.asarray(chunks, dtype=np.int64)
    if align > 1:
        chunks = ((chunks + align - 1) // align) * align
    return np.unique(chunks)


def _pad_rows(rows: List[np.ndarray]) -> np.ndarray:
    """Stack schedules of different lengths into one (B, K) batch by
    repeating each row's top chunk — duplicate classes are waste-neutral,
    so padding does not change any row's score."""
    k = max(len(r) for r in rows)
    out = np.empty((len(rows), k), dtype=np.int64)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
        out[i, len(r):] = r[-1]
    return out


def _score_frontier(rows: List[np.ndarray], support: np.ndarray,
                    freqs: np.ndarray, *, page_size: int) -> np.ndarray:
    """One batched waste evaluation of the candidate frontier.

    Prefers the Pallas kernel (compiled on TPU, interpret elsewhere);
    falls back to the vmapped jnp oracle if the kernel stack is
    unavailable (e.g. a CPU wheel without pallas support).
    """
    batch = _pad_rows(rows)
    try:
        from repro.kernels.ops import waste_eval
        scores = waste_eval(batch, support, freqs, page_size=page_size)
    except Exception:  # pragma: no cover - kernel stack unavailable
        from repro.core.waste import waste_batch_jax
        scores = waste_batch_jax(batch, support, freqs, page_size=page_size)
    with deliberate_sync("controller.frontier-scores"):
        return np.asarray(scores, dtype=np.float64)


def score_requests(reqs: List["ScoreRequest"]) -> List[np.ndarray]:
    """Score several candidate frontiers — each against its OWN
    histogram — in ONE batched ``waste_eval_fleet`` launch.

    All requests must share ``page_size`` (a static kernel parameter;
    the arbiter groups by it). Padding is score-neutral: schedules pad
    by repeating their top chunk (duplicate classes are waste-neutral),
    histograms pad with size-0/freq-0 buckets (zero waste contribution)
    — so each request's scores are exactly what its own
    :func:`_score_frontier` launch would produce.
    """
    page_size = reqs[0].page_size
    if any(r.page_size != page_size for r in reqs):
        raise ValueError("score_requests needs a uniform page_size")
    batches = [_pad_rows(r.rows) for r in reqs]
    kmax = max(b.shape[1] for b in batches)
    smax = max(r.support.size for r in reqs)
    rows_out, sup_out, frq_out, splits = [], [], [], []
    for r, b in zip(reqs, batches):
        if b.shape[1] < kmax:
            b = np.concatenate(
                [b, np.repeat(b[:, -1:], kmax - b.shape[1], axis=1)], axis=1)
        sup = np.zeros(smax, dtype=np.int64)
        frq = np.zeros(smax, dtype=np.float64)
        sup[:r.support.size] = r.support
        frq[:r.freqs.size] = r.freqs
        rows_out.append(b)
        sup_out.append(np.broadcast_to(sup, (b.shape[0], smax)))
        frq_out.append(np.broadcast_to(frq, (b.shape[0], smax)))
        splits.append(b.shape[0])
    chunks = np.concatenate(rows_out, axis=0)
    supports = np.concatenate(sup_out, axis=0)
    freqs = np.concatenate(frq_out, axis=0)
    try:
        from repro.kernels.ops import waste_eval_fleet
        with deliberate_sync("controller.fleet-frontier-scores"):
            scores = np.asarray(waste_eval_fleet(chunks, supports, freqs,
                                                 page_size=page_size),
                                dtype=np.float64)
    except Exception:  # pragma: no cover - kernel stack unavailable
        return [_score_frontier(r.rows, r.support, r.freqs,
                                page_size=page_size) for r in reqs]
    out, at = [], 0
    for n in splits:
        out.append(scores[at:at + n])
        at += n
    return out


class SlabController:
    """Drift-aware refit controller over a live size sketch.

    One instance per allocator (or per tenant, under
    :class:`~repro.core.arbiter.TenantArbiter`): feed every observed
    size through :meth:`observe`/:meth:`observe_many`, call
    :meth:`maybe_refit` on the hot path (cheap between checks), and
    apply ``decision.chunks`` to your storage when a decision comes
    back approved. The full gate pipeline is described in the module
    docstring; every verdict is kept in ``self.decisions``.

    Attributes:
        chunks:    the schedule the controller currently believes in
                   (consumers re-sync via :meth:`set_chunks` after
                   quantizing/tailing the deployed schedule).
        sketch:    the live :class:`DecayedSizeHistogram`.
        reference: fitting-time histogram the drift detector compares
                   against (None until the first check adopts one) — a
                   ``(support, weights)`` pair on the host path, a dense
                   device weight vector when ``config.device`` is set.
        n_checks / n_refits / last_drift: loop telemetry.
    """

    def __init__(self, chunk_sizes, *,
                 config: Optional[ControllerConfig] = None,
                 policy=None,
                 reference: Optional[Tuple[np.ndarray, np.ndarray]] = None,
                 sketch=None):
        self.config = config or ControllerConfig()
        self.chunks = np.unique(np.asarray(chunk_sizes, dtype=np.int64))
        if self.chunks.size == 0:
            raise ValueError("need at least one slab class")
        self._device = bool(self.config.device)
        if sketch is not None:
            # Injected sketch (e.g. a FleetSketchView over a stacked
            # fleet row) — must match the config's path.
            self.sketch = sketch
        elif self._device:
            self.sketch = DeviceSizeSketch(**device_sketch_kwargs(
                self.config))
        else:
            half_life = device_sketch_kwargs(self.config)["half_life"]
            self.sketch = DecayedSizeHistogram(
                half_life=half_life, max_bins=self.config.max_bins)
        self._policy = policy
        # Predictive seam: with an active forecaster, every drift check
        # records the live sketch as one window of this controller's
        # stream; a Reactive (or absent) forecaster short-circuits every
        # forecast code path so the reactive pipeline is untouched.
        self.forecaster = self.config.forecast
        self._forecast_on = bool(getattr(self.forecaster, "active", False))
        self._stream = (self.config.forecast_stream
                        or f"controller-{id(self):x}")
        self.n_predictive_refits = 0
        # Fitting-time histogram the drift detector compares against.
        # None until the first check (or refit) establishes one.
        self.reference = reference
        self._since_check = 0
        self._last_refit_at = 0
        self.n_refits = 0
        self.n_checks = 0
        self.last_drift = 0.0
        self.decisions: List[RefitDecision] = []
        # External-event timeline: (observation clock, label) marks fed
        # by the torture harness (chaos injections) or an operator
        # (deploys, failovers). Purely diagnostic — never gates.
        self.events: List[Tuple[int, str]] = []

    # -- shared policy -------------------------------------------------------
    @property
    def policy(self):
        if self._policy is None:
            from repro.core.slab_policy import SlabPolicy
            self._policy = SlabPolicy(page_size=self.config.page_size,
                                      min_chunk=self.config.min_chunk)
        return self._policy

    @property
    def n_observed(self) -> int:
        return self.sketch.n_observed

    def set_chunks(self, chunk_sizes) -> None:
        """Sync the controller after the consumer adjusted the schedule
        out-of-band (e.g. alignment quantization)."""
        self.chunks = np.unique(np.asarray(chunk_sizes, dtype=np.int64))

    # -- external events -----------------------------------------------------
    def note_event(self, label: str) -> None:
        """Mark an external event (chaos injection, deploy, tenant
        churn) at the current observation clock. Events never change
        decisions; they let :meth:`forecast_miss_refits` attribute
        later refits to the shocks that forced them."""
        self.events.append((self.n_observed, label))

    def forecast_miss_refits(self, window: Optional[int] = None) -> int:
        """Approved **reactive** refits landing within ``window``
        observations after a noted event — refits the controller had to
        take *after* a shock it did not pre-position for (a predictive
        refit before the shock would not count). The torture bench
        reports the worst case of this across scenarios: it is the
        forecaster's miss rate under adversarial timing. ``window``
        defaults to two check cadences."""
        w = (2 * self.config.check_every if window is None
             else int(window))
        n = 0
        for d in self.decisions:
            if d.approved and not d.predictive:
                if any(at <= d.at_observation <= at + w
                       for at, _ in self.events):
                    n += 1
        return n

    # -- observe -------------------------------------------------------------
    @hot_path
    def observe(self, size: int) -> None:
        """Feed one observed item size into the live sketch. O(1)."""
        self.sketch.observe(size)
        self._since_check += 1

    @hot_path
    def observe_many(self, sizes, weights=None) -> None:
        """Feed a batch of sizes (one flat array) into the live sketch.

        On the device path ``sizes`` may be a device array straight out
        of a serve step — it is bucketed and folded into the resident
        sketch in one kernel launch, with no host round-trip.
        """
        if self._device:
            before = self.sketch.n_observed
            self.sketch.observe_many(sizes, weights)
            self._since_check += self.sketch.n_observed - before
        else:
            sizes = np.asarray(sizes).ravel()
            self.sketch.observe_many(sizes, weights)
            self._since_check += len(sizes)

    # -- detect + decide -----------------------------------------------------
    def _reference_now(self):
        """The live sketch in reference form: a dense device weight
        vector on the device path, a host (support, weights) pair
        otherwise."""
        if self._device:
            return self.sketch.weights_device
        return self.sketch.snapshot_weights()

    def drift(self) -> float:
        """Distance of the live sketch from the fitting-time reference."""
        if self.reference is None:
            return 0.0
        if self._device:
            self.sketch.n_scalar_syncs += 1
            with deliberate_sync("controller.drift-gate"):
                return float(histogram_distance_device(
                    self.reference, self.sketch.weights_device,
                    metric=self.config.drift_metric))
        return histogram_distance(self.reference,
                                  self.sketch.snapshot_weights(),
                                  metric=self.config.drift_metric)

    @property
    def check_due(self) -> bool:
        """True when the next :meth:`maybe_refit`/:meth:`begin_check`
        will actually run a drift check (the cadence is due)."""
        return self._since_check >= self.config.check_every

    @hot_path(counters=("n_checks",))
    def maybe_refit(self,
                    cost_bytes_fn: Optional[Callable[[np.ndarray], float]]
                    = None) -> Optional[RefitDecision]:
        """Run one drift check if the cadence is due.

        Returns ``None`` between checks; otherwise a :class:`RefitDecision`
        (``approved`` tells the caller whether to apply ``chunks``).
        """
        out = self.begin_check(cost_bytes_fn)
        if not isinstance(out, ScoreRequest):
            return out
        scores = _score_frontier(out.rows, out.support, out.freqs,
                                 page_size=out.page_size)
        return self.finish_check(out, scores)

    @hot_path(counters=("n_checks",))
    def begin_check(self,
                    cost_bytes_fn: Optional[Callable[[np.ndarray], float]]
                    = None, *, precomputed_drift: Optional[float] = None):
        """First half of a drift check: run every gate up to candidate
        scoring. Returns ``None`` (not due / nothing observed), a
        final :class:`RefitDecision` (a gate declined), or a
        :class:`ScoreRequest` the caller must score and pass to
        :meth:`finish_check` — the arbiter batches many tenants'
        requests into one ``waste_eval`` launch; :meth:`maybe_refit`
        scores a single request inline.

        ``precomputed_drift`` is the fleet seam: when the arbiter has
        already computed this controller's drift in a batched gate
        launch (``repro.kernels.fleet_gate.drift_gate_fleet`` over
        every due tenant at once), passing it here skips the solo
        distance computation — the rest of the pipeline runs
        unchanged. The caller is responsible for having flushed any
        buffered device window before computing the value it passes.
        """
        if self._since_check < self.config.check_every:
            return None
        self._since_check = 0
        self.n_checks += 1
        if self._device:
            # Fused device path: the whole cadence window of buffered
            # observe batches folds into the resident sketch in ONE
            # dispatch here, which also emits the drift distance vs the
            # resident reference — so the window costs one launch and
            # the gate costs one scalar readback. The sketch is
            # materialized solely when the drift+cooldown gates have
            # already passed.
            if self.sketch.n_observed == 0:
                return None
            drift_dev = None
            if self.reference is not None and precomputed_drift is None:
                drift_dev = self.sketch.flush_window(
                    reference=self.reference,
                    metric=self.config.drift_metric)
            else:
                self.sketch.flush_window()
            if self._forecast_on:
                self._record_window_device()
            if self.reference is None:
                self.reference = self.sketch.weights_device
                return None
            if precomputed_drift is not None:
                drift = float(precomputed_drift)
            elif drift_dev is None:
                drift = self.drift()    # nothing was buffered this window
            else:
                self.sketch.n_scalar_syncs += 1
                with deliberate_sync("controller.window-drift-gate"):
                    drift = float(drift_dev)
        else:
            live = self.sketch.snapshot_weights()
            if live[0].size == 0:
                return None
            if self._forecast_on:
                self.forecaster.record_window(
                    self._stream,
                    demand_bytes=float(np.dot(
                        live[0].astype(np.float64), live[1])),
                    support=live[0], weights=live[1])
            if self.reference is None:
                # First check: adopt the live sketch as the reference the
                # initial schedule is presumed fit to.
                self.reference = live
                return None
            drift = (float(precomputed_drift)
                     if precomputed_drift is not None
                     else histogram_distance(self.reference, live,
                                             metric=self.config.drift_metric))
        self.last_drift = drift
        if drift < self.config.drift_threshold:
            if self._forecast_on:
                # The live mixture is covered — exactly when a coming
                # peak is invisible to the reactive gate. Ask the
                # forecast whether the mixture at +horizon has drifted.
                predicted = self._maybe_predictive(drift, cost_bytes_fn)
                if predicted is not None:
                    return predicted
            return self._decide(False, "drift-below-threshold", drift)
        if (self.n_observed - self._last_refit_at
                < self.config.min_items_between_refits):
            return self._decide(False, "cooldown", drift)
        return self._frontier_request(drift, cost_bytes_fn)

    # -- predictive path (ControllerConfig.forecast) -------------------------
    def _record_window_device(self) -> None:
        """One forecast window from the device sketch: the dense weight
        vector by reference (functional updates make it a stable,
        zero-sync snapshot) plus the one demand scalar the periodicity
        detector needs (a scalar readback, counted like the drift
        gate's)."""
        jnp = self.sketch._jnp
        w = self.sketch.weights_device
        self.sketch.n_scalar_syncs += 1
        with deliberate_sync("controller.forecast-demand"):
            demand = float(jnp.sum(
                self.sketch.support_device.astype(jnp.float32) * w))
        self.forecaster.record_window(self._stream, demand_bytes=demand,
                                      device_weights=w)

    def _maybe_predictive(self, drift: float, cost_bytes_fn):
        """Fire the refit pipeline on the FORECAST mixture — returning
        a decision or a :class:`ScoreRequest` — or return ``None`` to
        fall through to the reactive hold. Gates, in order:
        a period must be detected with ``forecast_min_confidence``
        autocorrelation, the forecast mixture must exceed the same
        drift threshold, and the shared refit cooldown must be clear."""
        cfg = self.config
        fc = self.forecaster.predict(self._stream,
                                     horizon=cfg.forecast_horizon)
        if fc is None or fc.confidence < cfg.forecast_min_confidence:
            return None
        if self._device:
            if fc.device_weights is None:
                return None
            self.sketch.n_scalar_syncs += 1
            with deliberate_sync("controller.forecast-drift-gate"):
                fdrift = float(histogram_distance_device(
                    self.reference, fc.device_weights,
                    metric=cfg.drift_metric))
        else:
            if fc.support is None or fc.support.size == 0:
                return None
            fdrift = histogram_distance(self.reference,
                                        (fc.support, fc.weights),
                                        metric=cfg.drift_metric)
        if fdrift < cfg.drift_threshold:
            return None
        if (self.n_observed - self._last_refit_at
                < cfg.min_items_between_refits):
            return self._decide(False, "forecast-cooldown", drift,
                                predictive=True, forecast_drift=fdrift)
        return self._frontier_request(drift, cost_bytes_fn, forecast=fc,
                                      forecast_drift=fdrift)

    def _forecast_mixture(self, fc):
        """``(support, freqs, new_reference)`` of the live/forecast
        blend the predictive pipeline scores against. The reference
        form matches the path (host pair / dense device vector)."""
        cfg = self.config
        if self._device:
            jnp = self.sketch._jnp
            live = self.sketch.weights_device
            scale = jnp.sum(live) / jnp.maximum(
                jnp.sum(fc.device_weights), 1e-30)
            blend = ((1.0 - cfg.forecast_blend) * live
                     + cfg.forecast_blend * scale * fc.device_weights)
            self.sketch.n_host_syncs += 1      # materialized below
            with deliberate_sync("controller.forecast-mixture"):
                w = np.asarray(blend, dtype=np.float64)
            freqs = np.rint(w).astype(np.int64)
            keep = freqs > 0
            support = ((np.nonzero(keep)[0].astype(np.int64) + 1)
                       * self.sketch.bucket_width)
            return support, freqs[keep], blend
        from repro.core.forecast import blend_histograms
        live = self.sketch.snapshot_weights()
        bs, bw = blend_histograms(live, (fc.support, fc.weights),
                                  cfg.forecast_blend)
        freqs = np.rint(bw).astype(np.int64)
        keep = freqs > 0
        return bs[keep], freqs[keep], (bs, bw)

    def _frontier_request(self, drift: float, cost_bytes_fn, *,
                          forecast=None, forecast_drift: float = 0.0):
        """Build the candidate frontier once every gate up to scoring
        has passed: returns a :class:`ScoreRequest`, or a final
        :class:`RefitDecision` when there is nothing to score."""
        cfg = self.config
        predictive = forecast is not None
        if predictive:
            support, freqs, new_reference = self._forecast_mixture(forecast)
            if support.size == 0:
                return self._decide(False, "empty-forecast", drift,
                                    predictive=True,
                                    forecast_drift=forecast_drift)
        else:
            support, freqs = self.sketch.snapshot()
            new_reference = None
            if support.size == 0:
                return self._decide(False, "empty-sketch", drift)
        k = cfg.k or len(self.chunks)
        fitted = self.policy.fit(support, freqs, k, method=cfg.method,
                                 baseline=self.chunks)
        candidates = [self.chunks,
                      _quantize_up(fitted.chunk_sizes, cfg.align)]
        from repro.core.slab_policy import covering_default_classes
        defaults = _quantize_up(
            covering_default_classes(support, k=k, page_size=cfg.page_size),
            cfg.align)
        if defaults.size:
            candidates.append(defaults)
        return ScoreRequest(rows=candidates, support=support, freqs=freqs,
                            page_size=cfg.page_size, drift=drift,
                            cost_bytes_fn=cost_bytes_fn,
                            predictive=predictive,
                            forecast_drift=forecast_drift,
                            new_reference=new_reference)

    @hot_path(counters=("n_refits",))
    def finish_check(self, req: ScoreRequest,
                     scores: np.ndarray) -> RefitDecision:
        """Second half of a drift check: turn the waste ``scores`` of
        ``req.rows`` (however they were computed — inline or in a
        fleet-batched launch) into the final decision."""
        cfg = self.config
        drift = req.drift
        forecast_drift = req.forecast_drift
        predictive = req.predictive
        new_reference = req.new_reference
        cost_bytes_fn = req.cost_bytes_fn
        candidates = req.rows
        scores = np.asarray(scores, dtype=np.float64)
        best = int(np.argmin(scores[1:])) + 1   # best non-current candidate
        winner = candidates[best]
        # The frontier scores ARE the waste values (row 0 is the current
        # schedule; padding is waste-neutral) — float32 round-off is a
        # few bytes on ~1e8 totals, far inside the 2% hysteresis band.
        w_cur = int(round(scores[0]))
        w_new = int(round(scores[best]))
        rel = (w_cur - w_new) / max(w_cur, 1)
        if rel < cfg.min_rel_improvement:
            if predictive:
                # hysteresis part 2 of the predictive path: the current
                # schedule already serves the blend — the live reference
                # is NOT re-anchored (a declined forecast must never
                # blind the reactive gate to real drift later).
                return self._decide(False,
                                    "forecast-improvement-below-hysteresis",
                                    drift, chunks=winner, w_cur=w_cur,
                                    w_new=w_new, predictive=True,
                                    forecast_drift=forecast_drift)
            # The schedule is still (near-)optimal for current traffic:
            # re-anchor the reference so steady-state traffic that merely
            # *settled* far from the old fitting histogram stops
            # triggering a full candidate evaluation every check.
            self.reference = self._reference_now()
            return self._decide(False, "improvement-below-hysteresis", drift,
                                chunks=winner, w_cur=w_cur, w_new=w_new)
        # Savings accrue over future traffic (amortization_windows sketch
        # masses); migration cost is paid once, now.
        savings = float(w_cur - w_new) * cfg.amortization_windows
        cost = cfg.cost_weight * float(cost_bytes_fn(winner)
                                       if cost_bytes_fn else 0.0)
        if savings <= cost:
            return self._decide(False,
                                ("forecast-cost-exceeds-savings"
                                 if predictive else "cost-exceeds-savings"),
                                drift, chunks=winner, w_cur=w_cur,
                                w_new=w_new, savings=savings, cost=cost,
                                predictive=predictive,
                                forecast_drift=forecast_drift)
        self.chunks = winner
        if predictive:
            # Anchor to the BLEND: neither the live traffic that is
            # still here nor the forecast traffic that arrives on
            # schedule reads as full drift afterwards, so a correct
            # forecast cannot bounce the schedule back (hysteresis
            # part 3); the shared cooldown covers the wrong-forecast
            # case until the reactive gate sees the truth.
            self.reference = new_reference
            self.n_predictive_refits += 1
        else:
            self.reference = self._reference_now()
        self._last_refit_at = self.n_observed
        self.n_refits += 1
        return self._decide(True,
                            "refit-predictive" if predictive else "refit",
                            drift, chunks=winner, w_cur=w_cur, w_new=w_new,
                            savings=savings, cost=cost,
                            predictive=predictive,
                            forecast_drift=forecast_drift)

    def _decide(self, approved: bool, reason: str, drift: float, *,
                chunks: Optional[np.ndarray] = None, w_cur: int = 0,
                w_new: int = 0, savings: float = 0.0,
                cost: float = 0.0, predictive: bool = False,
                forecast_drift: float = 0.0) -> RefitDecision:
        d = RefitDecision(approved=approved, reason=reason, drift=drift,
                          chunks=chunks, current_waste=w_cur,
                          candidate_waste=w_new, predicted_savings=savings,
                          predicted_cost=cost,
                          at_observation=self.n_observed,
                          predictive=predictive,
                          forecast_drift=forecast_drift)
        self.decisions.append(d)
        return d

    # -- unconditional refit (manual / legacy cadence path) ------------------
    def refit_now(self, k: Optional[int] = None, *,
                  method: Optional[str] = None,
                  policy=None) -> np.ndarray:
        """Fit on the live sketch unconditionally and adopt the result.

        This is the legacy ``refit_every`` path and the manual-maintenance
        path; the drift/cost gates are bypassed by design.
        """
        support, freqs = self.sketch.snapshot()
        if support.size == 0:
            return self.chunks
        cfg = self.config
        pol = policy or self.policy
        sched = pol.fit(support, freqs, k or cfg.k or len(self.chunks),
                        method=method or cfg.method, baseline=self.chunks)
        self.chunks = _quantize_up(sched.chunk_sizes, cfg.align)
        self.reference = self._reference_now()
        self._last_refit_at = self.n_observed
        self.n_refits += 1
        return self.chunks
