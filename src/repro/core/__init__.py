"""Core: the paper's contribution — learning slab-class schedules.

Public surface:
    distribution — traffic models + the paper's Tables 1-5 operating points
    waste        — exact + JAX waste objectives
    hillclimb    — paper's Algorithm 1 + batched/parallel/multi-restart
    dp_optimal   — exact global optimum (tests the paper's §6.3 claim)
    anneal       — simulated-annealing variant
    slab_policy  — SlabPolicy / SlabSchedule, the composable API
    observe      — streaming decayed size sketch + drift distances
    forecast     — DemandForecaster / Reactive, the predictive seam
    controller   — SlabController, the online observe→detect→refit loop
    arbiter      — ResourcePool/PagePool + TenantArbiter, cross-tenant
                   resource arbitration (pages, KV token quotas)
    fleet        — FleetState, the per-tenant arbiter state stacked
                   into [n_tenants, ...] arrays (TenantArbiter(fleet=True))
"""
from repro.core.distribution import (PAGE_SIZE, PAPER_N_ITEMS,
                                     PAPER_WORKLOADS, PaperWorkload,
                                     dense_histogram,
                                     lognormal_params_from_moments,
                                     merge_histograms,
                                     sample_lognormal_sizes,
                                     sample_multimodal_sizes,
                                     size_histogram)
from repro.core.dp_optimal import DPResult, dp_optimal, dp_optimal_bruteforce
from repro.core.hillclimb import (MIN_CHUNK, SearchResult, multi_restart,
                                  paper_hillclimb, parallel_hillclimb)
from repro.core.anneal import anneal
from repro.core.slab_policy import (SlabPolicy, SlabSchedule,
                                    covering_default_classes,
                                    default_memcached_schedule,
                                    schedule_with_default_tail)
from repro.core.waste import (default_waste_fraction, per_class_waste_exact,
                              uncovered_charge, utilization_exact,
                              waste_batch_jax, waste_exact, waste_jax)
from repro.core.observe import (DecayedSizeHistogram, DeviceSizeSketch,
                                histogram_distance,
                                histogram_distance_device)
from repro.core.forecast import (DemandForecaster, Forecast, Reactive,
                                 acf_period_batch, blend_histograms)
from repro.core.controller import (ControllerConfig, RefitDecision,
                                   SlabController)
from repro.core.arbiter import (PagePool, ResourcePool, TenantArbiter,
                                TenantPages, TransferDecision)
from repro.core.fleet import FleetSketchView, FleetState


def __getattr__(name):
    if name == "StreamingSizeSketch":   # removed alias, see observe.py
        from repro.core import observe
        return observe.StreamingSizeSketch
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "PAGE_SIZE", "PAPER_N_ITEMS", "PAPER_WORKLOADS", "PaperWorkload",
    "dense_histogram", "lognormal_params_from_moments", "merge_histograms",
    "sample_lognormal_sizes", "sample_multimodal_sizes", "size_histogram",
    "DPResult", "dp_optimal", "dp_optimal_bruteforce",
    "MIN_CHUNK", "SearchResult", "multi_restart", "paper_hillclimb",
    "parallel_hillclimb", "anneal",
    "SlabPolicy", "SlabSchedule", "covering_default_classes",
    "default_memcached_schedule", "schedule_with_default_tail",
    "default_waste_fraction", "per_class_waste_exact", "uncovered_charge",
    "utilization_exact", "waste_batch_jax", "waste_exact", "waste_jax",
    "DecayedSizeHistogram", "DeviceSizeSketch",
    "histogram_distance", "histogram_distance_device",
    "DemandForecaster", "Forecast", "Reactive", "acf_period_batch",
    "blend_histograms",
    "ControllerConfig", "RefitDecision", "SlabController",
    "PagePool", "ResourcePool", "TenantArbiter", "TenantPages",
    "TransferDecision", "FleetSketchView", "FleetState",
]
