"""Exact global optimum for the slab-class problem, by dynamic programming.

This is a beyond-paper contribution used to *test* the paper's §6.3 claim
that its greedy search "converges to a global minimum".

Key observation: in an optimal schedule every chunk size can be lowered to
the largest item size it actually covers without increasing waste, so the
optimal chunks can be drawn from the observed support ``s_1 < ... < s_S``.
With boundaries ``0 = j_0 <= j_1 <= ... <= j_K = S`` (class t has chunk
``s_{j_t}`` and covers sizes ``s_{j_{t-1}+1} .. s_{j_t}``):

    cost(i, j) = s_j * (F_j - F_i) - (M_j - M_i)
    dp[t][j]   = min_{i <= j} dp[t-1][i] + cost(i, j)

where F/M are prefix sums of freq and freq*size. The inner minimisation is
over lines ``y_i(x) = -F_i * x + (dp[t-1][i] + M_i)`` evaluated at
``x = s_j``; slopes are strictly decreasing in i and queries strictly
increasing in j, so a monotone convex-hull-trick gives O(K*S) exact
(arbitrary-precision int) time. A O(K*S^2) numpy brute force is kept as a
cross-check oracle for tests.

The top class is pinned to ``s_S`` by construction, so every item is
storable — the same constraint the waste objective enforces by penalty.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.distribution import PAGE_SIZE
from repro.core.waste import waste_exact


@dataclasses.dataclass(frozen=True)
class DPResult:
    chunks: np.ndarray   # distinct optimal chunk sizes, sorted (len <= k)
    waste: int           # exact optimal waste (bytes)
    k: int               # class budget requested


def _prefix_sums(support: np.ndarray, freqs: np.ndarray
                 ) -> Tuple[List[int], List[int]]:
    f = [0] * (len(support) + 1)
    m = [0] * (len(support) + 1)
    for i, (s, fr) in enumerate(zip(support.tolist(), freqs.tolist()), 1):
        f[i] = f[i - 1] + fr
        m[i] = m[i - 1] + fr * s
    return f, m


def dp_optimal(support, freqs, k: int) -> DPResult:
    """Exact minimum-waste schedule with at most ``k`` classes."""
    support = np.asarray(support, dtype=np.int64)
    freqs = np.asarray(freqs, dtype=np.int64)
    order = np.argsort(support)
    support, freqs = support[order], freqs[order]
    if np.any(freqs <= 0):
        keep = freqs > 0
        support, freqs = support[keep], freqs[keep]
    s_count = len(support)
    if s_count == 0:
        return DPResult(np.array([], dtype=np.int64), 0, k)
    k_eff = min(k, s_count)

    f_pre, m_pre = _prefix_sums(support, freqs)
    xs = support.tolist()

    inf = float("inf")
    dp_prev: List = [0] + [inf] * s_count
    parents: List[List[int]] = []

    for _t in range(k_eff):
        dp_cur: List = [inf] * (s_count + 1)
        parent = [0] * (s_count + 1)
        dp_cur[0] = dp_prev[0]
        # Monotone CHT: lines (m=-F_i, c=dp_prev[i]+M_i), slopes strictly
        # decreasing in i; queries x = s_j strictly increasing in j.
        hull: List[Tuple[int, int, int]] = []  # (slope, intercept, i)
        ptr = 0

        def add_line(i: int) -> None:
            nonlocal ptr
            if dp_prev[i] == inf:
                return
            m_new, c_new = -f_pre[i], int(dp_prev[i]) + m_pre[i]
            while len(hull) >= 2:
                m1, c1, _ = hull[-2]
                m2, c2, _ = hull[-1]
                # hull[-1] dominated by hull[-2] and the new line?
                if (c_new - c1) * (m1 - m2) <= (c2 - c1) * (m1 - m_new):
                    hull.pop()
                else:
                    break
            # Equal slopes can only happen via duplicate i; keep the lower c.
            if hull and hull[-1][0] == m_new:
                if hull[-1][1] <= c_new:
                    return
                hull.pop()
            hull.append((m_new, c_new, i))
            ptr = min(ptr, len(hull) - 1)

        add_line(0)
        for j in range(1, s_count + 1):
            add_line(j)  # i = j (empty class) is a legal predecessor
            x = xs[j - 1]
            if hull:
                while (ptr + 1 < len(hull)
                       and hull[ptr + 1][0] * x + hull[ptr + 1][1]
                       <= hull[ptr][0] * x + hull[ptr][1]):
                    ptr += 1
                m_b, c_b, i_b = hull[ptr]
                base = m_b * x + c_b
                dp_cur[j] = x * f_pre[j] - m_pre[j] + base
                parent[j] = i_b
        parents.append(parent)
        dp_prev = dp_cur

    # Backtrack boundaries; drop empty classes (duplicate boundaries).
    boundaries = []
    j = s_count
    for t in range(k_eff - 1, -1, -1):
        boundaries.append(j)
        j = parents[t][j]
    boundaries = sorted(set(b for b in boundaries if b > 0))
    chunks = np.array([xs[b - 1] for b in boundaries], dtype=np.int64)
    waste = waste_exact(chunks, support, freqs, page_size=PAGE_SIZE)
    expected = dp_prev[s_count]
    assert waste == expected, (
        f"DP internal inconsistency: backtracked {waste} != dp {expected}")
    return DPResult(chunks=chunks, waste=int(waste), k=k)


def dp_optimal_bruteforce(support, freqs, k: int) -> DPResult:
    """O(K*S^2) reference (numpy int64); for tests on small supports."""
    support = np.asarray(support, dtype=np.int64)
    freqs = np.asarray(freqs, dtype=np.int64)
    order = np.argsort(support)
    support, freqs = support[order], freqs[order]
    s_count = len(support)
    if s_count == 0:
        return DPResult(np.array([], dtype=np.int64), 0, k)
    k_eff = min(k, s_count)
    f_pre = np.concatenate([[0], np.cumsum(freqs)])
    m_pre = np.concatenate([[0], np.cumsum(freqs * support)])

    big = np.iinfo(np.int64).max // 4
    # cost[i, j] for 0 <= i <= j <= S
    jj = np.arange(s_count + 1)
    s_at = np.concatenate([[0], support])           # s_j for j >= 1
    cost = (s_at[None, :] * (f_pre[None, :] - f_pre[:, None])
            - (m_pre[None, :] - m_pre[:, None]))
    cost = np.where(jj[None, :] >= jj[:, None], cost, big)

    dp = np.full(s_count + 1, big, dtype=np.int64)
    dp[0] = 0
    parent = np.zeros((k_eff, s_count + 1), dtype=np.int64)
    for t in range(k_eff):
        tot = dp[:, None] + cost
        parent[t] = np.argmin(tot, axis=0)
        dp = np.min(tot, axis=0)

    boundaries = []
    j = s_count
    for t in range(k_eff - 1, -1, -1):
        boundaries.append(j)
        j = int(parent[t][j])
    boundaries = sorted(set(b for b in boundaries if b > 0))
    chunks = np.array([support[b - 1] for b in boundaries], dtype=np.int64)
    return DPResult(chunks=chunks,
                    waste=waste_exact(chunks, support, freqs,
                                      page_size=PAGE_SIZE), k=k)
