"""Item-size traffic distributions and histograms.

The paper drives Memcached with log-normal item-size traffic at five
(mu, sigma) operating points (its Tables 1-5). Back-solving the tables
(see DESIGN.md §1) pins the parameterisation as the *byte-space moments*
of the distribution and ~1e6 items per run. We expose both the byte-moment
parameterisation (primary) and a log-space one (sensitivity check).

Histograms are the interface between traffic and the optimizer: the waste
objective only needs (sizes, freqs) of the observed support.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

PAGE_SIZE = 1 << 20  # 1 MB, memcached's page / max-item size


@dataclasses.dataclass(frozen=True)
class PaperWorkload:
    """One operating point from the paper's Tables 1-5."""

    table: int
    mu: float                    # mean item size, bytes
    sigma: float                 # std of item size, bytes (byte-moment reading)
    old_chunks: Tuple[int, ...]  # "Available Chunk Sizes", old configuration
    new_chunks: Tuple[int, ...]  # paper's learned configuration
    old_waste: int               # bytes, as reported
    new_waste: int               # bytes, as reported

    @property
    def recovered_frac(self) -> float:
        return 1.0 - self.new_waste / self.old_waste


PAPER_WORKLOADS: Tuple[PaperWorkload, ...] = (
    PaperWorkload(1, 518.0, 10.5, (304, 384, 480, 600, 752, 944),
                  (461, 510, 557, 614, 702, 943), 62_013_552, 32_809_986),
    PaperWorkload(2, 1210.0, 15.8, (944, 1184, 1480, 1856),
                  (1173, 1280, 1414, 1735), 147_403_935, 74_979_930),
    PaperWorkload(3, 2109.0, 16.6, (1856, 2320, 2904),
                  (2120, 2287, 2643), 230_144_462, 111_980_981),
    PaperWorkload(4, 4133.0, 15.8, (4544, 5680),
                  (4246, 4644), 410_568_873, 181_599_689),
    PaperWorkload(5, 8131.0, 15.2, (8880,),
                  (8628,), 748_193_597, 496_353_869),
)

PAPER_N_ITEMS = 1_000_000


def lognormal_params_from_moments(mean, std):
    """(mu_log, sigma_log) of a LogNormal with the given byte-space moments.

    Accepts scalars (returns floats) or same-shape arrays (returns
    arrays) — the non-stationary traffic generators interpolate the
    moments per item.
    """
    mean = np.asarray(mean, dtype=np.float64)
    std = np.asarray(std, dtype=np.float64)
    if np.any(mean <= 0):
        raise ValueError(f"mean must be positive, got {mean}")
    var_ratio = (std / mean) ** 2
    sigma_log = np.sqrt(np.log1p(var_ratio))
    mu_log = np.log(mean) - 0.5 * sigma_log**2
    if mu_log.ndim == 0:
        return float(mu_log), float(sigma_log)
    return mu_log, sigma_log


def sample_lognormal_sizes(
    rng: np.random.Generator,
    n: int,
    mean: float,
    std: float,
    *,
    min_size: int = 1,
    max_size: int = PAGE_SIZE,
    log_space_sigma: bool = False,
) -> np.ndarray:
    """Integer item sizes from a log-normal.

    ``log_space_sigma=True`` reads ``std`` as sigma/100 of the underlying
    normal (the alternative reading of the paper's tables; see DESIGN.md).
    """
    if log_space_sigma:
        mu_log, sigma_log = float(np.log(mean)), std / 100.0
    else:
        mu_log, sigma_log = lognormal_params_from_moments(mean, std)
    raw = rng.lognormal(mean=mu_log, sigma=sigma_log, size=n)
    return np.clip(np.rint(raw), min_size, max_size).astype(np.int64)


def sample_multimodal_sizes(
    rng: np.random.Generator,
    n: int,
    modes: Tuple[Tuple[float, float, float], ...],
    *,
    min_size: int = 1,
    max_size: int = PAGE_SIZE,
) -> np.ndarray:
    """Mixture of log-normals: modes = ((weight, mean, std), ...).

    Used to *test* the paper's §6.3 global-convergence claim — multimodal
    traffic is where greedy ±1-byte walks can strand classes between modes.
    """
    weights = np.array([m[0] for m in modes], dtype=np.float64)
    weights = weights / weights.sum()
    counts = rng.multinomial(n, weights)
    parts = [
        sample_lognormal_sizes(rng, int(c), mean, std,
                               min_size=min_size, max_size=max_size)
        for c, (_, mean, std) in zip(counts, modes)
    ]
    sizes = np.concatenate(parts)
    rng.shuffle(sizes)
    return sizes


def size_histogram(sizes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(support, freqs): sorted unique sizes and their counts, int64."""
    support, freqs = np.unique(np.asarray(sizes, dtype=np.int64),
                               return_counts=True)
    return support.astype(np.int64), freqs.astype(np.int64)


def dense_histogram(sizes: np.ndarray, max_size: int | None = None
                    ) -> np.ndarray:
    """freqs[s] = count of items of size s, for s in [0, max_size]."""
    sizes = np.asarray(sizes, dtype=np.int64)
    if max_size is None:
        max_size = int(sizes.max())
    return np.bincount(sizes, minlength=max_size + 1).astype(np.int64)


def merge_histograms(a, b) -> Tuple[np.ndarray, np.ndarray]:
    """Merge two (support, freqs) histograms (e.g. from different shards)."""
    sa, fa = a
    sb, fb = b
    support = np.union1d(sa, sb)
    freqs = np.zeros_like(support)
    freqs[np.searchsorted(support, sa)] += fa
    freqs[np.searchsorted(support, sb)] += fb
    return support, freqs
