"""The waste objective: internal fragmentation of a slab-class schedule.

An item of size ``s`` is stored in the smallest chunk ``c_j >= s``; the
memory hole is ``c_j - s``. Items larger than the largest chunk cannot be
stored at all in Memcached; the optimizer must be discouraged from
uncovering them, so they are charged as if they consumed whole pages:
``ceil(s / page_size) * page_size - s`` extra bytes (at least one page).
For ``s <= page_size`` this is the classic full-page charge
``page_size - s``; for larger items the charge stays non-negative, so a
schedule that covers nothing can never score better than one that covers
everything. Any covering configuration is strictly better, which keeps
the top class above the observed maximum, matching Memcached's real
constraint.

Two implementations:

* ``waste_exact`` — numpy int64, bit-exact; used for all *reported* numbers
  and by the DP optimizer.
* ``waste_jax`` / ``waste_batch_jax`` — float32 JAX, jit/vmap-able; used
  inside search loops. float32 round-off on ~1e8-byte totals is <= a few
  bytes and deterministic for a fixed summation order; the paper's accept
  rule already tolerates neutral moves, so this cannot destabilise the
  search (see DESIGN.md). Final schedules are always re-scored with
  ``waste_exact``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distribution import PAGE_SIZE


def uncovered_charge(support, *, page_size: int = PAGE_SIZE) -> np.ndarray:
    """Waste charged to sizes no chunk covers: ``ceil(s/page)`` whole
    pages (at least one) minus the item bytes — always >= 0."""
    support = np.asarray(support, dtype=np.int64)
    pages = np.maximum(-(-support // page_size), 1)
    return pages * page_size - support


def waste_exact(chunks, support, freqs, *, page_size: int = PAGE_SIZE) -> int:
    """Exact total waste in bytes (numpy int64)."""
    chunks = np.sort(np.asarray(chunks, dtype=np.int64))
    support = np.asarray(support, dtype=np.int64)
    freqs = np.asarray(freqs, dtype=np.int64)
    idx = np.searchsorted(chunks, support, side="left")
    storable = idx < chunks.shape[0]
    assigned = chunks[np.minimum(idx, chunks.shape[0] - 1)]
    per_size = np.where(storable, assigned - support,
                        uncovered_charge(support, page_size=page_size))
    return int(np.sum(per_size * freqs))


def utilization_exact(chunks, support, freqs, *,
                      page_size: int = PAGE_SIZE) -> float:
    """Fraction of allocated chunk bytes that hold item bytes."""
    chunks = np.sort(np.asarray(chunks, dtype=np.int64))
    support = np.asarray(support, dtype=np.int64)
    freqs = np.asarray(freqs, dtype=np.int64)
    idx = np.searchsorted(chunks, support, side="left")
    storable = idx < chunks.shape[0]
    pages = np.maximum(-(-support // page_size), 1)
    assigned = np.where(storable, chunks[np.minimum(idx, len(chunks) - 1)],
                        pages * page_size)
    alloc = int(np.sum(assigned * freqs))
    used = int(np.sum(np.where(storable, support, 0) * freqs))
    return used / max(alloc, 1)


def per_class_waste_exact(chunks, support, freqs, *,
                          page_size: int = PAGE_SIZE) -> np.ndarray:
    """Waste attributed to each class (sorted order); index K = unstorable."""
    chunks = np.sort(np.asarray(chunks, dtype=np.int64))
    support = np.asarray(support, dtype=np.int64)
    freqs = np.asarray(freqs, dtype=np.int64)
    idx = np.searchsorted(chunks, support, side="left")
    storable = idx < chunks.shape[0]
    assigned = chunks[np.minimum(idx, len(chunks) - 1)]
    per_size = np.where(storable, assigned - support,
                        uncovered_charge(support, page_size=page_size))
    out = np.zeros(len(chunks) + 1, dtype=np.int64)
    np.add.at(out, np.where(storable, idx, len(chunks)), per_size * freqs)
    return out


@functools.partial(jax.jit, static_argnames=("page_size",))
def waste_jax(chunks, support, freqs, *, page_size: int = PAGE_SIZE):
    """Differentiable-shape JAX waste; float32 total. chunks may be unsorted."""
    chunks = jnp.sort(chunks.astype(jnp.int32))
    support = support.astype(jnp.int32)
    k = chunks.shape[0]
    idx = jnp.searchsorted(chunks, support, side="left")
    storable = idx < k
    assigned = chunks[jnp.minimum(idx, k - 1)]
    pages = jnp.maximum(-(-support // jnp.int32(page_size)), 1)
    per_size = jnp.where(storable, assigned - support,
                         pages * jnp.int32(page_size) - support)
    return jnp.sum(per_size.astype(jnp.float32) * freqs.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("page_size",))
def waste_batch_jax(chunk_batch, support, freqs, *,
                    page_size: int = PAGE_SIZE):
    """(B, K) candidate schedules -> (B,) waste. Vectorized search kernel.

    This is the search hot spot; ``repro.kernels.waste_eval`` provides a
    Pallas TPU kernel with identical semantics (this function doubles as
    its oracle via repro/kernels/ref.py).
    """
    fn = lambda c: waste_jax(c, support, freqs, page_size=page_size)
    return jax.vmap(fn)(chunk_batch)


def default_waste_fraction(chunks, support, freqs, *,
                           page_size: int = PAGE_SIZE) -> float:
    """Waste as a fraction of total item bytes (the paper's ~10% headline)."""
    total_item_bytes = int(np.sum(np.asarray(support, dtype=np.int64)
                                  * np.asarray(freqs, dtype=np.int64)))
    return waste_exact(chunks, support, freqs, page_size=page_size) / max(
        total_item_bytes, 1)
