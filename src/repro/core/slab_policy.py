"""SlabPolicy — the public API for learning slab-class schedules.

This is the paper's contribution packaged as a composable component:
feed it an observed allocation-size histogram, get back a schedule that
minimizes internal fragmentation. Consumers in this framework:

* ``repro.memcached`` — the paper's own testbed (byte-sized items),
* ``repro.serving.kv_slab_pool`` — KV-cache chunk classes in tokens,
* ``repro.data.bucketing`` — padded-length buckets for training batches.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence, Tuple

import jax
import numpy as np

from repro.core import hillclimb
from repro.core.anneal import anneal as _anneal_fn
from repro.core.dp_optimal import dp_optimal as _dp_optimal_fn
from repro.core.distribution import PAGE_SIZE, size_histogram
from repro.core.waste import (default_waste_fraction, utilization_exact,
                              waste_exact)

Method = Literal["dp", "hillclimb", "parallel", "multi_restart", "anneal"]


def default_memcached_schedule(*, growth_factor: float = 1.25,
                               min_chunk: int = 96,
                               page_size: int = PAGE_SIZE,
                               align: int = 8) -> np.ndarray:
    """Memcached's default geometric schedule (96B * 1.25^n, 8B aligned).

    Reproduces the stock class sizes the paper's "old configurations" are
    drawn from: ... 304, 384, 480, 600, 752, 944, 1184, 1480, 1856, ...
    """
    sizes = []
    size = min_chunk
    while size <= page_size / 2:
        sizes.append(size)
        nxt = int(np.ceil(size * growth_factor))
        if nxt % align:
            nxt += align - nxt % align
        size = max(nxt, size + align)
    sizes.append(page_size)
    return np.asarray(sizes, dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class SlabSchedule:
    """A learned (or default) slab-class schedule plus its provenance."""

    chunk_sizes: np.ndarray       # sorted, distinct, int64
    waste: int                    # exact waste on the fitting histogram
    baseline_waste: int           # waste of the baseline schedule
    baseline_chunks: np.ndarray
    method: str
    waste_fraction: float         # waste / total item bytes
    utilization: float            # item bytes / allocated bytes

    @property
    def recovered_frac(self) -> float:
        if self.baseline_waste == 0:
            return 0.0
        return 1.0 - self.waste / self.baseline_waste

    def assign(self, sizes) -> np.ndarray:
        """Class index for each size (== len(chunk_sizes) -> unstorable)."""
        return np.searchsorted(self.chunk_sizes,
                               np.asarray(sizes, dtype=np.int64),
                               side="left")

    def chunk_for(self, sizes) -> np.ndarray:
        idx = self.assign(sizes)
        idx = np.minimum(idx, len(self.chunk_sizes) - 1)
        return self.chunk_sizes[idx]


class SlabPolicy:
    """Learns slab-class schedules from observed allocation sizes."""

    def __init__(self, *, page_size: int = PAGE_SIZE,
                 min_chunk: int = hillclimb.MIN_CHUNK, seed: int = 0):
        self.page_size = page_size
        self.min_chunk = min_chunk
        self._key = jax.random.PRNGKey(seed)

    def _split(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def fit_sizes(self, sizes: Sequence[int], k: int, *,
                  method: Method = "dp", baseline=None,
                  **kwargs) -> SlabSchedule:
        support, freqs = size_histogram(np.asarray(sizes))
        return self.fit(support, freqs, k, method=method,
                        baseline=baseline, **kwargs)

    def fit(self, support, freqs, k: int, *, method: Method = "dp",
            baseline=None, **kwargs) -> SlabSchedule:
        """Learn a schedule of at most ``k`` classes for the histogram.

        ``baseline`` defaults to the stock geometric classes that cover the
        support (exactly the paper's "old configuration"); it both seeds the
        non-DP searches and anchors ``recovered_frac``.
        """
        support = np.asarray(support, dtype=np.int64)
        freqs = np.asarray(freqs, dtype=np.int64)
        if baseline is None:
            baseline = covering_default_classes(support, k=k,
                                                page_size=self.page_size)
        baseline = np.asarray(baseline, dtype=np.int64)
        init = baseline
        if len(init) != k:  # searches need exactly k movable classes
            init = _pad_or_trim(init, k, support)

        if method == "dp":
            res = _dp_optimal_fn(support, freqs, k)
            chunks, steps = res.chunks, 0
        elif method == "hillclimb":
            r = hillclimb.paper_hillclimb(self._split(), init, support,
                                          freqs, page_size=self.page_size,
                                          min_chunk=self.min_chunk, **kwargs)
            chunks = r.chunks
        elif method == "parallel":
            r = hillclimb.parallel_hillclimb(init, support, freqs,
                                             page_size=self.page_size,
                                             min_chunk=self.min_chunk,
                                             **kwargs)
            chunks = r.chunks
        elif method == "multi_restart":
            r = hillclimb.multi_restart(self._split(), init, support, freqs,
                                        page_size=self.page_size,
                                        min_chunk=self.min_chunk, **kwargs)
            chunks = r.chunks
        elif method == "anneal":
            r = _anneal_fn(self._split(), init, support, freqs,
                                  page_size=self.page_size,
                                  min_chunk=self.min_chunk, **kwargs)
            chunks = r.chunks
        else:
            raise ValueError(f"unknown method {method!r}")

        chunks = np.unique(np.asarray(chunks, dtype=np.int64))
        return SlabSchedule(
            chunk_sizes=chunks,
            waste=waste_exact(chunks, support, freqs,
                              page_size=self.page_size),
            baseline_waste=waste_exact(baseline, support, freqs,
                                       page_size=self.page_size),
            baseline_chunks=baseline,
            method=method,
            waste_fraction=default_waste_fraction(
                chunks, support, freqs, page_size=self.page_size),
            utilization=utilization_exact(chunks, support, freqs,
                                          page_size=self.page_size))


def covering_default_classes(support, *, k: int | None = None,
                             page_size: int = PAGE_SIZE) -> np.ndarray:
    """The stock geometric classes that receive the support's traffic.

    Mirrors how the paper's tables present the "old configuration": the
    subset of default classes spanning [min observed, >= max observed].
    If ``k`` is given and the natural span has fewer classes, extend
    downward (never upward: the top class must still cover max size).
    """
    support = np.asarray(support, dtype=np.int64)
    defaults = default_memcached_schedule(page_size=page_size)
    lo = int(np.searchsorted(defaults, support.min(), side="left"))
    hi = int(np.searchsorted(defaults, support.max(), side="left"))
    hi = min(hi, len(defaults) - 1)
    if k is not None:
        while hi - lo + 1 < k and lo > 0:
            lo -= 1
    return defaults[lo:hi + 1].astype(np.int64)


def schedule_with_default_tail(chunks, *,
                               page_size: int = PAGE_SIZE) -> np.ndarray:
    """Learned classes plus the stock geometric classes above them.

    A real memcached that re-learns classes for its observed traffic span
    still keeps the default classes above that span (items larger than
    anything seen so far must remain storable). The adaptive benchmarks
    deploy every learned schedule this way so an operating-point shift
    degrades gracefully into the geometric tail instead of rejecting.
    """
    chunks = np.unique(np.asarray(chunks, dtype=np.int64))
    defaults = default_memcached_schedule(page_size=page_size)
    return np.unique(np.concatenate(
        [chunks, defaults[defaults > chunks[-1]]]))


def _pad_or_trim(chunks: np.ndarray, k: int, support: np.ndarray
                 ) -> np.ndarray:
    """Give a search exactly k movable classes without losing coverage."""
    chunks = np.unique(chunks)
    max_size = int(support.max())
    if len(chunks) > k:
        keep = np.sort(np.concatenate(
            [chunks[-1:], chunks[:-1][-(k - 1):]]))  # always keep the top
        return keep.astype(np.int64)
    if len(chunks) < k:
        extra = np.linspace(int(support.min()), int(support.max()),
                            num=(k - len(chunks)) + 2,
                            dtype=np.int64)[1:-1]
        merged = np.concatenate([chunks, extra])
        # Nudge duplicates apart; waste is invariant to duplicate classes.
        merged = np.sort(merged)
        for i in range(1, len(merged)):
            if merged[i] <= merged[i - 1]:
                merged[i] = merged[i - 1] + 1
        return merged.astype(np.int64)
    return chunks.astype(np.int64)
