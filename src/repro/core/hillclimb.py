"""Hill-climbing slab-class search.

``paper_hillclimb`` is a faithful implementation of the paper's Algorithm 1:

    do:
        move one randomly chosen class +-1 byte
        accept iff new_waste <= old_waste       (neutral moves accepted)
    until 1000 consecutive rejections

as a single jitted ``lax.while_loop`` (the paper's pseudocode assigns
``newwaste = oldwaste`` in the accept branch; the intent — and what we
implement — is ``oldwaste = newwaste``; see DESIGN.md §1 errata).

Beyond-paper variants (same objective, better hardware mapping):

* ``parallel_hillclimb`` — evaluates *all* K x len(deltas) single-class
  moves per iteration as one batched waste evaluation (VPU-friendly;
  optionally the Pallas kernel) and takes the best strictly-improving
  move. Converges to a coordinate-wise local optimum in tens of
  iterations instead of the paper's tens of thousands of +-1 steps.
* ``multi_restart`` — vmapped restarts from jittered initial schedules;
  the paper ran 100 sequential restarts to argue global convergence
  (§6.3); on TPU these are one batched program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.guards import deliberate_sync
from repro.core.distribution import PAGE_SIZE
from repro.core.waste import waste_batch_jax, waste_exact, waste_jax

MIN_CHUNK = 48  # memcached's smallest usable chunk


@dataclasses.dataclass(frozen=True)
class SearchResult:
    chunks: np.ndarray          # learned schedule, sorted int64
    waste: int                  # exact waste of `chunks` (bytes)
    init_waste: int             # exact waste of the initial schedule
    steps: int                  # iterations actually executed
    method: str

    @property
    def recovered_frac(self) -> float:
        if self.init_waste == 0:
            return 0.0
        return 1.0 - self.waste / self.init_waste


def _as_i32(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("patience", "max_steps", "page_size", "min_chunk"))
def _paper_hillclimb_jax(key, init_chunks, support, freqs, *,
                         patience: int, max_steps: int,
                         page_size: int, min_chunk: int):
    k = init_chunks.shape[0]

    def waste_of(c):
        return waste_jax(c, support, freqs, page_size=page_size)

    def cond(state):
        _, _, _, count, step = state
        return jnp.logical_and(count <= patience, step < max_steps)

    def body(state):
        key, chunks, old, count, step = state
        key, k_cls, k_dir = jax.random.split(key, 3)
        j = jax.random.randint(k_cls, (), 0, k)
        delta = jnp.where(jax.random.bernoulli(k_dir), 1, -1).astype(jnp.int32)
        cand = chunks.at[j].add(delta)
        cand = jnp.clip(cand, min_chunk, page_size)
        new = waste_of(cand)
        accept = new <= old
        chunks = jnp.where(accept, cand, chunks)
        old = jnp.where(accept, new, old)
        count = jnp.where(accept, 0, count + 1)
        return key, chunks, old, count, step + 1

    state = (key, _as_i32(init_chunks),
             waste_of(_as_i32(init_chunks)), jnp.int32(0), jnp.int32(0))
    key, chunks, old, count, step = jax.lax.while_loop(cond, body, state)
    return chunks, step


def paper_hillclimb(key, init_chunks, support, freqs, *,
                    patience: int = 1000, max_steps: int = 200_000,
                    page_size: int = PAGE_SIZE,
                    min_chunk: int = MIN_CHUNK) -> SearchResult:
    """The paper's Algorithm 1. ``max_steps`` bounds runtime (the paper runs
    unbounded; with neutral moves accepted, unused classes random-walk and
    the 1000-rejection patience can take arbitrarily long to trip)."""
    support_j = _as_i32(support)
    freqs_j = jnp.asarray(freqs, dtype=jnp.float32)
    chunks, steps = _paper_hillclimb_jax(
        key, _as_i32(init_chunks), support_j, freqs_j,
        patience=patience, max_steps=max_steps,
        page_size=page_size, min_chunk=min_chunk)
    # Refit-time result readback: one deliberate device->host pull at the
    # end of the whole search, not a per-step sync.
    with deliberate_sync("hillclimb.paper-result"):
        chunks = np.sort(np.asarray(chunks, dtype=np.int64))
        steps_host = int(steps)
    return SearchResult(
        chunks=chunks,
        waste=waste_exact(chunks, support, freqs, page_size=page_size),
        init_waste=waste_exact(init_chunks, support, freqs,
                               page_size=page_size),
        steps=steps_host, method="paper_hillclimb")


DEFAULT_DELTAS: tuple = tuple(
    d for m in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512) for d in (-m, m))


@functools.partial(
    jax.jit,
    static_argnames=("max_iters", "page_size", "min_chunk", "deltas",
                     "batch_eval"))
def _parallel_hillclimb_jax(init_chunks, support, freqs, *,
                            max_iters: int, page_size: int, min_chunk: int,
                            deltas: tuple, batch_eval=None):
    k = init_chunks.shape[0]
    deltas_arr = jnp.asarray(deltas, dtype=jnp.int32)          # (D,)
    d = deltas_arr.shape[0]
    eval_batch = batch_eval or (
        lambda cb: waste_batch_jax(cb, support, freqs, page_size=page_size))

    def body(state):
        chunks, old, it, done = state
        # All K*D single-class moves as one batch.
        eye = jnp.eye(k, dtype=jnp.int32)                       # (K, K)
        moves = eye[:, None, :] * deltas_arr[None, :, None]     # (K, D, K)
        cands = chunks[None, None, :] + moves                   # (K, D, K)
        cands = jnp.clip(cands, min_chunk, page_size).reshape(k * d, k)
        w = eval_batch(cands)                                   # (K*D,)
        best = jnp.argmin(w)
        improved = w[best] < old
        chunks = jnp.where(improved, cands[best], chunks)
        old = jnp.where(improved, w[best], old)
        return chunks, old, it + 1, jnp.logical_not(improved)

    def cond(state):
        _, _, it, done = state
        return jnp.logical_and(it < max_iters, jnp.logical_not(done))

    init = (_as_i32(init_chunks),
            eval_batch(_as_i32(init_chunks)[None, :])[0],
            jnp.int32(0), jnp.bool_(False))
    chunks, _, it, _ = jax.lax.while_loop(cond, body, init)
    return chunks, it


def parallel_hillclimb(init_chunks, support, freqs, *,
                       max_iters: int = 2000, page_size: int = PAGE_SIZE,
                       min_chunk: int = MIN_CHUNK,
                       deltas: Sequence[int] = DEFAULT_DELTAS,
                       batch_eval: Callable | None = None) -> SearchResult:
    """Best-improvement hill climbing over a geometric move set.

    Terminates at a configuration where no single-class move in ``deltas``
    improves waste (a superset of the paper's +-1 moves, so its fixed
    points are at least as good). ``batch_eval`` lets callers swap in the
    Pallas kernel (repro.kernels.ops.waste_eval) for the evaluation.
    """
    support_j = _as_i32(support)
    freqs_j = jnp.asarray(freqs, dtype=jnp.float32)
    chunks, iters = _parallel_hillclimb_jax(
        _as_i32(init_chunks), support_j, freqs_j, max_iters=max_iters,
        page_size=page_size, min_chunk=min_chunk, deltas=tuple(deltas),
        batch_eval=batch_eval)
    with deliberate_sync("hillclimb.parallel-result"):
        chunks = np.sort(np.asarray(chunks, dtype=np.int64))
        iters_host = int(iters)
    return SearchResult(
        chunks=chunks,
        waste=waste_exact(chunks, support, freqs, page_size=page_size),
        init_waste=waste_exact(init_chunks, support, freqs,
                               page_size=page_size),
        steps=iters_host, method="parallel_hillclimb")


def multi_restart(key, init_chunks, support, freqs, *, n_restarts: int = 16,
                  jitter: int = 64, page_size: int = PAGE_SIZE,
                  min_chunk: int = MIN_CHUNK,
                  max_iters: int = 2000) -> SearchResult:
    """vmapped multi-restart parallel hill climbing; returns the best run."""
    support_j = _as_i32(support)
    freqs_j = jnp.asarray(freqs, dtype=jnp.float32)
    init = _as_i32(init_chunks)
    keys = jax.random.split(key, n_restarts)
    noise = jax.vmap(
        lambda k: jax.random.randint(k, init.shape, -jitter, jitter + 1)
    )(keys).astype(jnp.int32)
    noise = noise.at[0].set(0)  # restart 0 is the unjittered schedule
    starts = jnp.clip(init[None, :] + noise, min_chunk, page_size)
    # The top class must keep covering the max observed size.
    max_size = jnp.max(support_j)
    top = jnp.maximum(jnp.max(starts, axis=1), max_size)
    starts = starts.at[:, jnp.argmax(init)].set(
        jnp.maximum(starts[:, jnp.argmax(init)], top))

    run = functools.partial(
        _parallel_hillclimb_jax, support=support_j, freqs=freqs_j,
        max_iters=max_iters, page_size=page_size, min_chunk=min_chunk,
        deltas=DEFAULT_DELTAS, batch_eval=None)
    all_chunks, iters = jax.vmap(lambda c: run(c))(starts)
    wastes = waste_batch_jax(all_chunks, support_j, freqs_j,
                             page_size=page_size)
    with deliberate_sync("hillclimb.restart-result"):
        best = int(jnp.argmin(wastes))
        chunks = np.sort(np.asarray(all_chunks[best], dtype=np.int64))
        steps_host = int(np.max(np.asarray(iters)))
    return SearchResult(
        chunks=chunks,
        waste=waste_exact(chunks, support, freqs, page_size=page_size),
        init_waste=waste_exact(init_chunks, support, freqs,
                               page_size=page_size),
        steps=steps_host, method="multi_restart")
