"""Streaming observation of item sizes — the first half of the paper's loop.

The paper's technique is *analyse the sizes of items previously entered,
then re-configure the slab classes*. Everything downstream (the waste
objective, the optimizers, `SlabPolicy`) consumes a `(support, freqs)`
histogram; this module produces that histogram **online** from a stream
of sizes, with exponential decay so the estimate tracks drifting traffic
instead of averaging over the whole past.

`DecayedSizeHistogram` is an exponentially-decayed sparse histogram with
O(1) amortized updates (lazy per-bin decay: each bin stores the step at
which it was last touched and is brought forward only when re-observed,
pruned, or snapshotted). `snapshot()` returns the same `(support, freqs)`
int64 pair as `repro.core.distribution.size_histogram`, so every consumer
of the offline histogram works unchanged on the live sketch.

`DeviceSizeSketch` is the device-resident sibling: a dense
exponentially-decayed bucket histogram living in accelerator memory,
updated one whole batch of sizes per Pallas ``sketch_update`` launch
(see ``repro.kernels.sketch_update``). Its ``observe_many``/``snapshot``
API matches the host sketch, but nothing crosses the device→host
boundary until ``snapshot()``/``snapshot_weights()`` is actually called
— both classes count those materializations in ``n_host_syncs`` so the
benchmarks can compare sync traffic. ``histogram_distance_device`` is
the matching on-device drift metric over two dense weight vectors, so
the controller's drift gate runs without materializing the sketch.

`histogram_distance` is the drift signal: normalized L1 (total variation)
or earth-mover's distance between two histograms over their shared
support, both in [0, 1]. The controller compares the live sketch against
the fitting-time reference histogram to decide when the schedule is
stale.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

# Both are stdlib-only (guards imports jax lazily and only while a
# transfer guard is armed), so the host sketch stays jax-free.
from repro.analysis.guards import deliberate_sync
from repro.analysis.registry import hot_path


class DecayedSizeHistogram:
    """Exponentially-decayed sparse size histogram, O(1) per observation.

    ``half_life`` is measured in *observations*: after ``half_life``
    further observations, a sample's weight has halved. ``half_life=None``
    disables decay — the sketch then reproduces ``size_histogram`` of the
    full stream exactly (used by consumers that want the legacy
    every-item-counts behaviour and by round-trip tests).
    """

    def __init__(self, *, half_life: Optional[float] = None,
                 max_bins: int = 1 << 14):
        if half_life is not None and half_life <= 0:
            raise ValueError(f"half_life must be positive, got {half_life}")
        if max_bins < 2:
            raise ValueError("max_bins must be >= 2")
        self.half_life = half_life
        self.max_bins = max_bins
        self._decay = 0.5 ** (1.0 / half_life) if half_life else 1.0
        self._w: Dict[int, float] = {}       # size -> weight at step _last[s]
        self._last: Dict[int, int] = {}      # size -> step of last update
        self._t = 0                          # observation clock
        self.n_observed = 0                  # lifetime count (undecayed)
        self._total = 0.0                    # decayed total weight
        self.n_host_syncs = 0                # snapshot materializations
        self.n_dispatches = 0                # device launches (host: none)

    # -- updates -----------------------------------------------------------
    @hot_path
    def observe(self, size: int, weight: float = 1.0) -> None:
        """Record one size. O(1); decay of other bins is lazy."""
        s = int(size)
        if s < 0:
            raise ValueError(f"size must be non-negative, got {s}")
        self._t += 1
        self.n_observed += 1
        w = self._w.get(s)
        if w is not None:
            self._total = self._total * self._decay + weight
            self._w[s] = w * self._decay ** (self._t - self._last[s]) + weight
        else:
            if len(self._w) >= self.max_bins:
                # _prune syncs the kept bins to the (already stepped)
                # clock and rebuilds _total from them, so only the new
                # item's weight remains to be added.
                self._prune()
                self._total += weight
            else:
                self._total = self._total * self._decay + weight
            self._w[s] = weight
        self._last[s] = self._t

    @hot_path
    def observe_many(self, sizes, weights=None) -> None:
        """Record a batch of sizes, optionally with per-item weights
        (scalar or array-like broadcast against ``sizes``)."""
        sizes = np.asarray(sizes).ravel()
        if weights is None:
            for s in sizes.tolist():
                self.observe(int(s))
            return
        w = np.broadcast_to(np.asarray(weights, dtype=np.float64),
                            sizes.shape).ravel()
        for s, wi in zip(sizes.tolist(), w.tolist()):
            self.observe(int(s), wi)

    # -- views -------------------------------------------------------------
    @property
    def effective_count(self) -> float:
        """Decayed total mass (== n_observed when decay is disabled)."""
        return self._total

    def _synced_weights(self) -> Dict[int, float]:
        """All bins decayed forward to the current step."""
        if self._decay == 1.0:
            return dict(self._w)
        return {s: w * self._decay ** (self._t - self._last[s])
                for s, w in self._w.items()}

    def _prune(self) -> None:
        """Drop the lightest ~10% of bins (called when max_bins is hit)."""
        synced = self._synced_weights()
        keep = sorted(synced, key=synced.__getitem__, reverse=True)
        keep = keep[:max(1, int(self.max_bins * 0.9))]
        t = self._t
        self._w = {s: synced[s] for s in keep if synced[s] > 0.0}
        self._last = {s: t for s in self._w}
        # Dropped bins take their decayed mass with them: recompute the
        # running total from the kept (synced) bins so effective_count
        # never overstates the live mass after a prune.
        self._total = float(sum(self._w.values()))

    def snapshot(self) -> Tuple[np.ndarray, np.ndarray]:
        """``(support, freqs)`` int64, compatible with ``size_histogram``.

        Weights are rounded to the nearest integer; bins whose decayed
        weight rounds to zero are dropped (they no longer represent
        current traffic). With decay disabled this is bit-exact with
        ``size_histogram`` over every observed size.
        """
        self.n_host_syncs += 1
        synced = self._synced_weights()
        if not synced:
            return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
        support = np.asarray(sorted(synced), dtype=np.int64)
        freqs = np.rint([synced[int(s)] for s in support]).astype(np.int64)
        keep = freqs > 0
        return support[keep], freqs[keep]

    def snapshot_weights(self) -> Tuple[np.ndarray, np.ndarray]:
        """Float-weight variant of :meth:`snapshot` (no rounding) — the
        drift metric uses this to avoid quantization noise."""
        self.n_host_syncs += 1
        synced = self._synced_weights()
        if not synced:
            return (np.zeros(0, dtype=np.int64),
                    np.zeros(0, dtype=np.float64))
        support = np.asarray(sorted(synced), dtype=np.int64)
        w = np.asarray([synced[int(s)] for s in support], dtype=np.float64)
        keep = w > 0.0
        return support[keep], w[keep]

    def reset(self) -> None:
        self._w.clear()
        self._last.clear()
        self._t = 0
        self.n_observed = 0
        self._total = 0.0
        self.n_host_syncs = 0
        self.n_dispatches = 0


def __getattr__(name):
    # The "streaming size sketch" alias from the early docs was
    # deprecated in PR 5 and removed in PR 8. ImportError (not
    # AttributeError) so `from repro.core.observe import ...` surfaces
    # THIS message instead of a generic cannot-import line.
    if name == "StreamingSizeSketch":
        raise ImportError(
            "StreamingSizeSketch was removed; use "
            "repro.core.observe.DecayedSizeHistogram instead")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


_WINDOW_FLUSH: Dict[tuple, object] = {}


def _window_flush_fn(metric: str, use_kernel: bool, interpret: bool,
                     bucket_width: int, with_ref: bool, donate: bool):
    """One jitted program for a whole observe window: the scanned
    sketch update (kernel or oracle engine) plus — when a reference is
    supplied — the drift distance of the post-window state, emitted as
    a single device scalar. Cached per static configuration."""
    key = (metric, use_kernel, interpret, bucket_width, with_ref, donate)
    fn = _WINDOW_FLUSH.get(key)
    if fn is not None:
        return fn
    import jax
    import jax.numpy as jnp
    from repro.kernels.sketch_update import (sketch_window_pallas,
                                             sketch_window_ref)

    def run(state, sizes, weights, lengths, decay, decay_totals, ref):
        if use_kernel:
            new = sketch_window_pallas(state, sizes, weights, lengths,
                                       decay, decay_totals,
                                       bucket_width=bucket_width,
                                       interpret=interpret)
        else:
            new = sketch_window_ref(state, sizes, weights, lengths,
                                    decay, decay_totals,
                                    bucket_width=bucket_width)
        drift = (_dense_distance(ref, new, metric) if with_ref
                 else jnp.float32(0.0))
        return new, drift

    fn = jax.jit(run, donate_argnums=(0,) if donate else ())
    _WINDOW_FLUSH[key] = fn
    return fn


class DeviceSizeSketch:
    """Device-resident exponentially-decayed size histogram.

    The same observe/snapshot contract as :class:`DecayedSizeHistogram`,
    but the state is a dense ``(num_buckets,)`` float32 weight vector in
    accelerator memory, updated one whole batch per Pallas
    ``sketch_update`` launch. Sizes are bucketed on a fixed grid: size
    ``s`` lands in bucket ``ceil(s / bucket_width) - 1``, whose
    representative size is ``(bucket + 1) * bucket_width`` — the bucket's
    inclusive upper edge, so the representative always covers the item
    (the direction slab fitting needs). With ``bucket_width=1`` and
    sizes in ``[1, num_buckets]`` the sketch is bit-comparable to the
    host dict (size 0, which the host records verbatim, coarsens into
    the first bucket's representative here); serving uses
    ``bucket_width=align`` so ALIGN-quantized lengths map exactly. Sizes beyond the grid clamp into the top bucket (size
    the grid to the workload).

    Nothing crosses the device→host boundary until ``snapshot()`` /
    ``snapshot_weights()`` is called; those materializations are counted
    in ``n_host_syncs`` (scalar readbacks like ``effective_count`` and
    the controller's drift gate count in ``n_scalar_syncs``). The drift
    metric consumes :attr:`weights_device` directly via
    :func:`histogram_distance_device`, keeping the whole
    observe → drift loop on device.
    """

    def __init__(self, *, half_life: Optional[float] = None,
                 num_buckets: int = 1 << 13, bucket_width: int = 1,
                 interpret: Optional[bool] = None,
                 window: bool = False,
                 window_kernel: Optional[bool] = None,
                 max_pending_batches: int = 512):
        if half_life is not None and half_life <= 0:
            raise ValueError(f"half_life must be positive, got {half_life}")
        if num_buckets < 2:
            raise ValueError("num_buckets must be >= 2")
        if bucket_width < 1:
            raise ValueError("bucket_width must be >= 1")
        import jax.numpy as jnp   # deferred: host sketch stays jax-free
        self._jnp = jnp
        self.half_life = half_life
        self.num_buckets = num_buckets
        self.bucket_width = bucket_width
        self._decay = 0.5 ** (1.0 / half_life) if half_life else 1.0
        self._interpret = interpret
        self._use_ref = False       # latched once the Pallas path fails
        # window=True turns observe_many into an accumulator: batches
        # buffer on host (raw, untouched) and fold into the sketch in
        # ONE fused dispatch at flush_window() — or transparently, the
        # moment any state view is read. window_kernel picks the scan
        # engine: None = Pallas kernel on TPU, jnp oracle elsewhere
        # (the interpret-mode kernel would be slower than the host
        # dict); True/False forces it.
        self._window = bool(window)
        self._window_kernel = window_kernel
        self._max_pending = int(max_pending_batches)
        self._pending: list = []    # [(sizes_row, weights_row|None, n), ...]
        self._escaped = False       # a weights_device ref is held outside
        self._weights = jnp.zeros(num_buckets, dtype=jnp.float32)
        self.n_observed = 0                  # lifetime count (undecayed)
        self.n_dispatches = 0                # jitted observe-loop launches
        self.n_host_syncs = 0                # full materializations
        self.n_scalar_syncs = 0              # few-byte scalar readbacks

    # -- updates -----------------------------------------------------------
    def bucket_of(self, sizes):
        """Bucket ids for an array of sizes (device-side, no transfer).

        Size 0 coarsens into the first bucket (representative
        ``bucket_width``) exactly like any other in-bucket size rounds
        up to its representative. Negative sizes map to -1, which the
        scatter ignores: the host sketch raises on them, but raising
        here would need a device→host readback, so invalid items are
        dropped instead — validate upstream. (They still tick the decay
        clock and ``n_observed``, like any batch item.)
        """
        jnp = self._jnp
        s = jnp.asarray(sizes).ravel().astype(jnp.int32)
        idx = -(-s // jnp.int32(self.bucket_width)) - 1
        return jnp.where(s < 0, -1,
                         jnp.clip(idx, 0, self.num_buckets - 1))

    @hot_path(counters=("n_dispatches", "n_scalar_syncs"))
    def observe(self, size: int, weight: float = 1.0) -> None:
        """Record one size (a one-element batch; prefer observe_many)."""
        self.observe_many([int(size)], [float(weight)])

    def _normalize_batch(self, sizes, weights):
        """``(sizes_row, weights_row|None, n)`` with host arrays kept on
        host (stacking pads them for free; the single device transfer
        happens at dispatch) and device arrays left on device."""
        if not hasattr(sizes, "ravel"):
            sizes = np.asarray(sizes)
        sizes = sizes.ravel() if sizes.ndim != 1 else sizes
        n = int(sizes.shape[0])
        if weights is not None:
            if isinstance(weights, (int, float)):
                weights = np.full(n, weights, dtype=np.float32)
            elif not hasattr(weights, "ravel"):
                weights = np.asarray(weights, dtype=np.float32)
        return sizes, weights, n

    @hot_path(counters=("n_dispatches",))
    def observe_many(self, sizes, weights=None) -> None:
        """Record a batch of sizes — ONE jitted dispatch (or zero, in
        window mode, where batches buffer until ``flush_window``).

        ``sizes`` may be a host array or a device array straight out of
        a serve step — either way nothing is pulled back to host, and
        bucketization happens inside the jit (the host hands over raw
        sizes). Each item i of an n-item batch is folded in with
        ``decay**(n-1-i)``, matching n sequential host observations
        exactly.
        """
        row = self._normalize_batch(sizes, weights)
        if row[2] == 0:
            return
        self.n_observed += row[2]
        if self._window:
            self._pending.append(row)
            if len(self._pending) >= self._max_pending:
                self.flush_window()     # bound host memory, not a sync
            return
        self._launch([row])

    @hot_path(counters=("n_dispatches",))
    def observe_window(self, sizes_chunk, weights_chunk=None, *,
                       reference=None, metric: str = "l1"):
        """Fold a whole chunk of observe batches in ONE fused dispatch.

        ``sizes_chunk`` is a sequence of batches (ragged is fine) or a
        2-D ``[n_batches, batch]`` array; ``weights_chunk`` optionally
        matches its shape. Bit-equivalent to calling ``observe_many``
        per batch — but the scan over ``sketch_update`` steps, the
        per-item decay, and (when ``reference`` is given) the drift
        distance of the post-window state compile into a single launch.
        (On the kernel engine, bit-equivalence holds when the batch
        lengths share one BLOCK_N pad band — uniform serving batches
        always do; mixed bands round within ~1 f32 ulp. The jnp oracle
        engine is bit-stable for any raggedness.)
        Returns the drift as a 0-d device array (no host sync) when
        ``reference`` is supplied, else ``None``. Any batches buffered
        in window mode are folded into the same dispatch first.
        """
        rows = self._pending
        self._pending = []
        for i, batch in enumerate(sizes_chunk):
            w = None if weights_chunk is None else weights_chunk[i]
            row = self._normalize_batch(batch, w)
            if row[2]:
                self.n_observed += row[2]
                rows.append(row)
        if not rows:
            return None
        return self._launch(rows, reference=reference, metric=metric)

    @hot_path(counters=("n_dispatches",))
    def flush_window(self, *, reference=None, metric: str = "l1"):
        """Fold every buffered batch into the sketch in one dispatch.

        Returns the drift vs ``reference`` as a 0-d device array when a
        reference is given, else ``None``; no-op when nothing is
        pending. Reading any state view (``weights_device``,
        ``snapshot*``, ``effective_count``) flushes implicitly, so
        buffering is invisible to consumers of the sketch.
        """
        if not self._pending:
            return None
        rows, self._pending = self._pending, []
        return self._launch(rows, reference=reference, metric=metric)

    def _stacked(self, rows):
        """Stack buffered rows into ``(sizes2d, weights2d, lengths,
        decay_totals)``. Shapes are padded up to powers of two (B) and
        power-of-two multiples of BLOCK_N (N) so ragged serving windows
        reuse a handful of compiled programs instead of one per shape;
        dead positions/rows are exact no-ops in the scan. Per-row
        ``decay ** n`` is computed here, in host float64, so the fused
        path rounds identically to the per-batch path."""
        from repro.kernels.sketch_update import BLOCK_N
        import jax
        b = len(rows)
        lengths = np.zeros(1 << (b - 1).bit_length(), dtype=np.int32)
        lengths[:b] = [n for (_, _, n) in rows]
        nmax = int(lengths.max())
        npad = BLOCK_N << max(0, -(-nmax // BLOCK_N) - 1).bit_length()
        decay_totals = np.asarray([self._decay ** int(n) for n in lengths],
                                  dtype=np.float32)
        on_device = any(isinstance(s, jax.Array) for (s, _, _) in rows)
        if on_device:
            jnp = self._jnp
            sizes2d = jnp.zeros((len(lengths), npad), dtype=jnp.int32)
            weights2d = jnp.ones((len(lengths), npad), dtype=jnp.float32)
            for i, (s, w, n) in enumerate(rows):
                sizes2d = sizes2d.at[i, :n].set(
                    jnp.asarray(s).astype(jnp.int32))
                if w is not None:
                    weights2d = weights2d.at[i, :n].set(
                        jnp.asarray(w, dtype=jnp.float32))
            return sizes2d, weights2d, lengths, decay_totals
        sizes2d = np.zeros((len(lengths), npad), dtype=np.int32)
        weights2d = np.ones((len(lengths), npad), dtype=np.float32)
        for i, (s, w, n) in enumerate(rows):
            sizes2d[i, :n] = s
            if w is not None:
                weights2d[i, :n] = np.broadcast_to(w, (n,))
        return sizes2d, weights2d, lengths, decay_totals

    def _launch(self, rows, *, reference=None, metric: str = "l1"):
        """One fused dispatch folding ``rows`` into the sketch; returns
        the drift device scalar when ``reference`` is given."""
        import jax
        sizes2d, weights2d, lengths, decay_totals = self._stacked(rows)
        with_ref = reference is not None
        ref = reference if with_ref else np.float32(0.0)
        use_kernel = (self._window_kernel if self._window_kernel is not None
                      else (not self._use_ref
                            and jax.default_backend() == "tpu"))
        interpret = False
        if use_kernel:
            from repro.kernels.ops import _default_interpret
            interpret = (self._interpret if self._interpret is not None
                         else _default_interpret())
        # Donate the carried state so the fused update runs in place —
        # unless a caller still holds a reference to the current buffer
        # (the controller's drift reference, a forecast window), which
        # donation would invalidate. CPU ignores donation; skip it
        # there to avoid per-launch warnings.
        donate = jax.default_backend() != "cpu" and not self._escaped
        decay = np.float32(self._decay)
        try:
            fn = _window_flush_fn(metric, use_kernel, interpret,
                                  self.bucket_width, with_ref, donate)
            new, drift = fn(self._weights, sizes2d, weights2d, lengths,
                            decay, decay_totals, ref)
        except Exception as e:  # pragma: no cover - pallas unavailable
            if not use_kernel:
                raise
            # Latched: don't re-pay a doomed trace per window — but say
            # so once, or a production run would silently measure the
            # fallback while reporting itself as the kernel path.
            import warnings
            warnings.warn(
                "DeviceSizeSketch: Pallas sketch_window launch failed "
                f"({e!r}); latching the jnp fallback for this sketch",
                RuntimeWarning)
            self._use_ref = True
            fn = _window_flush_fn(metric, False, False, self.bucket_width,
                                  with_ref, donate)
            new, drift = fn(self._weights, sizes2d, weights2d, lengths,
                            decay, decay_totals, ref)
        self._weights = new
        self._escaped = False
        self.n_dispatches += 1
        return drift if with_ref else None

    # -- views -------------------------------------------------------------
    @property
    def weights_device(self):
        """The dense per-bucket weight vector (device array, no sync).

        Flushes any buffered window first, and marks the buffer as
        escaped: the next fused launch will not donate a buffer the
        caller may still be holding."""
        self.flush_window()
        self._escaped = True
        return self._weights

    @property
    def support_device(self):
        """Representative sizes of every bucket (device array)."""
        jnp = self._jnp
        return ((jnp.arange(self.num_buckets, dtype=jnp.int32) + 1)
                * jnp.int32(self.bucket_width))

    @property
    def effective_count(self) -> float:
        """Decayed total mass (scalar readback, not a materialization)."""
        self.flush_window()
        self.n_scalar_syncs += 1
        with deliberate_sync("DeviceSizeSketch.effective_count"):
            return float(self._jnp.sum(self._weights))

    def snapshot(self) -> Tuple[np.ndarray, np.ndarray]:
        """``(support, freqs)`` int64 — THE device→host sync point."""
        self.flush_window()
        self.n_host_syncs += 1
        with deliberate_sync("DeviceSizeSketch.snapshot"):
            w = np.asarray(self._weights)
        freqs = np.rint(w).astype(np.int64)
        keep = freqs > 0
        support = (np.nonzero(keep)[0].astype(np.int64) + 1) \
            * self.bucket_width
        return support, freqs[keep]

    def snapshot_weights(self) -> Tuple[np.ndarray, np.ndarray]:
        """Float-weight variant of :meth:`snapshot` (no rounding)."""
        self.flush_window()
        self.n_host_syncs += 1
        with deliberate_sync("DeviceSizeSketch.snapshot_weights"):
            w = np.asarray(self._weights, dtype=np.float64)
        keep = w > 0.0
        support = (np.nonzero(keep)[0].astype(np.int64) + 1) \
            * self.bucket_width
        return support, w[keep]

    def reset(self) -> None:
        self._weights = self._jnp.zeros(self.num_buckets,
                                        dtype=self._jnp.float32)
        self._pending = []
        self._escaped = False
        self.n_observed = 0
        self.n_dispatches = 0
        self.n_host_syncs = 0
        self.n_scalar_syncs = 0


def _aligned(a: Tuple[np.ndarray, np.ndarray],
             b: Tuple[np.ndarray, np.ndarray]
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    sa, fa = a
    sb, fb = b
    sa = np.asarray(sa, dtype=np.int64)
    sb = np.asarray(sb, dtype=np.int64)
    support = np.union1d(sa, sb)
    pa = np.zeros(len(support), dtype=np.float64)
    pb = np.zeros(len(support), dtype=np.float64)
    pa[np.searchsorted(support, sa)] = np.asarray(fa, dtype=np.float64)
    pb[np.searchsorted(support, sb)] = np.asarray(fb, dtype=np.float64)
    return support, pa, pb


def _dense_distance(wa, wb, metric: str):
    """jnp body of the dense-histogram distance — shared by
    :func:`histogram_distance_device` and the fused observe-window
    flush, so the in-scan drift scalar and the standalone gate are the
    same traced ops."""
    import jax.numpy as jnp
    wa = wa.astype(jnp.float32)
    wb = wb.astype(jnp.float32)
    ta = jnp.sum(wa)
    tb = jnp.sum(wb)
    pa = wa / jnp.maximum(ta, 1e-30)
    pb = wb / jnp.maximum(tb, 1e-30)
    if metric == "l1":
        d = 0.5 * jnp.sum(jnp.abs(pa - pb))
    else:
        # emd on a uniform bucket grid: the bucket width cancels, and
        # the host metric's span is the occupied extent (empty edge
        # buckets contribute zero cdf gap, so only the denominator
        # needs the occupied first/last bucket).
        occupied = (wa > 0) | (wb > 0)
        first = jnp.argmax(occupied)
        last = wa.shape[0] - 1 - jnp.argmax(occupied[::-1])
        cdf_gap = jnp.abs(jnp.cumsum(pa - pb))[:-1]
        d = jnp.sum(cdf_gap) / jnp.maximum(last - first, 1)
    # empty-vs-empty is 0, empty-vs-mass is 1 (host semantics)
    both = (ta > 0) & (tb > 0)
    return jnp.where(both, d, jnp.where(ta == tb, 0.0, 1.0))


def _histogram_distance_device_jit(metric: str):
    """Build the jitted dense-histogram distance for one metric."""
    import jax

    @jax.jit
    def dist(wa, wb):
        return _dense_distance(wa, wb, metric)

    return dist


_DEVICE_DISTANCE = {}


def histogram_distance_device(wa, wb, *, metric: str = "l1"):
    """On-device drift: distance in [0, 1] between two DENSE per-bucket
    weight vectors on the same grid (e.g. two
    :attr:`DeviceSizeSketch.weights_device` states). Returns a 0-d
    device array — nothing is materialized on host until the caller
    reads the scalar. Same semantics as :func:`histogram_distance` over
    the bucket-representative support.
    """
    if metric not in ("l1", "emd"):
        raise ValueError(f"unknown metric {metric!r}")
    fn = _DEVICE_DISTANCE.get(metric)
    if fn is None:
        fn = _DEVICE_DISTANCE[metric] = _histogram_distance_device_jit(metric)
    return fn(wa, wb)


def histogram_distance(a, b, *, metric: str = "l1") -> float:
    """Distance in [0, 1] between two ``(support, freqs)`` histograms.

    ``"l1"``  — total variation: ``0.5 * sum |p - q|`` of the normalized
    mass functions over the union support. Insensitive to *how far* mass
    moved; cheap and scale-free.
    ``"emd"`` — earth-mover's (Wasserstein-1) distance of the normalized
    distributions, divided by the span of the union support, so shifting
    all mass from one end to the other scores 1.
    """
    support, pa, pb = _aligned(a, b)
    if support.size == 0:
        return 0.0
    ta, tb = pa.sum(), pb.sum()
    if ta <= 0 or tb <= 0:
        return 0.0 if ta == tb else 1.0
    pa = pa / ta
    pb = pb / tb
    if metric == "l1":
        return float(0.5 * np.abs(pa - pb).sum())
    if metric == "emd":
        if support.size == 1:
            return 0.0
        span = float(support[-1] - support[0])
        cdf_gap = np.abs(np.cumsum(pa - pb))[:-1]
        gaps = np.diff(support).astype(np.float64)
        return float(np.sum(cdf_gap * gaps) / span)
    raise ValueError(f"unknown metric {metric!r}")
