"""Streaming observation of item sizes — the first half of the paper's loop.

The paper's technique is *analyse the sizes of items previously entered,
then re-configure the slab classes*. Everything downstream (the waste
objective, the optimizers, `SlabPolicy`) consumes a `(support, freqs)`
histogram; this module produces that histogram **online** from a stream
of sizes, with exponential decay so the estimate tracks drifting traffic
instead of averaging over the whole past.

`DecayedSizeHistogram` is an exponentially-decayed sparse histogram with
O(1) amortized updates (lazy per-bin decay: each bin stores the step at
which it was last touched and is brought forward only when re-observed,
pruned, or snapshotted). `snapshot()` returns the same `(support, freqs)`
int64 pair as `repro.core.distribution.size_histogram`, so every consumer
of the offline histogram works unchanged on the live sketch.

`histogram_distance` is the drift signal: normalized L1 (total variation)
or earth-mover's distance between two histograms over their shared
support, both in [0, 1]. The controller compares the live sketch against
the fitting-time reference histogram to decide when the schedule is
stale.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


class DecayedSizeHistogram:
    """Exponentially-decayed sparse size histogram, O(1) per observation.

    ``half_life`` is measured in *observations*: after ``half_life``
    further observations, a sample's weight has halved. ``half_life=None``
    disables decay — the sketch then reproduces ``size_histogram`` of the
    full stream exactly (used by consumers that want the legacy
    every-item-counts behaviour and by round-trip tests).
    """

    def __init__(self, *, half_life: Optional[float] = None,
                 max_bins: int = 1 << 14):
        if half_life is not None and half_life <= 0:
            raise ValueError(f"half_life must be positive, got {half_life}")
        if max_bins < 2:
            raise ValueError("max_bins must be >= 2")
        self.half_life = half_life
        self.max_bins = max_bins
        self._decay = 0.5 ** (1.0 / half_life) if half_life else 1.0
        self._w: Dict[int, float] = {}       # size -> weight at step _last[s]
        self._last: Dict[int, int] = {}      # size -> step of last update
        self._t = 0                          # observation clock
        self.n_observed = 0                  # lifetime count (undecayed)
        self._total = 0.0                    # decayed total weight

    # -- updates -----------------------------------------------------------
    def observe(self, size: int, weight: float = 1.0) -> None:
        """Record one size. O(1); decay of other bins is lazy."""
        s = int(size)
        if s < 0:
            raise ValueError(f"size must be non-negative, got {s}")
        self._t += 1
        self.n_observed += 1
        self._total = self._total * self._decay + weight
        w = self._w.get(s)
        if w is not None:
            self._w[s] = w * self._decay ** (self._t - self._last[s]) + weight
        else:
            if len(self._w) >= self.max_bins:
                self._prune()
            self._w[s] = weight
        self._last[s] = self._t

    def observe_many(self, sizes) -> None:
        for s in np.asarray(sizes).ravel().tolist():
            self.observe(int(s))

    # -- views -------------------------------------------------------------
    @property
    def effective_count(self) -> float:
        """Decayed total mass (== n_observed when decay is disabled)."""
        return self._total

    def _synced_weights(self) -> Dict[int, float]:
        """All bins decayed forward to the current step."""
        if self._decay == 1.0:
            return dict(self._w)
        return {s: w * self._decay ** (self._t - self._last[s])
                for s, w in self._w.items()}

    def _prune(self) -> None:
        """Drop the lightest ~10% of bins (called when max_bins is hit)."""
        synced = self._synced_weights()
        keep = sorted(synced, key=synced.__getitem__, reverse=True)
        keep = keep[:max(1, int(self.max_bins * 0.9))]
        kept = set(keep)
        t = self._t
        self._w = {s: synced[s] for s in keep}
        self._last = {s: t for s in keep}
        for s in list(kept):
            if self._w[s] <= 0.0:
                del self._w[s]
                del self._last[s]

    def snapshot(self) -> Tuple[np.ndarray, np.ndarray]:
        """``(support, freqs)`` int64, compatible with ``size_histogram``.

        Weights are rounded to the nearest integer; bins whose decayed
        weight rounds to zero are dropped (they no longer represent
        current traffic). With decay disabled this is bit-exact with
        ``size_histogram`` over every observed size.
        """
        synced = self._synced_weights()
        if not synced:
            return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
        support = np.asarray(sorted(synced), dtype=np.int64)
        freqs = np.rint([synced[int(s)] for s in support]).astype(np.int64)
        keep = freqs > 0
        return support[keep], freqs[keep]

    def snapshot_weights(self) -> Tuple[np.ndarray, np.ndarray]:
        """Float-weight variant of :meth:`snapshot` (no rounding) — the
        drift metric uses this to avoid quantization noise."""
        synced = self._synced_weights()
        if not synced:
            return (np.zeros(0, dtype=np.int64),
                    np.zeros(0, dtype=np.float64))
        support = np.asarray(sorted(synced), dtype=np.int64)
        w = np.asarray([synced[int(s)] for s in support], dtype=np.float64)
        keep = w > 0.0
        return support[keep], w[keep]

    def reset(self) -> None:
        self._w.clear()
        self._last.clear()
        self._t = 0
        self.n_observed = 0
        self._total = 0.0


# Public alias: the docs call this the "streaming size sketch" — the
# name says what it is for, DecayedSizeHistogram says how it works.
StreamingSizeSketch = DecayedSizeHistogram


def _aligned(a: Tuple[np.ndarray, np.ndarray],
             b: Tuple[np.ndarray, np.ndarray]
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    sa, fa = a
    sb, fb = b
    sa = np.asarray(sa, dtype=np.int64)
    sb = np.asarray(sb, dtype=np.int64)
    support = np.union1d(sa, sb)
    pa = np.zeros(len(support), dtype=np.float64)
    pb = np.zeros(len(support), dtype=np.float64)
    pa[np.searchsorted(support, sa)] = np.asarray(fa, dtype=np.float64)
    pb[np.searchsorted(support, sb)] = np.asarray(fb, dtype=np.float64)
    return support, pa, pb


def histogram_distance(a, b, *, metric: str = "l1") -> float:
    """Distance in [0, 1] between two ``(support, freqs)`` histograms.

    ``"l1"``  — total variation: ``0.5 * sum |p - q|`` of the normalized
    mass functions over the union support. Insensitive to *how far* mass
    moved; cheap and scale-free.
    ``"emd"`` — earth-mover's (Wasserstein-1) distance of the normalized
    distributions, divided by the span of the union support, so shifting
    all mass from one end to the other scores 1.
    """
    support, pa, pb = _aligned(a, b)
    if support.size == 0:
        return 0.0
    ta, tb = pa.sum(), pb.sum()
    if ta <= 0 or tb <= 0:
        return 0.0 if ta == tb else 1.0
    pa = pa / ta
    pb = pb / tb
    if metric == "l1":
        return float(0.5 * np.abs(pa - pb).sum())
    if metric == "emd":
        if support.size == 1:
            return 0.0
        span = float(support[-1] - support[0])
        cdf_gap = np.abs(np.cumsum(pa - pb))[:-1]
        gaps = np.diff(support).astype(np.float64)
        return float(np.sum(cdf_gap * gaps) / span)
    raise ValueError(f"unknown metric {metric!r}")
