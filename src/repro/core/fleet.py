"""FleetState — per-tenant arbiter state as stacked arrays.

The legacy :class:`~repro.core.arbiter.TenantArbiter` keeps one Python
object per tenant and loops over all of them every arbitration round:
pressure refresh, forecast surcharges, and donor pricing are each an
O(n_tenants) Python pass, and the drift gate is one device launch per
due tenant. Fine at 4 tenants, dead at 4,000.

This module stacks all of that state into ``[capacity, ...]`` arrays so
every decision stage runs as ONE batched operation over the whole
fleet:

* ownership / quota / floor / denial counters (the
  :class:`~repro.core.arbiter.TenantPages` fields) — int64 rows that
  the shared :class:`~repro.core.arbiter.ResourcePool` reads and
  writes *through* (:class:`_FleetRec` swaps into ``pool._tenants`` as
  an attribute-compatible view, so ``acquire``/``release``/
  ``move_quota``/``equal_partition`` mutate fleet rows transparently),
* pressure-window baselines and the demand-forecast rings
  (:meth:`record_demand` / :meth:`demand_growth` — the batched twins
  of ``DemandForecaster.record_window`` / ``demand_growth``, sharing
  :func:`~repro.core.forecast.acf_period_batch` with the scalar path
  so both are the same bits),
* drift-check cadence mirrors (``since_check`` / ``check_every``) that
  turn the arbiter's per-tick due-scan into one vectorized mask,
* optionally the device observe sketches, stacked ``[capacity,
  num_buckets]`` with :class:`FleetSketchView` giving each tenant's
  controller a :class:`~repro.core.observe.DeviceSizeSketch` whose
  weight vector IS its fleet row.

Host arrays deliberately stay int64/float64 numpy: the differential
contract of ``TenantArbiter(fleet=True)`` is *bit-identical decisions*
versus the legacy Python loop, and the legacy loop computes in Python
ints and float64 — a float32 device mirror of the pricing stage would
trade that certainty for nothing (the arrays are a few KB; the
O(n_tenants) wins come from replacing Python iteration with vectorized
numpy, and the device wins live where the data already is: the stacked
sketches and the one-launch drift gate in
``repro.kernels.fleet_gate``).

Row lifecycle: :meth:`alloc_row` / :meth:`free_row` with a LIFO
free-list, so join/leave chaos reuses rows instead of growing without
bound; a freed row is zeroed everywhere (the "free rows hold zero
mass" invariant ``scenarios.invariants.check_fleet`` enforces).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.forecast import acf_period_batch
from repro.core.observe import DeviceSizeSketch

_QUOTA_NONE = -1          # array sentinel for quota=None (unmanaged)


class _FleetRec:
    """A ``TenantPages``-shaped view over one fleet row.

    Swapped into ``ResourcePool._tenants`` when a tenant joins the
    fleet: every pool operation (acquire/release/set_owned/move_quota/
    equal_partition) keeps mutating plain attributes, and those
    attributes read and write the stacked arrays — one source of truth,
    no sync step. ``quota`` maps ``None`` ↔ the ``-1`` array sentinel.
    """

    __slots__ = ("_fleet", "_row")

    def __init__(self, fleet: "FleetState", row: int):
        object.__setattr__(self, "_fleet", fleet)
        object.__setattr__(self, "_row", row)

    @property
    def owned(self) -> int:
        return int(self._fleet.owned[self._row])

    @owned.setter
    def owned(self, v: int) -> None:
        self._fleet.owned[self._row] = v

    @property
    def quota(self) -> Optional[int]:
        q = int(self._fleet.quota[self._row])
        return None if q == _QUOTA_NONE else q

    @quota.setter
    def quota(self, v: Optional[int]) -> None:
        self._fleet.quota[self._row] = _QUOTA_NONE if v is None else int(v)

    @property
    def floor(self) -> int:
        return int(self._fleet.floor[self._row])

    @floor.setter
    def floor(self, v: int) -> None:
        self._fleet.floor[self._row] = v

    @property
    def n_denied(self) -> int:
        return int(self._fleet.n_denied[self._row])

    @n_denied.setter
    def n_denied(self, v: int) -> None:
        self._fleet.n_denied[self._row] = v


class FleetSketchView(DeviceSizeSketch):
    """A :class:`DeviceSizeSketch` whose weight vector is a fleet row.

    The parent class keeps its state in ``self._weights``; here that
    name is a property reading ``fleet.sketch[row]`` and writing
    ``fleet.sketch.at[row].set(...)``, so every inherited method
    (observe_many, flush_window, snapshot, drift fusion, donation)
    operates on the stacked ``[capacity, num_buckets]`` fleet matrix
    without knowing it. The arbiter's batched drift gate slices the
    same matrix, so due tenants never need their sketches gathered
    one by one.
    """

    def __init__(self, fleet: "FleetState", row: int, **kwargs):
        # must exist before super().__init__ assigns self._weights
        self._fleet = fleet
        self._row = int(row)
        super().__init__(**kwargs)
        if fleet.sketch.shape[1] != self.num_buckets:
            raise ValueError(
                f"fleet sketch grid has {fleet.sketch.shape[1]} buckets, "
                f"view wants {self.num_buckets}")

    @property
    def _weights(self):
        return self._fleet.sketch[self._row]

    @_weights.setter
    def _weights(self, value) -> None:
        f = self._fleet
        f.sketch = f.sketch.at[self._row].set(value)


class FleetState:
    """Stacked per-tenant arbiter state with a row free-list.

    Created by ``TenantArbiter(fleet=True)``; not normally constructed
    directly. ``forecaster`` (a ``DemandForecaster`` or None) supplies
    the ring geometry and periodicity thresholds for the stacked
    demand rings.
    """

    def __init__(self, *, capacity: int = 8, forecaster=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        fc_on = bool(getattr(forecaster, "active", False))
        self.ring = int(forecaster.ring) if fc_on else 0
        self._min_cycles = float(forecaster.min_cycles) if fc_on else 2.0
        self._min_confidence = (float(forecaster.min_confidence)
                                if fc_on else 0.1)
        c = self.capacity
        # -- pool-record fields (mutated through _FleetRec views) -----------
        self.owned = np.zeros(c, dtype=np.int64)
        self.quota = np.full(c, _QUOTA_NONE, dtype=np.int64)
        self.floor = np.zeros(c, dtype=np.int64)
        self.n_denied = np.zeros(c, dtype=np.int64)
        # -- pressure-window state ------------------------------------------
        self.evicted0 = np.zeros(c, dtype=np.int64)
        self.denials0 = np.zeros(c, dtype=np.int64)
        self.pressure = np.zeros(c, dtype=np.float64)
        self.window_demand = np.zeros(c, dtype=np.float64)
        self.last_donated = np.full(c, -1, dtype=np.int64)
        # -- drift-check cadence mirror -------------------------------------
        self.since_check = np.zeros(c, dtype=np.int64)
        self.check_every = np.zeros(c, dtype=np.int64)
        # -- forecast demand rings (left-aligned valid prefix per row) ------
        self.demand_ring = np.zeros((c, self.ring), dtype=np.float64)
        self.ring_len = np.zeros(c, dtype=np.int64)
        # -- row bookkeeping -------------------------------------------------
        self.active = np.zeros(c, dtype=bool)
        self.row_of: Dict[str, int] = {}
        self.name_of: List[Optional[str]] = [None] * c
        self._free: List[int] = []            # LIFO reuse
        self._next = 0                        # high-water mark
        # -- stacked device sketches (lazy; jnp [capacity, buckets]) --------
        self.sketch = None
        self.sketch_buckets: Optional[int] = None

    # -- rows ----------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def _grow(self, new_cap: int) -> None:
        old = self.capacity
        self.capacity = new_cap

        def widen(a, fill=0):
            out = np.full(new_cap, fill, dtype=a.dtype)
            out[:old] = a
            return out

        self.owned = widen(self.owned)
        self.quota = widen(self.quota, _QUOTA_NONE)
        self.floor = widen(self.floor)
        self.n_denied = widen(self.n_denied)
        self.evicted0 = widen(self.evicted0)
        self.denials0 = widen(self.denials0)
        self.pressure = widen(self.pressure)
        self.window_demand = widen(self.window_demand)
        self.last_donated = widen(self.last_donated, -1)
        self.since_check = widen(self.since_check)
        self.check_every = widen(self.check_every)
        self.active = widen(self.active)
        ring = np.zeros((new_cap, self.ring), dtype=np.float64)
        ring[:old] = self.demand_ring
        self.demand_ring = ring
        self.ring_len = widen(self.ring_len)
        self.name_of.extend([None] * (new_cap - old))
        if self.sketch is not None:
            import jax.numpy as jnp
            pad = jnp.zeros((new_cap - old, self.sketch.shape[1]),
                            dtype=self.sketch.dtype)
            self.sketch = jnp.concatenate([self.sketch, pad], axis=0)

    def alloc_row(self, name: str) -> int:
        if name in self.row_of:
            raise ValueError(f"tenant {name!r} already has a fleet row")
        if self._free:
            row = self._free.pop()
        else:
            if self._next >= self.capacity:
                self._grow(2 * self.capacity)
            row = self._next
            self._next += 1
        self.active[row] = True
        self.row_of[name] = row
        self.name_of[row] = name
        return row

    def free_row(self, name: str) -> None:
        """Release a tenant's row: zero every field (the free-rows-hold-
        zero-mass invariant) and push it on the free-list for reuse."""
        row = self.row_of.pop(name)
        self.name_of[row] = None
        self.active[row] = False
        self.owned[row] = 0
        self.quota[row] = _QUOTA_NONE
        self.floor[row] = 0
        self.n_denied[row] = 0
        self.evicted0[row] = 0
        self.denials0[row] = 0
        self.pressure[row] = 0.0
        self.window_demand[row] = 0.0
        self.last_donated[row] = -1
        self.since_check[row] = 0
        self.check_every[row] = 0
        self.demand_ring[row] = 0.0
        self.ring_len[row] = 0
        if self.sketch is not None:
            self.sketch = self.sketch.at[row].set(0.0)
        self._free.append(row)

    # -- pool integration ----------------------------------------------------
    def adopt_pool_record(self, pool, name: str) -> None:
        """Copy the tenant's existing ``TenantPages`` record into its
        fleet row and swap a :class:`_FleetRec` view into the pool —
        from here on the pool mutates the stacked arrays directly.
        (The allocator registers itself with the pool before the
        arbiter runs, so the record may already carry owned pages.)"""
        row = self.row_of[name]
        rec = pool._tenants[name]
        self.owned[row] = rec.owned
        self.quota[row] = _QUOTA_NONE if rec.quota is None else rec.quota
        self.floor[row] = rec.floor
        self.n_denied[row] = rec.n_denied
        pool._tenants[name] = _FleetRec(self, row)

    # -- stacked sketches ----------------------------------------------------
    def ensure_sketch(self, num_buckets: int) -> None:
        if self.sketch is None:
            import jax.numpy as jnp
            self.sketch_buckets = int(num_buckets)
            self.sketch = jnp.zeros((self.capacity, self.sketch_buckets),
                                    dtype=jnp.float32)
        elif self.sketch_buckets != int(num_buckets):
            raise ValueError(
                f"fleet sketch grid is {self.sketch_buckets} buckets; "
                f"cannot add a {num_buckets}-bucket tenant")

    def sketch_view(self, row: int, config) -> FleetSketchView:
        """A device sketch for ``row`` configured exactly as
        ``SlabController`` would configure its own, but stacked."""
        from repro.core.controller import device_sketch_kwargs
        kwargs = device_sketch_kwargs(config)
        self.ensure_sketch(kwargs["num_buckets"])
        return FleetSketchView(self, row, **kwargs)

    # -- batched forecast ring ----------------------------------------------
    def record_demand(self, rows: np.ndarray, values: np.ndarray) -> None:
        """Push one demand window per row — the batched twin of
        ``DemandForecaster.record_window`` (demand scalar only; the
        arbiter never records histograms)."""
        if self.ring == 0:
            return
        rows = np.asarray(rows, dtype=np.int64)
        values = np.asarray(values, dtype=np.float64)
        lens = self.ring_len[rows]
        full = lens >= self.ring
        fr = rows[full]
        if fr.size:
            self.demand_ring[fr, :-1] = self.demand_ring[fr, 1:]
            self.demand_ring[fr, -1] = values[full]
        nr = rows[~full]
        if nr.size:
            self.demand_ring[nr, lens[~full]] = values[~full]
            self.ring_len[nr] = lens[~full] + 1

    def demand_growth(self, rows: np.ndarray, horizon: int = 1
                      ) -> "tuple[np.ndarray, np.ndarray]":
        """(growth bytes, confidence) per row — the batched twin of
        ``DemandForecaster.demand_growth``, decision-identical because
        the periodicity detector IS the scalar one
        (:func:`acf_period_batch`) and the seasonal-naive source index
        replicates ``predict`` exactly (no period / horizon past the
        period / source before the ring ⇒ (0, 0))."""
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        rows = np.asarray(rows, dtype=np.int64)
        n = rows.size
        growth = np.zeros(n, dtype=np.float64)
        conf = np.zeros(n, dtype=np.float64)
        if self.ring == 0 or n == 0:
            return growth, conf
        lens = self.ring_len[rows]
        series = self.demand_ring[rows]
        lags, confs = acf_period_batch(
            series, lens, min_cycles=self._min_cycles,
            min_confidence=self._min_confidence)
        src = lens - 1 + horizon - lags
        ok = (lags >= 0) & (horizon <= lags) & (src >= 0)
        idx = np.nonzero(ok)[0]
        if idx.size:
            growth[idx] = (series[idx, src[idx]]
                           - series[idx, lens[idx] - 1])
            conf[idx] = confs[idx]
        return growth, conf
