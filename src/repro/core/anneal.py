"""Simulated-annealing slab-class search (beyond-paper variant).

Same move set as the paper's Algorithm 1 but with geometric step sizes and
a Metropolis accept rule, so the walk can cross waste barriers between
modes of a multimodal size distribution — exactly the case where the
paper's strictly-greedy walk strands classes (tests/test_dp_optimal.py).
Runs as one jitted ``lax.fori_loop``; tracks best-so-far.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.guards import deliberate_sync
from repro.core.distribution import PAGE_SIZE
from repro.core.hillclimb import MIN_CHUNK, SearchResult
from repro.core.waste import waste_exact, waste_jax


@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "page_size", "min_chunk"))
def _anneal_jax(key, init_chunks, support, freqs, *, n_steps: int,
                t0: float, t_final: float, page_size: int, min_chunk: int):
    k = init_chunks.shape[0]
    alpha = (t_final / t0) ** (1.0 / max(n_steps - 1, 1))

    def waste_of(c):
        return waste_jax(c, support, freqs, page_size=page_size)

    def body(i, state):
        key, chunks, cur, best_chunks, best = state
        key, k_cls, k_mag, k_dir, k_acc = jax.random.split(key, 5)
        j = jax.random.randint(k_cls, (), 0, k)
        mag = jnp.int32(2) ** jax.random.randint(k_mag, (), 0, 9)  # 1..256
        delta = jnp.where(jax.random.bernoulli(k_dir), mag, -mag)
        cand = jnp.clip(chunks.at[j].add(delta), min_chunk, page_size)
        new = waste_of(cand)
        temp = t0 * alpha ** i
        accept = jnp.logical_or(
            new <= cur,
            jax.random.uniform(k_acc) < jnp.exp(-(new - cur) / temp))
        chunks = jnp.where(accept, cand, chunks)
        cur = jnp.where(accept, new, cur)
        better = cur < best
        best_chunks = jnp.where(better, chunks, best_chunks)
        best = jnp.where(better, cur, best)
        return key, chunks, cur, best_chunks, best

    init = init_chunks.astype(jnp.int32)
    w0 = waste_of(init)
    state = (key, init, w0, init, w0)
    _, _, _, best_chunks, _ = jax.lax.fori_loop(0, n_steps, body, state)
    return best_chunks


def anneal(key, init_chunks, support, freqs, *, n_steps: int = 20_000,
           t0: float | None = None, t_final: float = 1.0,
           page_size: int = PAGE_SIZE,
           min_chunk: int = MIN_CHUNK) -> SearchResult:
    support_j = jnp.asarray(support, dtype=jnp.int32)
    freqs_j = jnp.asarray(freqs, dtype=jnp.float32)
    init_waste = waste_exact(init_chunks, support, freqs,
                             page_size=page_size)
    if t0 is None:
        t0 = max(float(init_waste) * 1e-3, 1.0)
    chunks = _anneal_jax(key, jnp.asarray(init_chunks, dtype=jnp.int32),
                         support_j, freqs_j, n_steps=n_steps, t0=t0,
                         t_final=t_final, page_size=page_size,
                         min_chunk=min_chunk)
    with deliberate_sync("anneal.result"):
        chunks = np.sort(np.asarray(chunks, dtype=np.int64))
    return SearchResult(
        chunks=chunks,
        waste=waste_exact(chunks, support, freqs, page_size=page_size),
        init_waste=init_waste, steps=n_steps, method="anneal")
