"""Demand forecasting — the predictive half the paper's loop is missing.

The paper's refit is purely reactive: it learns a traffic pattern only
*after* the holes have appeared. Production cache and serving traffic is
strongly periodic (diurnal peaks, out-of-phase tenant cycles), so the
recent past predicts the near future well enough to act on. This module
is the shared forecast layer the reactive consumers plug into:

* :class:`DemandForecaster` — keeps a ring of per-window sketch
  snapshots per *stream* (one stream per controller, tenant, or serving
  stream), detects periodicity by autocorrelation over the per-window
  demand series, and answers :meth:`predict` with the seasonal-naive
  forecast: the recorded window one detected period back from the
  requested horizon — an expected size histogram plus expected demand
  bytes, tagged with the autocorrelation confidence.
* :class:`Reactive` — the null forecaster. ``active`` is False, every
  method is a no-op, ``predict`` returns ``None``: consumers built
  against the seam reproduce today's reactive behaviour bit-for-bit
  (the parity tests in ``tests/test_forecast.py`` hold decisions AND
  sync counts equal).

Consumers (see their modules for the integration contract):

* ``SlabController`` (``ControllerConfig(forecast=...)``) records its
  live sketch at every drift check and fires *predictive* refits when
  the forecast mixture — not the live one — has drifted from the
  reference, pre-positioning the schedule before the peak.
* ``TenantArbiter`` records per-tenant demand per arbitration window
  and prices donors by their forecast demand trajectory: pages are not
  taken from a tenant that is about to need them.

Windows may be host ``(support, weights)`` pairs or dense device weight
vectors (``DeviceSizeSketch.weights_device`` — functionally immutable,
so storing the reference is a zero-copy, zero-sync snapshot); the
periodicity detector only ever needs the one demand scalar per window.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Forecast:
    """One answer of :meth:`DemandForecaster.predict`.

    Exactly one of ``(support, weights)`` / ``device_weights`` is set,
    matching the representation the windows were recorded in.
    """

    demand_bytes: float          # expected demand at the horizon
    confidence: float            # autocorrelation of the detected period
    period: int                  # detected period, in windows
    horizon: int                 # windows ahead this forecast is for
    support: Optional[np.ndarray] = None     # expected size histogram
    weights: Optional[np.ndarray] = None
    device_weights: Optional[object] = None  # dense device weight vector


@dataclasses.dataclass
class _Window:
    demand_bytes: float
    support: Optional[np.ndarray] = None
    weights: Optional[np.ndarray] = None
    device_weights: Optional[object] = None


class _Stream:
    __slots__ = ("windows",)

    def __init__(self, ring: int):
        self.windows: Deque[_Window] = deque(maxlen=ring)


class Reactive:
    """The null forecaster: today's behaviour, bit for bit.

    Consumers check ``active`` before doing any forecast work at all, so
    a ``Reactive`` (or ``forecast=None``) consumer records nothing,
    syncs nothing, and decides exactly as the pre-forecast code did.
    """

    active = False

    def record_window(self, stream: str, *, demand_bytes: float = 0.0,
                      support=None, weights=None,
                      device_weights=None) -> None:
        pass

    def predict(self, stream: str, horizon: int = 1) -> Optional[Forecast]:
        return None

    def demand_growth(self, stream: str, horizon: int = 1
                      ) -> Tuple[float, float]:
        """(predicted demand increase in bytes, confidence) — (0, 0)."""
        return 0.0, 0.0


class DemandForecaster:
    """Periodicity-aware seasonal-naive forecaster over window snapshots.

    ``ring`` bounds how many windows are kept per stream; the detector
    needs at least ``min_cycles`` full cycles inside the ring before it
    trusts a period, so the longest detectable period is
    ``ring / min_cycles`` windows. ``min_confidence`` is the
    autocorrelation floor below which :meth:`predict` returns ``None``
    (consumers typically gate again with their own, stricter threshold).

    One forecaster instance serves many *streams* (one per tenant /
    controller); streams share nothing but the configuration.
    """

    active = True

    def __init__(self, *, ring: int = 96, min_cycles: float = 2.0,
                 min_confidence: float = 0.1):
        if ring < 8:
            raise ValueError(f"ring must be >= 8 windows, got {ring}")
        if min_cycles < 1.0:
            raise ValueError(f"min_cycles must be >= 1, got {min_cycles}")
        self.ring = int(ring)
        self.min_cycles = float(min_cycles)
        self.min_confidence = float(min_confidence)
        self._streams: Dict[str, _Stream] = {}
        self.n_windows = 0                 # lifetime windows recorded

    # -- recording -----------------------------------------------------------
    def record_window(self, stream: str, *, demand_bytes: float,
                      support: Optional[np.ndarray] = None,
                      weights: Optional[np.ndarray] = None,
                      device_weights=None) -> None:
        """Append one window snapshot to ``stream``'s ring.

        ``demand_bytes`` is the window's scalar summary (the periodicity
        series). The histogram is optional — the arbiter records demand
        only; the controller records the full sketch so predictive
        refits can score candidate schedules against the forecast
        mixture. ``device_weights`` stores the dense device vector by
        reference (no copy, no sync — sketch updates are functional, so
        the reference is a stable snapshot).
        """
        st = self._streams.get(stream)
        if st is None:
            st = self._streams[stream] = _Stream(self.ring)
        st.windows.append(_Window(
            demand_bytes=float(demand_bytes),
            support=None if support is None else np.asarray(support),
            weights=None if weights is None else np.asarray(weights),
            device_weights=device_weights))
        self.n_windows += 1

    # -- periodicity ---------------------------------------------------------
    def demand_series(self, stream: str) -> np.ndarray:
        st = self._streams.get(stream)
        if st is None:
            return np.zeros(0, dtype=np.float64)
        return np.asarray([w.demand_bytes for w in st.windows],
                          dtype=np.float64)

    def period(self, stream: str) -> Tuple[Optional[int], float]:
        """Detected period (in windows) and its autocorrelation, or
        ``(None, 0.0)``. A lag ``L`` is admissible when ``min_cycles``
        full cycles fit in the recorded series; the winner is the
        best-correlated LOCAL MAXIMUM of the autocorrelation function
        over the centred demand series — a smooth periodic series
        correlates well at every small lag (neighbouring windows look
        alike), so the global max would lock onto lag 2 and never see
        the cycle; the true period is where the ACF *peaks*. A flat
        series has no period (every lag would correlate perfectly, but
        there is nothing to forecast).

        Delegates to :func:`acf_period_batch` with a single row, so the
        scalar answer and the fleet-batched answer go through the one
        implementation and cannot diverge (the bit-parity contract the
        ``TenantArbiter(fleet=True)`` differential suite relies on)."""
        s = self.demand_series(stream)
        lags, confs = acf_period_batch(
            s[None, :], np.array([len(s)], dtype=np.int64),
            min_cycles=self.min_cycles, min_confidence=self.min_confidence)
        if lags[0] < 0:
            return None, 0.0
        return int(lags[0]), float(confs[0])

    # -- prediction ----------------------------------------------------------
    def predict(self, stream: str, horizon: int = 1) -> Optional[Forecast]:
        """Seasonal-naive forecast ``horizon`` windows ahead: the
        recorded window at ``now + horizon - period``. ``None`` when no
        period is detected (or the horizon reaches past one period —
        the seasonal-naive model has nothing to say there)."""
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        lag, conf = self.period(stream)
        if lag is None or horizon > lag:
            return None
        windows = self._streams[stream].windows
        # index of "now" is len-1; the forecast source is now+h-L
        src = len(windows) - 1 + horizon - lag
        if src < 0:
            return None
        w = windows[src]
        return Forecast(demand_bytes=w.demand_bytes, confidence=conf,
                        period=lag, horizon=horizon, support=w.support,
                        weights=w.weights, device_weights=w.device_weights)

    def demand_growth(self, stream: str, horizon: int = 1
                      ) -> Tuple[float, float]:
        """(predicted demand increase over the current window, in bytes;
        confidence). Positive means the stream is heading into a peak —
        the arbiter's "don't take pages it is about to need" signal.
        Zero (not negative clamped) growth is returned as-is so callers
        can also spot falling demand."""
        fc = self.predict(stream, horizon)
        if fc is None:
            return 0.0, 0.0
        s = self.demand_series(stream)
        return fc.demand_bytes - float(s[-1]), fc.confidence


def acf_period_batch(series: np.ndarray, lengths: np.ndarray, *,
                     min_cycles: float, min_confidence: float
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized ACF peak detection over many demand series at once.

    ``series`` is ``[n_streams, max_len]`` float64, row ``c`` valid over
    ``series[c, :lengths[c]]`` (entries past the length are ignored).
    Returns ``(lags, confs)``: detected period per row (``-1`` for none)
    and its autocorrelation (``0.0`` for none).

    Rows are grouped by length and each group is processed on arrays
    trimmed to exactly that length, with all inner products going
    through one ``np.einsum`` code path. That makes a batch of N rows
    bit-identical to N single-row calls — the reduction order depends
    only on the row length, never on the batch size — which is what
    lets :meth:`DemandForecaster.period` (scalar, legacy arbiter) and
    the fleet-stacked ring (``TenantArbiter(fleet=True)``) share this
    one implementation and stay decision-identical.

    Lengths saturate at the forecaster ring size, so a steady fleet
    collapses to a single group; join/leave churn adds at most one
    group per distinct join cohort.
    """
    series = np.asarray(series, dtype=np.float64)
    lengths = np.asarray(lengths, dtype=np.int64)
    n = series.shape[0]
    lags = np.full(n, -1, dtype=np.int64)
    confs = np.zeros(n, dtype=np.float64)
    for ln in np.unique(lengths):
        max_lag = int(int(ln) / min_cycles)
        if max_lag < 3:
            continue
        idx = np.nonzero(lengths == ln)[0]
        length = int(ln)
        s = series[idx, :length]
        mean = np.einsum("cj->c", s) / float(length)
        s = s - mean[:, None]
        var = np.einsum("cj,cj->c", s, s)
        ok = (var > 0.0) & np.isfinite(var)
        denom_floor = 1e-12 * var
        acf = np.full((len(idx), max_lag + 2), -np.inf)
        for lag in range(1, max_lag + 2):
            if lag >= length:
                break
            a, b = s[:, lag:], s[:, :length - lag]
            denom = np.sqrt(np.einsum("cj,cj->c", a, a)
                            * np.einsum("cj,cj->c", b, b))
            num = np.einsum("cj,cj->c", a, b)
            with np.errstate(divide="ignore", invalid="ignore"):
                vals = num / denom
            acf[:, lag] = np.where(ok & (denom > denom_floor), vals,
                                   -np.inf)
        best_r = np.zeros(len(idx))
        best_lag = np.full(len(idx), -1, dtype=np.int64)
        for lag in range(2, max_lag + 1):
            r, lo, hi = acf[:, lag], acf[:, lag - 1], acf[:, lag + 1]
            # a peak, not a shoulder: both neighbours computed and lower
            cand = (np.isfinite(r) & np.isfinite(lo) & np.isfinite(hi)
                    & (lo <= r) & (r >= hi) & (r > best_r))
            best_lag = np.where(cand, lag, best_lag)
            best_r = np.where(cand, r, best_r)
        good = (best_lag >= 0) & (best_r >= min_confidence)
        lags[idx] = np.where(good, best_lag, -1)
        confs[idx] = np.where(good, best_r, 0.0)
    return lags, confs


def blend_histograms(live: Tuple[np.ndarray, np.ndarray],
                     forecast: Tuple[np.ndarray, np.ndarray],
                     frac_forecast: float
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Mass-preserving blend of two ``(support, weights)`` histograms.

    The forecast histogram is rescaled to the live histogram's total
    mass first (the two windows were recorded at different decay
    states; only the *shape* of the forecast matters), then blended
    ``(1 - f) * live + f * forecast`` over the merged support. The
    controller scores predictive candidate schedules against this
    mixture, so a pre-positioned schedule must serve both the traffic
    that is here and the traffic that is coming — the first half of the
    anti-thrash hysteresis.
    """
    if not 0.0 <= frac_forecast <= 1.0:
        raise ValueError(
            f"frac_forecast must be in [0, 1], got {frac_forecast}")
    ls, lw = np.asarray(live[0]), np.asarray(live[1], dtype=np.float64)
    fs, fw = np.asarray(forecast[0]), np.asarray(forecast[1],
                                                 dtype=np.float64)
    if ls.size == 0:
        return fs, fw
    if fs.size == 0 or frac_forecast == 0.0:
        return ls, lw
    scale = lw.sum() / max(fw.sum(), 1e-30)
    support = np.union1d(ls, fs)
    out = np.zeros(len(support), dtype=np.float64)
    out[np.searchsorted(support, ls)] += (1.0 - frac_forecast) * lw
    out[np.searchsorted(support, fs)] += frac_forecast * scale * fw
    keep = out > 0.0
    return support[keep], out[keep]
