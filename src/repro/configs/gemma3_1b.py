"""gemma3-1b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ModelConfig, local_global_pattern

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    block_pattern=local_global_pattern(26, 5),
    sliding_window=512,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    post_block_norms=True,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
