"""zamba2-7b [hybrid] — Mamba2 blocks + shared attention block.

81 block applications = 27 groups of [mamba2, mamba2, shared-attn]; the
attention+MLP block weights are shared across all 27 applications (the
Zamba2 design), each application keeping its own KV cache. Shared
attention runs sliding-window at long context (DESIGN.md §6).
[arXiv:2411.15242; unverified]
"""
from repro.configs.base import MAMBA2, SHARED_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    block_pattern=(MAMBA2, MAMBA2, SHARED_ATTN) * 27,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    sliding_window=4096,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    activation="gelu",
    tie_embeddings=True,
    source="arXiv:2411.15242",
)
