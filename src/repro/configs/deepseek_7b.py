"""deepseek-7b [dense] — llama-architecture MHA decoder.

[arXiv:2401.02954; hf]
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102_400,
    block_pattern=uniform_pattern(ATTN_GLOBAL, 30),
    activation="silu",
    tie_embeddings=False,
    source="arXiv:2401.02954",
)
