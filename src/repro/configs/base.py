"""Config system: one dataclass drives model build, sharding, and launch.

Every assigned architecture is a ``ModelConfig`` instance in its own file
(``repro/configs/<arch>.py``), selectable by ``--arch <id>`` in the
launchers. ``reduced()`` derives the family-preserving small config used
by per-arch smoke tests (full configs are exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Layer-kind tags used in ModelConfig.block_pattern
ATTN_GLOBAL = "attn_global"
ATTN_LOCAL = "attn_local"     # sliding-window attention
MAMBA2 = "mamba2"
MLSTM = "mlstm"
SLSTM = "slstm"
SHARED_ATTN = "shared_attn"   # zamba2: one weight set, applied at each tag


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention structure ---
    block_pattern: Tuple[str, ...] = ()   # len == n_layers (decoder stack)
    sliding_window: int = 1024            # used by ATTN_LOCAL layers
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None  # gemma3: 1e6 on global layers
    qk_norm: bool = False                 # gemma3
    post_block_norms: bool = False        # gemma3 post-attn/post-mlp norms
    attn_logit_softcap: float = 0.0       # gemma2-style (0 = off)

    # --- ffn ---
    activation: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)

    # --- moe ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False      # arctic: dense MLP in parallel
    router_aux_loss: float = 0.01
    moe_dispatch_dtype: str = "float32"   # bf16 halves dispatch wire bytes
    moe_ep_constraints: bool = False      # pin the EP all-to-all boundary

    # --- ssm (mamba2 / xlstm) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssm_chunk: int = 128

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_is_causal: bool = False

    # --- vlm (llama-3.2-vision) ---
    cross_attn_layers: Tuple[int, ...] = ()  # decoder layer idxs w/ cross-attn
    n_image_tokens: int = 0                  # stub patch-embedding count

    # --- embedding / misc ---
    embed_scale: bool = False             # gemma: x * sqrt(d_model)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # --- serving knobs ---
    cache_write: str = "dus"   # "onehot": SPMD-friendly for sharded seq

    # --- training knobs ---
    remat: bool = True
    use_scan: bool = True

    # --- provenance ---
    source: str = ""

    def __post_init__(self):
        if self.block_pattern and len(self.block_pattern) != self.n_layers:
            raise ValueError(
                f"{self.name}: block_pattern has {len(self.block_pattern)} "
                f"entries for n_layers={self.n_layers}")

    # ------------------------------------------------------------------
    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        glu = 3 if self.activation in ("silu", "gelu") else 2
        attn = d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d
        dense_mlp = glu * d * ff
        moe_mlp = (self.n_experts * glu * d * ff + d * self.n_experts
                   + (dense_mlp if self.moe_dense_residual else 0))
        d_in = self.ssm_expand * d
        mamba = (d * (2 * d_in + 2 * self.ssm_state + self.ssm_heads)
                 + d_in * d + self.conv_kernel
                 * (d_in + 2 * self.ssm_state))
        pattern = self.block_pattern or (ATTN_GLOBAL,) * self.n_layers
        shared_attn_counted = False
        for kind in pattern:
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                total += attn + (moe_mlp if self.n_experts else dense_mlp)
            elif kind == SHARED_ATTN:
                if not shared_attn_counted:
                    total += attn + dense_mlp
                    shared_attn_counted = True
            elif kind == MAMBA2:
                total += mamba
            elif kind in (MLSTM, SLSTM):
                total += 4 * d * d_in + d_in * d  # qkv/gates + out
        total += self.encoder_layers * (attn + dense_mlp)
        for _ in self.cross_attn_layers:
            total += attn + 2 * d * self.kv_dim
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        glu = 3 if self.activation in ("silu", "gelu") else 2
        inactive = ((self.n_experts - self.experts_per_token)
                    * glu * d * ff * self.n_layers)
        return self.param_count() - inactive

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        factor = max(self.n_layers // 4, 1)
        n_layers = max(self.n_layers // factor, 2)
        # families built from fixed-size layer groups need n_layers to be a
        # multiple of the group size (hybrid: [m,m,attn]; ssm: 7xmLSTM+sLSTM;
        # vlm: 4 self + 1 cross)
        group = {"hybrid": 3, "ssm": 8, "vlm": 5}.get(self.family, 1)
        n_layers = group * max(1, round(n_layers / group))
        pattern = self.block_pattern
        if pattern:
            if group > 1:
                # preserve the group structure exactly
                pattern = tuple(pattern[:group]) * (n_layers // group)
            else:
                # keep the family structure: subsample the pattern
                step = len(pattern) / n_layers
                pattern = tuple(pattern[min(int(i * step), len(pattern) - 1)]
                                for i in range(n_layers))
                # ensure at least one of each kind survives
                for kind in set(self.block_pattern):
                    if kind not in pattern:
                        pattern = pattern[:-1] + (kind,)
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=128,
            n_heads=max(min(self.n_heads, 4), 1),
            n_kv_heads=max(min(self.n_kv_heads, 2), 1),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            block_pattern=pattern,
            sliding_window=min(self.sliding_window, 32),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=(min(self.experts_per_token, 2)
                               if self.n_experts else 0),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=16,
            encoder_layers=2 if self.encoder_layers else 0,
            cross_attn_layers=((1,) if self.cross_attn_layers else ()),
            n_image_tokens=8 if self.n_image_tokens else 0,
            dtype="float32",
        )


def uniform_pattern(kind: str, n: int) -> Tuple[str, ...]:
    return (kind,) * n


def local_global_pattern(n: int, locals_per_global: int,
                         ) -> Tuple[str, ...]:
    """gemma3-style: N local layers then 1 global, repeating."""
    out = []
    for i in range(n):
        if (i + 1) % (locals_per_global + 1) == 0:
            out.append(ATTN_GLOBAL)
        else:
            out.append(ATTN_LOCAL)
    return tuple(out)
