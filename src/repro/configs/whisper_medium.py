"""whisper-medium [audio] — enc-dec backbone; conv frontend stubbed.

input_specs() provides precomputed frame embeddings (B, frames, d_model)
per the assignment; positional scheme unified to RoPE (DESIGN.md).
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    block_pattern=uniform_pattern(ATTN_GLOBAL, 24),
    activation="gelu",
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
