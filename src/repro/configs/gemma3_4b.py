"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-4b-pt; unverified]
"""
from repro.configs.base import ModelConfig, local_global_pattern

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    block_pattern=local_global_pattern(34, 5),
    sliding_window=1024,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    post_block_norms=True,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-4b-pt",
)
