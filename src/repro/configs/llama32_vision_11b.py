"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer.

Vision tower stubbed: input_specs() provides projected patch embeddings
(B, n_image_tokens, d_model). 40 layers = 8 groups of [4 self-attn,
1 gated cross-attn]. [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    n_image_tokens=1601,
    activation="silu",
    tie_embeddings=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
