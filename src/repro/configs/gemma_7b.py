"""gemma-7b [dense] — GeGLU, head_dim=256, full attention.

[arXiv:2403.08295; hf]
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    block_pattern=uniform_pattern(ATTN_GLOBAL, 28),
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    source="arXiv:2403.08295",
)
