"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP.

Snowflake's dense-MoE hybrid: every layer runs a dense FFN in parallel
with the routed expert branch. [hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    block_pattern=uniform_pattern(ATTN_GLOBAL, 35),
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    activation="silu",
    tie_embeddings=False,
    source="hf:Snowflake/snowflake-arctic-base",
)
