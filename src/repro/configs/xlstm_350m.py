"""xlstm-350m [ssm] — mLSTM + sLSTM blocks, no separate FFN (d_ff=0).

24 layers as 3 groups of [7 x mLSTM, 1 x sLSTM]; recurrent state is
O(1)/request, the paper technique's data-path-only case (DESIGN.md §5).
[arXiv:2405.04517; unverified]
"""
from repro.configs.base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    block_pattern=((MLSTM,) * 7 + (SLSTM,)) * 3,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50_304,
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=128,
    activation="gelu",
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
