"""The paper's own experiment configs: Tables 1-5 log-normal workloads.

Not a neural architecture — the slab-learning operating points, exposed
here so launchers can treat `--arch paper-lognormal-tN` uniformly.
"""
from repro.core.distribution import PAPER_WORKLOADS

WORKLOADS = {f"paper-lognormal-t{w.table}": w for w in PAPER_WORKLOADS}
