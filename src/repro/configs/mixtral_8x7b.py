"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

All-SWA means the decode cache is a rolling window buffer, which is what
makes the long_500k cell tractable. [arXiv:2401.04088; hf]
"""
from repro.configs.base import ATTN_LOCAL, ModelConfig, uniform_pattern

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32_000,
    block_pattern=uniform_pattern(ATTN_LOCAL, 32),
    sliding_window=4096,
    n_experts=8,
    experts_per_token=2,
    activation="silu",
    tie_embeddings=False,
    source="arXiv:2401.04088",
)
