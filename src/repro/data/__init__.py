"""Data pipeline: synthetic corpus, learned length buckets, prefetch."""
from repro.data.bucketing import (BucketScheme, batch_by_bucket, fit_buckets,
                                  padding_waste, pow2_buckets)
from repro.data.pipeline import (DataConfig, Prefetcher, SyntheticCorpus,
                                 fit_corpus_buckets, make_batches)

__all__ = ["BucketScheme", "batch_by_bucket", "fit_buckets",
           "padding_waste", "pow2_buckets", "DataConfig", "Prefetcher",
           "SyntheticCorpus", "fit_corpus_buckets", "make_batches"]
