"""Synthetic data pipeline: variable-length LM batches with prefetch.

Produces next-token-prediction batches from a synthetic corpus whose
sample lengths follow a configurable log-normal (matching the paper's
traffic shape — and realistic SFT mixtures). Batches are padded either
to fixed max length (baseline) or to learned buckets (bucketing.py);
the trainer sees {"tokens": (B, S+1)} with pad tokens masked as label -1
replaced by 0 + loss weighting left to z-loss-free CE on real tokens.

A double-buffered background thread keeps one batch ahead of the step
(host-side prefetch; on a real pod this also overlaps H2D).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.distribution import lognormal_params_from_moments
from repro.data.bucketing import BucketScheme, fit_buckets


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch_size: int = 8
    max_len: int = 512
    length_mean: float = 300.0
    length_std: float = 140.0
    seed: int = 0
    learned_buckets: int = 0     # 0 = pad to max_len; K > 0 = fit K buckets
    zipf_alpha: float = 1.2      # token-id distribution


class SyntheticCorpus:
    """Deterministic synthetic corpus with log-normal sample lengths."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        mu, sig = lognormal_params_from_moments(cfg.length_mean,
                                                cfg.length_std)
        self._mu, self._sig = mu, sig
        # zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_alpha
        self._p = p / p.sum()

    def sample_lengths(self, n: int) -> np.ndarray:
        raw = self._rng.lognormal(self._mu, self._sig, size=n)
        return np.clip(raw, 8, self.cfg.max_len).astype(np.int64)

    def sample(self, length: int) -> np.ndarray:
        return self._rng.choice(self.cfg.vocab_size, size=length,
                                p=self._p).astype(np.int32)


def make_batches(cfg: DataConfig,
                 scheme: Optional[BucketScheme] = None
                 ) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {"tokens": (B, S+1)} padded batches forever."""
    corpus = SyntheticCorpus(cfg)
    while True:
        lengths = corpus.sample_lengths(cfg.batch_size)
        if scheme is not None:
            pad_to = int(scheme.padded_length(lengths).max())
        else:
            pad_to = cfg.max_len
        batch = np.zeros((cfg.batch_size, pad_to + 1), dtype=np.int32)
        for i, ln in enumerate(lengths):
            batch[i, :ln] = corpus.sample(int(ln))
        yield {"tokens": batch, "lengths": lengths}


def fit_corpus_buckets(cfg: DataConfig, k: int, *,
                       n_probe: int = 50_000) -> BucketScheme:
    """Learn bucket boundaries from a probe of the corpus length
    distribution (the paper's 'observe then re-configure' loop)."""
    corpus = SyntheticCorpus(
        dataclasses.replace(cfg, seed=cfg.seed + 104729))
    lengths = corpus.sample_lengths(n_probe)
    return fit_buckets(lengths, k, max_len=cfg.max_len)


class Prefetcher:
    """One-batch-ahead background prefetch with clean shutdown."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
