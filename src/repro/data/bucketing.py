"""Learned sequence-length buckets — the paper's technique in the data path.

Variable-length training samples must be padded to a bucket length; the
bucket boundaries are slab classes, padding is the memory hole, and the
objective is identical to the paper's: given the observed length
histogram and a bucket budget K, minimize total padded tokens. We use the
exact DP optimizer by default (lengths histograms are small), the paper's
hill climbing as an option.

Padding waste costs compute quadratically in attention, so we also expose
a FLOP-weighted objective (weight each length by ~its attention cost) as
a beyond-paper refinement.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import SlabPolicy, size_histogram, waste_exact


@dataclasses.dataclass(frozen=True)
class BucketScheme:
    boundaries: np.ndarray           # sorted bucket lengths
    padded_tokens: int               # real + padding, fitting histogram
    baseline_boundaries: np.ndarray
    baseline_padded_tokens: int
    real_tokens: int = 0

    @property
    def recovered_frac(self) -> float:
        """Fraction of PADDING waste recovered vs the pow2 baseline
        (the paper's §5 metric, waste-only — not diluted by real
        tokens)."""
        base_waste = self.baseline_padded_tokens - self.real_tokens
        if base_waste <= 0:
            return 0.0
        waste = self.padded_tokens - self.real_tokens
        return 1.0 - waste / base_waste

    def bucket_for(self, lengths) -> np.ndarray:
        idx = np.searchsorted(self.boundaries, np.asarray(lengths), "left")
        return np.minimum(idx, len(self.boundaries) - 1)

    def padded_length(self, lengths) -> np.ndarray:
        return self.boundaries[self.bucket_for(lengths)]


def pow2_buckets(max_len: int, min_len: int = 16) -> np.ndarray:
    out = []
    b = min_len
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return np.asarray(out, dtype=np.int64)


def fit_buckets(lengths: Sequence[int], k: int, *,
                max_len: int | None = None, method: str = "dp",
                align: int = 1, seed: int = 0) -> BucketScheme:
    """Learn K bucket lengths minimizing padded tokens."""
    lengths = np.asarray(lengths, dtype=np.int64)
    if max_len is not None:
        lengths = np.minimum(lengths, max_len)
    if align > 1:
        lengths_q = ((lengths + align - 1) // align) * align
    else:
        lengths_q = lengths
    support, freqs = size_histogram(lengths_q)
    top = int(support.max())
    baseline = pow2_buckets(top)
    policy = SlabPolicy(page_size=max(top * 2, 1 << 20), min_chunk=1,
                        seed=seed)
    sched = policy.fit(support, freqs, k, method=method, baseline=baseline)
    boundaries = sched.chunk_sizes
    if align > 1:
        boundaries = np.unique(((boundaries + align - 1) // align) * align)
    real = int(np.sum(support * freqs))
    return BucketScheme(
        boundaries=boundaries,
        padded_tokens=int(waste_exact(boundaries, support, freqs)) + real,
        baseline_boundaries=baseline,
        baseline_padded_tokens=int(waste_exact(baseline, support, freqs))
        + real,
        real_tokens=real)


def padding_waste(boundaries, lengths) -> Tuple[int, float]:
    """(padded tokens beyond real tokens, waste fraction of padded)."""
    lengths = np.asarray(lengths, dtype=np.int64)
    support, freqs = size_histogram(lengths)
    waste = int(waste_exact(np.asarray(boundaries, dtype=np.int64),
                            support, freqs))
    total = int(np.sum(lengths)) + waste
    return waste, waste / max(total, 1)


def batch_by_bucket(lengths: Sequence[int], scheme: BucketScheme,
                    batch_size: int) -> List[Tuple[int, np.ndarray]]:
    """Group sample indices into (bucket_len, idx-batch) lists."""
    lengths = np.asarray(lengths)
    buckets = scheme.bucket_for(lengths)
    out = []
    for b in np.unique(buckets):
        idx = np.nonzero(buckets == b)[0]
        for i in range(0, len(idx), batch_size):
            out.append((int(scheme.boundaries[b]),
                        idx[i:i + batch_size]))
    return out
