"""Sharding rules: parameter/activation/cache PartitionSpecs per mesh.

Conventions (GSPMD mesh axes):
  'pod'   — cross-pod axis (multi-pod mesh only): pure data parallel by
            default (the slow DCN hop carries one gradient all-reduce).
  'data'  — intra-pod data parallelism; also hosts ZeRO-sharded optimizer
            moments, MoE expert parallelism, and sequence parallelism for
            long-context decode (B=1 cells).
  'model' — tensor parallelism: attention heads / FFN hidden / vocab.

Rules are applied by leaf path-name matching over the param pytree, so
every family's parameter naming (wq/wk/wv/wo, we_*, in_proj, ...) maps
without per-model code.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.treeutil import simple_keystr

# (regex on 'path/leafname', spec builder given leaf ndim)
# Specs are written for the UNSTACKED leaf; stacked layer dims (leading
# scan axes) are padded with None automatically by _pad_spec.
_RULES: Tuple[Tuple[str, P], ...] = (
    # embeddings: vocab on model axis
    (r"embedding/embed$", P("model", None)),
    (r"embedding/unembed$", P(None, "model")),
    # attention: head (output) dim on model axis
    (r"attn/wq$", P(None, "model")),
    (r"attn/wk$", P(None, "model")),
    (r"attn/wv$", P(None, "model")),
    (r"attn/wo$", P("model", None)),
    (r"xattn/wq$", P(None, "model")),
    (r"xattn/wk$", P(None, "model")),
    (r"xattn/wv$", P(None, "model")),
    (r"xattn/wo$", P("model", None)),
    # dense mlp: hidden dim on model axis
    (r"mlp/wg$", P(None, "model")),
    (r"mlp/wi$", P(None, "model")),
    (r"mlp/wo$", P("model", None)),
    (r"dense/wg$", P(None, "model")),
    (r"dense/wi$", P(None, "model")),
    (r"dense/wo$", P("model", None)),
    # moe: experts on data axis (EP), expert hidden on model axis (TP)
    (r"moe/we_gate$", P("data", None, "model")),
    (r"moe/we_in$", P("data", None, "model")),
    (r"moe/we_out$", P("data", "model", None)),
    (r"moe/router$", P(None, None)),
    # mamba2: inner channels on model axis
    (r"in_proj$", P(None, "model")),
    (r"out_proj$", P("model", None)),
    (r"conv_w$", P(None, "model")),
    (r"conv_b$", P("model")),
    (r"gate_norm$", P("model")),
    # xlstm
    (r"wgate$", P(None, "model")),
    (r"wog$", P(None, "model")),
    (r"wx$", P(None, "model")),
    (r"out_norm$", P("model")),
    (r"(^|/)r$", P(None, None, "model")),
    (r"mlstm.*/(wq|wk|wv)$", P(None, "model")),
    (r"mlstm.*/wo$", P("model", None)),
)


def _pad_spec(spec: P, ndim: int) -> P:
    """Left-pad a spec with None for stacked (scan) leading dims."""
    parts = tuple(spec)
    if len(parts) > ndim:
        # small leaves (biases/norms stacked): drop leading Nones
        parts = parts[len(parts) - ndim:]
    return P(*([None] * (ndim - len(parts)) + list(parts)))


def _shardable(dim: int, mesh: Mesh, axis: Optional[str]) -> bool:
    if axis is None:
        return True
    return dim % int(np.prod([mesh.shape[a] for a in (
        (axis,) if isinstance(axis, str) else axis)])) == 0


def param_spec(params: Any, mesh: Mesh, *, tp_attention: bool = True
               ) -> Any:
    """PartitionSpec pytree for a parameter pytree (path-rule matched).

    ``tp_attention=False`` replicates attention projections over the
    model axis — the right call for architectures whose head counts
    don't divide the model axis (gemma3's 4 q / 1 kv heads on a 16-way
    axis force XLA into activation all-gathers otherwise; see
    EXPERIMENTS.md §Perf iteration 1).
    """

    def leaf_spec(path, leaf):
        name = simple_keystr(path, separator="/")
        if not tp_attention and re.search(
                r"(attn|xattn)/(wq|wk|wv|wo)$", name):
            return P()
        for pat, spec in _RULES:
            if re.search(pat, name):
                spec = _pad_spec(spec, leaf.ndim)
                # divisibility guard: replicate any non-divisible dim
                parts = []
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    parts.append(ax if _shardable(dim, mesh, ax) else None)
                return P(*parts)
        return P()  # norms, gates, scalars: replicated

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_sharding(params: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_spec(params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def zero_spec(params: Any, mesh: Mesh, *, axis: str = "data") -> Any:
    """ZeRO-1 sharding for optimizer moments: take the param spec and
    additionally shard the largest replicated dim over the data axis."""
    base = param_spec(params, mesh)

    axis_elems = (axis,) if isinstance(axis, str) else tuple(axis)
    n_ways = int(np.prod([mesh.shape[a] for a in axis_elems]))

    def upgrade(path, leaf, spec):
        parts = list(tuple(_pad_spec(spec, leaf.ndim)))
        if any((p in axis_elems) or (isinstance(p, tuple)
                                     and set(p) & set(axis_elems))
               for p in parts if p is not None):
            return P(*parts)
        # choose the largest dim that is divisible and unsharded
        order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
        for i in order:
            if parts[i] is None and _shardable(leaf.shape[i], mesh, axis) \
                    and leaf.shape[i] >= n_ways:
                parts[i] = axis
                break
        return P(*parts)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf, spec: upgrade(path, leaf, spec), params, base)


def batch_spec(mesh: Mesh, ndim: int, *, batch_dim: int = 0) -> P:
    """Activations/tokens: batch over ('pod','data') when present."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape.keys())
    batch_axes = axes if len(axes) > 1 else (axes[0] if axes else None)
    parts = [None] * ndim
    parts[batch_dim] = batch_axes
    return P(*parts)


def cache_spec(cache: Any, mesh: Mesh, *, seq_parallel: bool = False,
               seq_axis: Optional[str] = None,
               head_dim_axis: Optional[str] = None) -> Any:
    """KV/state cache sharding.

    Default: shard the batch dim (first dim after stacked layer-group
    dims — detected as the first dim whose size matches none of the
    stack heuristics; here we shard the largest divisible dim among the
    first two non-layer dims). With ``seq_parallel`` (long-context B=1
    decode), shard the sequence dim over 'data' instead.
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape.keys())
    n_data = int(np.prod([mesh.shape[a] for a in data_axes]))
    batch_axes = data_axes if len(data_axes) > 1 else data_axes[0]

    def leaf_spec(path, leaf):
        parts = [None] * leaf.ndim
        name = simple_keystr(path, separator="/")
        # find batch dim: first dim from the left that divides by n_data
        # skipping stacked layer dims (conventionally small and leading).
        # KV leaves: (L..., B, S, H, D); state leaves: (L..., B, ...)
        kv_like = leaf.ndim >= 3 and re.search(r"(^|/)(k|v|pos)$", name)
        if kv_like:
            b_dim = leaf.ndim - (3 if name.endswith("pos") else 4)
            s_dim = b_dim + 1
            if seq_parallel and leaf.shape[s_dim] % n_data == 0 and \
                    leaf.shape[s_dim] >= n_data:
                parts[s_dim] = batch_axes
            elif leaf.shape[b_dim] % n_data == 0:
                parts[b_dim] = batch_axes
            # shard heads over model if divisible; else optionally shard
            # the sequence dim over the model axis instead (flash-decode
            # partial softmax — the fix for few-KV-head caches that
            # otherwise replicate 16x; EXPERIMENTS.md §Perf cell 3)
            if not name.endswith("pos"):
                h_dim = b_dim + 2
                if _shardable(leaf.shape[h_dim], mesh, "model") and \
                        leaf.shape[h_dim] >= mesh.shape["model"]:
                    parts[h_dim] = "model"
                elif head_dim_axis and _shardable(
                        leaf.shape[h_dim + 1], mesh, head_dim_axis):
                    # few-KV-head caches: shard head_dim instead — the
                    # decode write stays local (seq unsharded) and the
                    # QK/AV contractions only all-reduce tiny scores
                    parts[h_dim + 1] = head_dim_axis
                elif seq_axis and parts[s_dim] is None and \
                        _shardable(leaf.shape[s_dim], mesh, seq_axis):
                    parts[s_dim] = seq_axis
            elif seq_axis and parts[s_dim] is None and \
                    _shardable(leaf.shape[s_dim], mesh, seq_axis):
                parts[s_dim] = seq_axis
        else:
            # recurrent states: shard batch if possible (search dims)
            for i in range(leaf.ndim):
                if leaf.shape[i] % n_data == 0 and leaf.shape[i] >= n_data:
                    parts[i] = batch_axes
                    break
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def to_shardings(tree_spec: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_spec,
                        is_leaf=lambda x: isinstance(x, P))
