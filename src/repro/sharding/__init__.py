"""Sharding rules for params, activations, and caches."""
from repro.sharding.rules import (batch_spec, cache_spec, param_sharding,
                                  param_spec, to_shardings, zero_spec)

__all__ = ["batch_spec", "cache_spec", "param_sharding", "param_spec",
           "to_shardings", "zero_spec"]
