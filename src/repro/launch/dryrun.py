import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, on the single-pod 16x16 and
multi-pod 2x16x16 meshes:

    jax.jit(step, in_shardings=..., donate...).lower(**ShapeDtypeStructs)
        .compile()

then record memory_analysis(), cost_analysis(), and the trip-count-aware
HLO walk (dot FLOPs + collective bytes per device) into
reports/dryrun/<mesh>/<arch>__<shape>.json. No arrays are ever allocated:
params/caches come from jax.eval_shape, inputs from launch/specs.py.

The XLA_FLAGS line above MUST run before any jax import — jax locks the
device count on first init. This module is the only place that forces
512 host devices; tests and benches see the real device count.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single \
        --cells gemma3-1b:train_4k,arctic-480b:decode_32k
"""
import argparse
import json
import sys
import time
import traceback


def _get_cfg(arch, overrides):
    import dataclasses

    from repro.models import get_config
    cfg = get_config(arch)
    for k, v in (overrides.get("config") or {}).items():
        cfg = dataclasses.replace(cfg, **{k: v})
    return cfg


def _build_train_cell(arch, mesh, multi_pod, overrides):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch import specs as S
    from repro.models import build_model, get_config
    from repro.sharding import param_spec, to_shardings, zero_spec
    from repro.training import (AdamWConfig, TrainConfig, init_train_state,
                                make_train_step)
    from repro.training.train_step import TrainState
    from repro.training.optimizer import OptState

    cfg = _get_cfg(arch, overrides)
    model = build_model(cfg)
    info = S.SHAPES["train_4k"]
    total_data = (2 * 16) if multi_pod else 16
    micro = overrides.get("microbatches") or min(
        S.TRAIN_MICROBATCHES, info["batch"] // total_data)
    big = cfg.param_count() > 1e11
    tcfg = TrainConfig(
        optimizer=AdamWConfig(
            moment_dtype="bfloat16" if big else "float32"),
        microbatches=max(micro, 1),
        accum_dtype=overrides.get("accum_dtype", "float32"))
    step = make_train_step(model, tcfg)

    params_sh = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    state_sh = jax.eval_shape(lambda p: init_train_state(p, tcfg),
                              params_sh)
    batch_specs = S.input_specs(arch, "train_4k")

    zero_axis = ("pod", "data") if multi_pod else "data"
    tp_attn = overrides.get("tp_attention", True)
    p_spec = param_spec(params_sh, mesh, tp_attention=tp_attn)
    state_spec = TrainState(
        params=p_spec,
        opt=OptState(step=P(), mu=zero_spec(params_sh, mesh,
                                            axis=zero_axis),
                     nu=zero_spec(params_sh, mesh, axis=zero_axis)),
        residuals=None)
    state_shardings = to_shardings(state_spec, mesh)
    batch_axes = ("pod", "data") if multi_pod else "data"
    batch_shardings = {
        k: NamedSharding(mesh, P(batch_axes, *([None] * (v.ndim - 1))))
        for k, v in batch_specs.items()}
    fn = jax.jit(step, in_shardings=(state_shardings, batch_shardings),
                 donate_argnums=(0,))
    return fn, (state_sh, batch_specs), dict(microbatches=tcfg.microbatches)


def _build_prefill_cell(arch, mesh, multi_pod, overrides):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch import specs as S
    from repro.models import build_model, get_config
    from repro.sharding import param_spec, to_shardings

    cfg = _get_cfg(arch, overrides)
    model = build_model(cfg)
    seq = S.SHAPES["prefill_32k"]["seq"]
    params_sh = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    inputs = S.input_specs(arch, "prefill_32k")
    tokens = inputs.pop("tokens")
    extras = inputs or None

    def step(params, tokens, extras):
        return model.prefill(params, tokens, extras, seq)

    batch_axes = ("pod", "data") if multi_pod else "data"
    p_shard = to_shardings(param_spec(
        params_sh, mesh,
        tp_attention=overrides.get("tp_attention", True)), mesh)
    tok_shard = NamedSharding(mesh, P(batch_axes, None))
    ex_shard = (jax.tree.map(
        lambda v: NamedSharding(mesh, P(batch_axes,
                                        *([None] * (v.ndim - 1)))),
        extras) if extras else None)
    fn = jax.jit(step, in_shardings=(p_shard, tok_shard, ex_shard))
    return fn, (params_sh, tokens, extras), {}


def _build_decode_cell(arch, shape, mesh, multi_pod, overrides):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch import specs as S
    from repro.models import build_model, get_config
    from repro.sharding import cache_spec, param_spec, to_shardings

    cfg = _get_cfg(arch, overrides)
    model = build_model(cfg)
    params_sh = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    inputs = S.input_specs(arch, shape)
    seq_parallel = shape == "long_500k"

    def step(params, token, cache, cache_len):
        return model.decode(params, token, cache, cache_len, None)

    batch_axes = ("pod", "data") if multi_pod else "data"
    b = inputs["token"].shape[0]
    total_data = (2 * 16) if multi_pod else 16
    tok_spec = P(batch_axes, None) if b % total_data == 0 else P(None, None)
    p_shard = to_shardings(param_spec(
        params_sh, mesh,
        tp_attention=overrides.get("tp_attention", True)), mesh)
    cache_shard = to_shardings(
        cache_spec(inputs["cache"], mesh, seq_parallel=seq_parallel,
                   seq_axis=overrides.get("cache_seq_axis"),
                   head_dim_axis=overrides.get("cache_head_dim_axis")),
        mesh)
    fn = jax.jit(step,
                 in_shardings=(p_shard, NamedSharding(mesh, tok_spec),
                               cache_shard, NamedSharding(mesh, P())),
                 donate_argnums=(2,))
    args = (params_sh, inputs["token"], inputs["cache"],
            inputs["cache_len"])
    return fn, args, dict(seq_parallel=seq_parallel)


def run_cell(arch, shape, mesh, multi_pod, overrides=None):
    overrides = overrides or {}
    if shape == "train_4k":
        fn, args, meta = _build_train_cell(arch, mesh, multi_pod, overrides)
    elif shape == "prefill_32k":
        fn, args, meta = _build_prefill_cell(arch, mesh, multi_pod,
                                             overrides)
    else:
        fn, args, meta = _build_decode_cell(arch, shape, mesh, multi_pod,
                                            overrides)
    if overrides:
        meta = dict(meta, overrides=overrides)

    t0 = time.time()
    lowered = fn.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    mem_d = {k: int(getattr(mem, k)) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes")} if mem else {}
    cost = compiled.cost_analysis() or {}
    cost_d = {k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float)) and k in
              ("flops", "bytes accessed", "optimal_seconds")}

    sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                    "..", "..", ".."))
    from benchmarks import hlo_analysis
    hlo_txt = compiled.as_text()
    walk = hlo_analysis.analyze(hlo_txt)

    return {
        "arch": arch, "shape": shape,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory_per_device": mem_d,
        "xla_cost_analysis_loop_body_once": cost_d,
        "hlo_walk_per_device": walk.to_json(),
        "hlo_bytes": len(hlo_txt),
        **meta,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="all",
                    help="'all' or comma list arch:shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--overrides", default="{}",
                    help="JSON: microbatches, tp_attention, "
                         "cache_seq_axis, config={...} field overrides")
    ap.add_argument("--tag", default="",
                    help="suffix for perf-iteration artifacts")
    args = ap.parse_args()
    overrides = json.loads(args.overrides)

    import jax  # device count now locked at 512
    assert len(jax.devices()) == 512, len(jax.devices())

    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh

    if args.cells == "all":
        cells = S.cell_list()
    else:
        cells = tuple(tuple(c.split(":")) for c in args.cells.split(","))

    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    n_fail = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "multi" if multi_pod else "single"
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch, shape in cells:
            tag = f"{mesh_name:6s} {arch}:{shape}"
            suffix = f"__{args.tag}" if args.tag else ""
            outfile = os.path.join(outdir,
                                   f"{arch}__{shape}{suffix}.json")
            try:
                with mesh:
                    rec = run_cell(arch, shape, mesh, multi_pod, overrides)
                with open(outfile, "w") as f:
                    json.dump(rec, f, indent=1)
                m = rec["memory_per_device"]
                tot = (m.get("argument_size_in_bytes", 0)
                       + m.get("temp_size_in_bytes", 0)
                       - m.get("alias_size_in_bytes", 0))
                print(f"OK   {tag:50s} compile={rec['compile_s']:7.1f}s "
                      f"mem/dev={tot/2**30:6.2f}GiB "
                      f"dotTF={rec['hlo_walk_per_device']['dot_flops']/1e12:8.2f} "
                      f"collGB={rec['hlo_walk_per_device']['collective_bytes']/2**30:7.3f}",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                n_fail += 1
                with open(outfile + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"FAIL {tag:50s} {type(e).__name__}: {e}",
                      flush=True)
    print(f"\ndone; failures: {n_fail}")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
