"""Production serving launcher: slab-pool KV + continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
        [--reduced] --requests 64 --refit-every 200

Admits log-normal request traffic through the learned-slab-class KV pool
(the paper's technique as the allocator), decodes greedily with the zoo
model, and reports pool fragmentation before/after online refit. On a
real slice the decode step runs under the production mesh with the §Perf
decode profile (seq-sharded cache + onehot writes); on CPU use
``--reduced``.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--pool-tokens", type=int, default=1 << 16)
    ap.add_argument("--classes", type=int, default=6)
    ap.add_argument("--refit-every", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=16,
                    help="decode steps per admitted request (demo)")
    args = ap.parse_args()

    import copy

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import get_model
    from repro.serving import (ContinuousBatcher, KVSlabPool,
                               default_pow2_classes,
                               lognormal_request_workload, make_serve_fns)

    cfg, model = get_model(args.arch, reduced=args.reduced)

    # 1) allocator simulation at production scale: measure fragmentation
    rng = np.random.default_rng(0)
    workload = lognormal_request_workload(
        rng, args.requests, prompt_mean=args.pool_tokens / 64,
        prompt_std=args.pool_tokens / 256)
    pool = KVSlabPool(args.pool_tokens * 64, default_pow2_classes())
    batcher = ContinuousBatcher(pool, max_batch=args.max_batch,
                                refit_every=args.refit_every or None)
    res = batcher.run(copy.deepcopy(workload), steps=5000)
    print(f"pool: completed={res.completed} rejected={res.rejected} "
          f"waste={res.mean_waste_fraction:.1%} "
          f"classes={list(pool.chunk_classes)[:8]}")

    # 2) real decode through the model's cache path (demo scale)
    prompt_len, batch = 8, min(args.max_batch, 4)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, prompt_len), 0, cfg.vocab_size)
    extras = None
    if cfg.family == "encdec":
        extras = {"frames": jnp.zeros((batch, 16, cfg.d_model),
                                      jnp.float32)}
    if cfg.family == "vlm":
        extras = {"image_embeds": jnp.zeros(
            (batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)}
    prefill_fn, decode_fn = make_serve_fns(model)
    tok, cache = prefill_fn(params, prompt,
                            extras, prompt_len + args.steps)
    decode_fn = jax.jit(decode_fn)
    out = [tok]
    key = jax.random.PRNGKey(2)
    for i in range(args.steps - 1):
        key, sub = jax.random.split(key)
        tok, _, cache = decode_fn(params, tok, cache,
                                  jnp.int32(prompt_len + i), extras, sub)
        out.append(tok)
    tokens = jnp.concatenate(out, axis=1)
    print(f"decoded {tokens.shape[1]} tokens x {batch} seqs; "
          f"sample: {np.asarray(tokens[0, :12]).tolist()}")


if __name__ == "__main__":
    main()
