"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --steps 1000 --global-batch 256 --seq 4096 \
        --ckpt-dir /path/ckpts [--reduced]

On a real TPU slice this builds the production mesh, applies the
sharding rules (including the §Perf profiles), and runs the fault-
tolerant loop: resume-from-latest, async checkpoints, straggler
watchdog, elastic batch rescale. On CPU (tests/demos) pass ``--reduced``
to run the family-preserving small config on a 1-device mesh.
"""
from __future__ import annotations

import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--microbatches", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpts")
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving small config (CPU demo)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tp-attention", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 + error-feedback gradient compression")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.data import DataConfig, Prefetcher, make_batches
    from repro.models import get_model
    from repro.sharding import param_spec, to_shardings, zero_spec
    from repro.training import (AdamWConfig, CheckpointManager, StepTimer,
                                TrainConfig, init_train_state,
                                make_train_step, rescale_batch)
    from repro.training.optimizer import OptState
    from repro.training.train_step import TrainState

    cfg, model = get_model(args.arch, reduced=args.reduced)
    n_dev = len(jax.devices())
    if args.reduced or n_dev < 256:
        mesh = jax.make_mesh((1, 1), ("data", "model"),
                             devices=jax.devices()[:1])
        global_batch, seq, micro = 4, min(args.seq, 128), 2
    else:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        global_batch, seq = args.global_batch, args.seq
        micro = args.microbatches
        global_batch = rescale_batch(global_batch, mesh) * (
            mesh.shape.get("data", 1) * mesh.shape.get("pod", 1))

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=min(100, args.steps),
                              total_steps=args.steps),
        microbatches=micro, compress_grads=args.compress_grads)
    step_fn = make_train_step(model, tcfg)

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        state = init_train_state(params, tcfg)
        spec = TrainState(
            params=param_spec(params, mesh,
                              tp_attention=bool(args.tp_attention)),
            opt=OptState(step=P(), mu=zero_spec(params, mesh),
                         nu=zero_spec(params, mesh)),
            residuals=(param_spec(params, mesh)
                       if args.compress_grads else None))
        state = jax.tree.map(jax.device_put, state,
                             to_shardings(spec, mesh))
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        start = 0
        if mgr.latest_step() is not None:
            state = mgr.restore(state, shardings=to_shardings(spec, mesh))
            start = int(jax.device_get(state.opt.step))
            print(f"resumed at step {start}")

        dcfg = DataConfig(vocab_size=cfg.vocab_size,
                          batch_size=global_batch, max_len=seq)
        batches = Prefetcher(make_batches(dcfg))
        timer = StepTimer()
        for i, batch in zip(range(start, args.steps), batches):
            timer.start()
            state, metrics = step_fn(
                state, {"tokens": jnp.asarray(batch["tokens"])})
            if timer.stop(i):
                print(f"straggler at step {i} "
                      f"(mean {timer.mean_step_time*1e3:.0f}ms)")
            if (i + 1) % 10 == 0 or i == start:
                print(f"step {i+1} loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e}")
            if (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state, blocking=False)
        mgr.wait()
        mgr.save(args.steps, state)
        batches.close()
        print("done")


if __name__ == "__main__":
    main()
