"""Dry-run cell definitions: (arch x input-shape) -> ShapeDtypeStruct trees.

``input_specs(arch, shape)`` returns weak-type-correct, shardable
stand-ins for every model input — no device allocation anywhere (params
and caches come from jax.eval_shape over the real init functions).

Shape kinds (assignment):
  train_4k     seq 4096,   global batch 256  -> train_step
  prefill_32k  seq 32768,  global batch 32   -> prefill_step
  decode_32k   KV 32768,   global batch 128  -> serve_step (1 new token)
  long_500k    KV 524288,  global batch 1    -> serve_step, sub-quadratic
               archs only (skips documented in DESIGN.md §6)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import build_model, get_config

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k runs only where attention/state is sub-quadratic in context
# (rolling SWA buffers, local:global patterns, or recurrent state).
LONG_CONTEXT_ARCHS = frozenset({
    "gemma3-1b", "gemma3-4b", "zamba2-7b", "mixtral-8x7b", "xlstm-350m",
})
LONG_SKIP_REASON = {
    "gemma-7b": "pure full attention (28 global layers)",
    "deepseek-7b": "pure full attention (30 global layers)",
    "whisper-medium": "enc-dec; decoder context is 448 tokens by design",
    "arctic-480b": "pure full attention; 4k trained context",
    "llama-3.2-vision-11b": "pure full attention text stack",
}

# per-arch microbatch count for train_4k (bounds activation memory);
# chosen so per-device microbatch == 1 sequence on the 16x16 mesh.
TRAIN_MICROBATCHES = 16


def cell_list(include_skipped: bool = False) -> Tuple[Tuple[str, str], ...]:
    from repro.models import list_archs
    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                if include_skipped:
                    cells.append((arch, shape + ":SKIP"))
                continue
            cells.append((arch, shape))
    return tuple(cells)


def _extras_specs(cfg, batch: int, seq: int) -> Dict[str, Any]:
    ex: Dict[str, Any] = {}
    if cfg.family == "encdec":
        # stub frame embeddings: one frame per target token (backbone-only)
        ex["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                            jnp.bfloat16)
    if cfg.family == "vlm":
        ex["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    return ex


def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the cell's step-function inputs."""
    cfg = get_config(arch)
    model = build_model(cfg)
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]

    if kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        specs.update(_extras_specs(cfg, b, s))
        return specs

    if kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        specs.update(_extras_specs(cfg, b, s))
        return specs

    # decode: one new token against a seq-length KV/state cache
    specs = {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
             "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.family == "encdec":
        enc_len = 1500  # whisper 30s audio -> 1500 encoder frames
        cache = jax.eval_shape(
            lambda: model.init_cache(b, s, enc_len=enc_len))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
    specs["cache"] = cache
    return specs


def param_specs(arch: str) -> Any:
    cfg = get_config(arch)
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
