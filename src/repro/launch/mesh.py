"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before any jax initialization.

Topology (TPU v5e pods):
  single-pod:  (data=16, model=16)            = 256 chips
  multi-pod:   (pod=2, data=16, model=16)     = 512 chips
The 'pod' axis carries only data parallelism (one gradient all-reduce
over DCN per step) unless pipeline mode re-purposes it.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax  # deferred: device count must be locked by the caller first
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (launch/dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_debug_mesh(data: int = 4, model: int = 2):
    """Small mesh for CPU sharding tests (8 forced host devices)."""
    import jax
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n])
