"""Architecture registry: --arch <id> -> (ModelConfig, model)."""
from __future__ import annotations

from typing import Dict, Tuple

from repro.configs import (arctic_480b, deepseek_7b, gemma3_1b, gemma3_4b,
                           gemma_7b, llama32_vision_11b, mixtral_8x7b,
                           whisper_medium, xlstm_350m, zamba2_7b)
from repro.configs.base import ModelConfig
from repro.models.transformer import build_model

_REGISTRY: Dict[str, ModelConfig] = {
    cfg.name: cfg for cfg in (
        gemma3_1b.CONFIG,
        gemma3_4b.CONFIG,
        gemma_7b.CONFIG,
        deepseek_7b.CONFIG,
        zamba2_7b.CONFIG,
        whisper_medium.CONFIG,
        mixtral_8x7b.CONFIG,
        arctic_480b.CONFIG,
        xlstm_350m.CONFIG,
        llama32_vision_11b.CONFIG,
    )
}


def list_archs() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown arch {name!r}; known: {', '.join(_REGISTRY)}"
        ) from None


def get_model(name: str, *, reduced: bool = False):
    cfg = get_config(name)
    if reduced:
        cfg = cfg.reduced()
    return cfg, build_model(cfg)
