"""Mamba2 block via the SSD chunked-parallel algorithm (zamba2's mixer).

TPU adaptation: the SSD formulation (Mamba-2 paper §6) decomposes the
selective-scan into chunk-diagonal attention-like matmuls plus a short
scan over chunk states — everything heavy lands on the MXU instead of a
length-S sequential recurrence. Decode keeps the O(1) recurrent form.

All decay math in f32 log-space; every exp() argument is <= 0 by
construction (A < 0, dt >= 0), so the kernel is numerically safe without
clamping.

Group count G = 1 (zamba2): B/C projections are shared across heads.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_rms_norm, rms_norm

Params = Dict[str, Any]


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba2(key, cfg) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d_in, h, p_dim, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * n + h   # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_ch),
                                     dtype=jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
        "gate_norm": init_rms_norm(d_in),
        "out_proj": dense_init(ks[3], d_in, cfg.d_model, dtype),
    }


def _split_proj(proj, cfg):
    d_in, h, p_dim, n = _dims(cfg)
    z, xs, b_, c_, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    return z, xs, b_, c_, dt


def _causal_conv(x, w, b):
    """x: (B, S, CH); w: (K, CH) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # static unroll, K = 4
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None, :]
    return out + b[None, None, :]


def ssd_chunked(xh, dt, a_neg, b_, c_, chunk: int, *, init_state=None):
    """Chunked-parallel SSD.

    xh: (B,S,H,P) f32   input (already conv'd/activated), per head
    dt: (B,S,H)  f32    softplus'd step sizes
    a_neg: (H,)  f32    negative decay rates (-exp(a_log))
    b_,c_: (B,S,N) f32  shared-across-heads input/output maps (G=1)
    Returns y: (B,S,H,P), final_state: (B,H,N,P).
    """
    bsz, s, h, p_dim = xh.shape
    n = b_.shape[-1]
    q = min(chunk, s) if s % chunk else chunk
    pad = (-s) % q
    if pad:  # dt=0 on padding -> decay 1, zero input: states unaffected
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0)))
        c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0)))
    s_real, s = s, s + pad
    nc = s // q

    x_c = (xh * dt[..., None]).reshape(bsz, nc, q, h, p_dim)
    da = (dt * a_neg[None, None, :]).reshape(bsz, nc, q, h)   # <= 0
    cum = jnp.cumsum(da, axis=2)                              # (B,nc,Q,H)
    total = cum[:, :, -1]                                     # (B,nc,H)
    b_c = b_.reshape(bsz, nc, q, n)
    c_c = c_.reshape(bsz, nc, q, n)

    # --- intra-chunk (attention-like, causal with decay) ---
    cb = jnp.einsum("bctn,bcsn->bcts", c_c, b_c)              # (B,nc,Q,Q)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), dtype=bool))
    # mask BEFORE exp: masked (s > t) entries have diff > 0 and would
    # overflow, poisoning gradients through the where (inf * 0 = nan)
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -1e30))
    y_intra = jnp.einsum("bcts,bctsh,bcshp->bcthp",
                         cb, decay, x_c)

    # --- chunk states + recurrence ---
    state_c = jnp.einsum("bcsn,bcsh,bcshp->bchnp",
                         b_c, jnp.exp(total[:, :, None, :] - cum), x_c)

    def step(st, inp):
        tot_c, sc = inp                                       # (B,H), (B,H,N,P)
        new = jnp.exp(tot_c)[:, :, None, None] * st + sc
        return new, st                                        # emit prev state

    init = (jnp.zeros((bsz, h, n, p_dim), jnp.float32)
            if init_state is None else init_state)
    final_state, prev_states = jax.lax.scan(
        step, init, (total.transpose(1, 0, 2),
                     state_c.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (B,nc,H,N,P)

    y_inter = jnp.einsum("bctn,bchnp,bcth->bcthp",
                         c_c, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bsz, s, h, p_dim)[:, :s_real]
    return y, final_state


def mamba2_block(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Train/prefill path. x: (B, S, D) -> (B, S, D)."""
    bsz, s, _ = x.shape
    d_in, h, p_dim, n = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xs, b_, c_, dt = _split_proj(proj, cfg)

    conv_in = jnp.concatenate([xs, b_, c_], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, b_, c_ = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt_f = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"][None, None, :])
    a_neg = -jnp.exp(p["a_log"])
    xh = xs.astype(jnp.float32).reshape(bsz, s, h, p_dim)
    y, _ = ssd_chunked(xh, dt_f, a_neg,
                       b_.astype(jnp.float32), c_.astype(jnp.float32),
                       cfg.ssm_chunk)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def init_mamba2_state(cfg, batch: int, dtype=jnp.float32) -> Params:
    d_in, h, p_dim, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, h, n, p_dim), jnp.float32),
    }


def mamba2_decode(p: Params, x: jnp.ndarray, state: Params, cfg
                  ) -> Tuple[jnp.ndarray, Params]:
    """Single-token recurrent step. x: (B, 1, D)."""
    bsz = x.shape[0]
    d_in, h, p_dim, n = _dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]   # (B, E)
    z, xs, b_, c_, dt = _split_proj(proj, cfg)

    conv_in = jnp.concatenate([xs, b_, c_], axis=-1)          # (B, CH)
    window = jnp.concatenate([state["conv"],
                              conv_in[:, None, :]], axis=1)   # (B, K, CH)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    xs_c, b_c, c_c = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    a_neg = -jnp.exp(p["a_log"])                               # (H,)
    decay = jnp.exp(dt_f * a_neg[None, :])                     # (B,H)
    xh = xs_c.reshape(bsz, h, p_dim)
    new_ssm = (decay[:, :, None, None] * state["ssm"]
               + jnp.einsum("bn,bh,bhp->bhnp", b_c, dt_f, xh))
    y = jnp.einsum("bn,bhnp->bhp", c_c, new_ssm)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z[:, None, :]), p["gate_norm"],
                 cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_state = {"conv": window[:, 1:].astype(state["conv"].dtype),
                 "ssm": new_ssm}
    return out, new_state


def ssd_reference(xh, dt, a_neg, b_, c_):
    """Naive O(S) sequential SSD — oracle for tests."""
    bsz, s, h, p_dim = xh.shape
    n = b_.shape[-1]

    def step(st, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t * a_neg[None, :])                 # (B,H)
        st = (decay[:, :, None, None] * st
              + jnp.einsum("bn,bh,bhp->bhnp", b_t, dt_t, x_t))
        y = jnp.einsum("bn,bhnp->bhp", c_t, st)
        return st, y

    init = jnp.zeros((bsz, h, n, p_dim), jnp.float32)
    _, ys = jax.lax.scan(
        step, init,
        (xh.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
         b_.transpose(1, 0, 2), c_.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3)
