"""Model zoo: shared layers + per-family assemblies + registry."""
from repro.models.model_zoo import get_config, get_model, list_archs
from repro.models.transformer import build_model

__all__ = ["get_config", "get_model", "list_archs", "build_model"]
