"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) + sLSTM (scan).

Follows the structure of arXiv:2405.04517 with one documented numerical
simplification (DESIGN.md §Arch-applicability): the mLSTM input gate uses
sigmoid instead of exp, which removes the cross-timestep max-stabilizer
and lets the recurrence run in the same chunked matmul form as SSD —
the TPU-native mapping. Memory/FLOP shape matches xlstm-350m.

mLSTM recurrence (per head, matrix memory C: (dk, dv), normalizer n):
    C_t = f_t C_{t-1} + i_t k_t v_t^T
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (q_t C_t) / max(|q_t . n_t|, 1)
Chunkwise: identical algebra to a gated-linear-attention chunk scan.

sLSTM: scalar memory per head-channel with recurrent gate feedback —
inherently sequential; implemented as lax.scan over time (the paper keeps
sLSTM in only a fraction of layers, so the sequential tail is small).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_rms_norm, rms_norm

Params = Dict[str, Any]


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model     # value dim
    h = cfg.n_heads
    dv = d_in // h
    dk = cfg.d_model // h
    return d_in, h, dk, dv


# ----------------------------------------------------------------------------
# mLSTM
# ----------------------------------------------------------------------------


def init_mlstm(key, cfg) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d_in, h, dk, dv = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], cfg.d_model, h * dk, dtype),
        "wk": dense_init(ks[1], cfg.d_model, h * dk, dtype),
        "wv": dense_init(ks[2], cfg.d_model, d_in, dtype),
        "wgate": dense_init(ks[3], cfg.d_model, 2 * h, dtype),  # i,f logits
        "wog": dense_init(ks[4], cfg.d_model, d_in, dtype),
        "out_norm": init_rms_norm(d_in),
        "wo": dense_init(ks[5], d_in, cfg.d_model, dtype),
    }


def _mlstm_chunked(q, k, v, log_f, i_gate, chunk: int, init_state=None):
    """q,k: (B,S,H,dk) f32; v: (B,S,H,dv); log_f: (B,S,H) <= 0; i: (B,S,H).

    Returns h: (B,S,H,dv), final (C: (B,H,dk,dv), n: (B,H,dk)).
    """
    bsz, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s) if s % chunk else chunk
    pad = (-s) % chunk
    if pad:  # log_f=0 (decay 1) and i=0 on padding: state unaffected
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)))
    s_real, s = s, s + pad
    nc = s // chunk
    cq = lambda t: t.reshape(bsz, nc, chunk, *t.shape[2:])
    qc, kc, vc = cq(q), cq(k), cq(v)
    lf, ig = cq(log_f), cq(i_gate)

    cum = jnp.cumsum(lf, axis=2)                       # (B,nc,Q,H)
    total = cum[:, :, -1]                              # (B,nc,H)

    # intra-chunk: score[t,s] = (q_t.k_s) * exp(cum_t - cum_s) * i_s, s<=t
    qk = jnp.einsum("bcthd,bcshd->bchts", qc, kc)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    # mask BEFORE exp (see mamba2.ssd_chunked): avoids inf * 0 nan-grads
    dec = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -1e30))
    w = (qk * dec.transpose(0, 1, 4, 2, 3)
         * ig.transpose(0, 1, 3, 2)[:, :, :, None, :])
    h_intra = jnp.einsum("bchts,bcshd->bcthd", w, vc)
    # q_t . n_t intra part: w already contains q.k, so just sum over s
    qn_intra = jnp.sum(w, axis=-1).transpose(0, 1, 3, 2)   # (B,nc,Q,H)

    # chunk-end state contributions
    state_c = jnp.einsum("bcsh,bcshk,bcshv->bchkv",
                         ig * jnp.exp(total[:, :, None, :] - cum), kc, vc)
    norm_c = jnp.einsum("bcsh,bcshk->bchk",
                        ig * jnp.exp(total[:, :, None, :] - cum), kc)

    def step(carry, inp):
        c_st, n_st = carry
        tot, sc, nc_ = inp
        dec_t = jnp.exp(tot)[:, :, None, None]
        new_c = dec_t * c_st + sc
        new_n = jnp.exp(tot)[:, :, None] * n_st + nc_
        return (new_c, new_n), (c_st, n_st)

    init = (jnp.zeros((bsz, h, dk, dv), jnp.float32),
            jnp.zeros((bsz, h, dk), jnp.float32)) if init_state is None \
        else init_state
    (c_fin, n_fin), (c_prev, n_prev) = jax.lax.scan(
        step, init, (total.transpose(1, 0, 2),
                     state_c.transpose(1, 0, 2, 3, 4),
                     norm_c.transpose(1, 0, 2, 3)))
    c_prev = c_prev.transpose(1, 0, 2, 3, 4)           # (B,nc,H,dk,dv)
    n_prev = n_prev.transpose(1, 0, 2, 3)              # (B,nc,H,dk)

    dec_q = jnp.exp(cum)                               # (B,nc,Q,H)
    h_inter = jnp.einsum("bcthd,bchdv,bcth->bcthv", qc, c_prev, dec_q)
    n_inter = jnp.einsum("bcthd,bchd,bcth->bcth", qc, n_prev, dec_q)

    h_raw = (h_intra + h_inter).reshape(bsz, s, h, dv)[:, :s_real]
    qn = (qn_intra + n_inter).reshape(bsz, s, h)[:, :s_real]
    denom = jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    return h_raw / denom, (c_fin, n_fin)


def mlstm_block(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    bsz, s, _ = x.shape
    d_in, h, dk, dv = _dims(cfg)
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(bsz, s, h, dk)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(bsz, s, h, dk)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(bsz, s, h, dv)
    gates = jnp.einsum("bsd,de->bse", x, p["wgate"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gates[..., :h])
    i_g = jax.nn.sigmoid(gates[..., h:])
    hidden, _ = _mlstm_chunked(
        q.astype(jnp.float32) * (dk ** -0.5), k.astype(jnp.float32),
        v.astype(jnp.float32), log_f, i_g, cfg.ssm_chunk)
    hidden = hidden.reshape(bsz, s, d_in).astype(x.dtype)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["wog"]))
    hidden = rms_norm(hidden, p["out_norm"], cfg.norm_eps) * og
    return jnp.einsum("bse,ed->bsd", hidden, p["wo"])


def init_mlstm_state(cfg, batch: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    d_in, h, dk, dv = _dims(cfg)
    return (jnp.zeros((batch, h, dk, dv), jnp.float32),
            jnp.zeros((batch, h, dk), jnp.float32))


def mlstm_decode(p: Params, x: jnp.ndarray, state, cfg):
    """x: (B, 1, D); state = (C, n)."""
    bsz = x.shape[0]
    d_in, h, dk, dv = _dims(cfg)
    q = jnp.einsum("bsd,de->bse", x, p["wq"])[:, 0].reshape(bsz, h, dk)
    k = jnp.einsum("bsd,de->bse", x, p["wk"])[:, 0].reshape(bsz, h, dk)
    v = jnp.einsum("bsd,de->bse", x, p["wv"])[:, 0].reshape(bsz, h, dv)
    gates = jnp.einsum("bsd,de->bse", x,
                       p["wgate"])[:, 0].astype(jnp.float32)
    f_g = jax.nn.sigmoid(gates[..., :h])
    i_g = jax.nn.sigmoid(gates[..., h:])
    c_st, n_st = state
    qf = q.astype(jnp.float32) * (dk ** -0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c_new = (f_g[:, :, None, None] * c_st
             + i_g[:, :, None, None] * kf[..., None] * vf[:, :, None, :])
    n_new = f_g[:, :, None] * n_st + i_g[:, :, None] * kf
    h_raw = jnp.einsum("bhk,bhkv->bhv", qf, c_new)
    qn = jnp.sum(qf * n_new, axis=-1)
    hidden = h_raw / jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    hidden = hidden.reshape(bsz, 1, d_in).astype(x.dtype)
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["wog"]))
    hidden = rms_norm(hidden, p["out_norm"], cfg.norm_eps) * og
    return jnp.einsum("bse,ed->bsd", hidden, p["wo"]), (c_new, n_new)


# ----------------------------------------------------------------------------
# sLSTM
# ----------------------------------------------------------------------------


def init_slstm(key, cfg) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 3)
    return {
        # 4 gates (i, f, z, o) from input; per-head recurrent R (block-diag)
        "wx": dense_init(ks[0], d, 4 * d, dtype),
        "r": (jax.random.normal(ks[1], (h, dh, 4 * dh), jnp.float32)
              * (dh ** -0.5)).astype(dtype),
        "out_norm": init_rms_norm(d),
        "wo": dense_init(ks[2], d, cfg.d_model, dtype),
    }


def init_slstm_state(cfg, batch: int):
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "m": z(), "h": z()}


def _slstm_step(p, cfg, x_t, st):
    """x_t: (B, 4D) pre-projected gates; st: state dict of (B, D)."""
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    bsz = x_t.shape[0]
    h_prev = st["h"].reshape(bsz, h, dh)
    rec = jnp.einsum("bhd,hde->bhe", h_prev,
                     p["r"].astype(jnp.float32)).reshape(bsz, 4 * d)
    pre = x_t + rec
    i_t, f_t, z_t, o_t = jnp.split(pre, 4, axis=-1)
    # exp input/forget gates with max-stabilizer (xLSTM eq. 15-17)
    m_new = jnp.maximum(f_t + st["m"], i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + st["m"] - m_new)
    c_new = f_p * st["c"] + i_p * jnp.tanh(z_t)
    n_new = f_p * st["n"] + i_p
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_block(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    bsz, s, d = x.shape
    xg = jnp.einsum("bsd,de->bse", x, p["wx"]).astype(jnp.float32)

    def step(st, x_t):
        new = _slstm_step(p, cfg, x_t, st)
        return new, new["h"]

    _, hs = jax.lax.scan(step, init_slstm_state(cfg, bsz),
                         xg.transpose(1, 0, 2))
    hidden = hs.transpose(1, 0, 2).astype(x.dtype)
    hidden = rms_norm(hidden, p["out_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", hidden, p["wo"])


def slstm_decode(p: Params, x: jnp.ndarray, state, cfg):
    xg = jnp.einsum("bsd,de->bse", x, p["wx"])[:, 0].astype(jnp.float32)
    new = _slstm_step(p, cfg, xg, state)
    hidden = new["h"][:, None, :].astype(x.dtype)
    hidden = rms_norm(hidden, p["out_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", hidden, p["wo"]), new
