"""Mixture-of-Experts FFN: top-k routing with GShard-style dense dispatch.

Dispatch/combine are einsums against a capacity-limited one-hot tensor, so
under pjit the expert dimension can be sharded over the data axis (expert
parallelism — XLA SPMD materialises the token shuffle as all-to-all) while
each expert's FFN is tensor-parallel over the model axis. Tokens routed
beyond an expert's capacity are dropped (standard GShard semantics); the
router carries a load-balancing aux loss.

arctic-480b additionally runs a *dense residual* MLP in parallel with the
expert branch (Snowflake's dense-MoE hybrid), enabled by
``cfg.moe_dense_residual``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]


def init_moe(key, cfg) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    def w(k, shape):
        return (jax.random.normal(k, shape, dtype=jnp.float32)
                * scale).astype(dtype)

    p = {
        "router": (jax.random.normal(ks[0], (d, e), dtype=jnp.float32)
                   * 0.02).astype(jnp.float32),  # router math stays f32
        "we_gate": w(ks[1], (e, d, f)),
        "we_in": w(ks[2], (e, d, f)),
        "we_out": w(ks[3], (e, f, d)),
    }
    if cfg.moe_dense_residual:
        p["dense"] = layers.init_mlp(ks[4], cfg)
    return p


GROUP_TOKENS = 512  # dispatch-group size; bounds the one-hot working set


def _capacity(cfg, group_tokens: int) -> int:
    cap = int(cfg.capacity_factor * cfg.experts_per_token
              * group_tokens / cfg.n_experts)
    return max(cap, 4)


def moe_block(p: Params, x: jnp.ndarray, cfg
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Tokens are dispatched in groups of GROUP_TOKENS, so the one-hot
    dispatch/combine tensor is (G, T, E, C) with T*E*C bounded — at
    E=128, T=512, C=~10, that's ~1.3k slots per token instead of the
    naive per-sequence capacity that would blow past HBM. Expert-parallel
    sharding: group dim follows the batch ('data') axis; XLA SPMD
    materialises the token shuffle as all-to-all when the expert dim of
    the dispatched activations is resharded onto 'data'.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Load-balance aux loss (Switch/GShard form).
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = jnp.sum(me * ce) * e * cfg.router_aux_loss

    # Regroup (B, S) -> (G, T) token groups.
    t = min(GROUP_TOKENS, s)
    assert s % t == 0, f"seq {s} not a multiple of moe group {t}"
    g = b * (s // t)
    c = _capacity(cfg, t)
    xg = x.reshape(g, t, d)
    sel = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32).reshape(g, t, k, e)
    gates = gate_vals.reshape(g, t, k)

    # Position of each (token, choice) in its expert's capacity buffer.
    pos = jnp.cumsum(sel.reshape(g, t * k, e), axis=1) - 1.0
    pos = pos.reshape(g, t, k, e)
    within_cap = pos < c
    sel = sel * within_cap
    pos = jnp.where(within_cap, pos, 0.0)

    ddt = jnp.dtype(cfg.moe_dispatch_dtype)
    cap_onehot = jax.nn.one_hot(pos.astype(jnp.int32), c,
                                dtype=ddt)                     # (G,T,k,E,C)
    dispatch = jnp.einsum("gtke,gtkec->gtec", sel.astype(ddt), cap_onehot)
    combine = jnp.einsum("gtk,gtke,gtkec->gtec", gates.astype(ddt),
                         sel.astype(ddt), cap_onehot)

    if cfg.moe_ep_constraints:
        # Pin the expert-parallel boundary: token-side tensors stay
        # group-sharded ('data'), expert-side tensors expert-sharded
        # ('data'), so SPMD lowers the boundary to one all-to-all instead
        # of replicating activations (EXPERIMENTS.md §Perf cell 2).
        from jax.sharding import PartitionSpec as _P
        wsc = jax.lax.with_sharding_constraint
        dispatch = wsc(dispatch, _P("data", None, None, None))
        combine = wsc(combine, _P("data", None, None, None))
    xin = jnp.einsum("gtec,gtd->egcd", dispatch.astype(x.dtype), xg)
    if cfg.moe_ep_constraints:
        xin = wsc(xin, _P("data", None, None, "model"))
    gate_h = act(jnp.einsum("egcd,edf->egcf", xin, p["we_gate"]))
    up = jnp.einsum("egcd,edf->egcf", xin, p["we_in"])
    expert_out = jnp.einsum("egcf,efd->egcd", gate_h * up, p["we_out"])
    if cfg.moe_ep_constraints:
        expert_out = wsc(expert_out, _P("data", None, None, "model"))
    out = jnp.einsum("gtec,egcd->gtd", combine.astype(x.dtype), expert_out)
    out = out.reshape(b, s, d)
    if cfg.moe_ep_constraints:
        out = wsc(out.reshape(g, t, d), _P("data", None, None)).reshape(
            b, s, d)

    if cfg.moe_dense_residual:
        out = out + layers.mlp_block(p["dense"], x, cfg)
    return out, aux
