"""Model assembly: every assigned architecture family as a scanned stack.

Families (selected by ModelConfig.family):
  dense / moe — uniform decoder stack; per-layer attention kind (global vs
      sliding-window) is *data*, not structure: a stacked ``is_global``
      vector rides through one lax.scan, so gemma3's 5:1 local:global and
      mixtral's all-SWA compile to a single scanned layer body (small HLO,
      fast multi-arch compiles).
  hybrid — zamba2: lax.scan over groups of [mamba2, mamba2, shared-attn];
      the attention block's weights are shared across all applications
      (scan closure), while its KV cache is per-application (scan xs/ys).
  ssm — xlstm: scan over groups of [7 x mLSTM, 1 x sLSTM].
  encdec — whisper backbone: bidirectional encoder scan over stub frame
      embeddings + causal decoder scan with fused cross-attention.
  vlm — llama-3.2-vision backbone: scan over groups of [4 self layers,
      1 gated cross-attn layer] against stub image embeddings.

API (uniform across families):
  init(key) -> params
  train_logits(params, tokens, extras) -> (logits, aux_loss)
  prefill(params, tokens, extras, max_len) -> (logits, cache)
  decode(params, token, cache, cache_len, extras) -> (logits, cache)

``extras`` carries modality-stub inputs (frame/image embeddings).
Decode uses a scalar ``cache_len`` (batch-aligned serving) and supports
rolling sliding-window caches when every layer is local (mixtral): the
cache then has window-size slots plus an absolute-position plane, and
masking by stored position makes wraparound transparent.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgs
from repro.models import layers, mamba2, moe, xlstm
from repro.models.layers import (attention_block, chunked_attention, embed,
                                 init_attention, init_embedding,
                                 init_mlp, init_rms_norm, mlp_block,
                                 rms_norm, unembed)

Params = Dict[str, Any]
_FULL_WINDOW = 1 << 30


# ============================================================================
# attention-layer block (dense or moe ffn), uniform-stack body
# ============================================================================


def _init_attn_layer(key, cfg) -> Params:
    ks = jax.random.split(key, 3)
    p = {"ln1": init_rms_norm(cfg.d_model),
         "ln2": init_rms_norm(cfg.d_model),
         "attn": init_attention(ks[0], cfg)}
    if cfg.n_experts:
        p["moe"] = moe.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg)
    if cfg.post_block_norms:
        p["ln1_post"] = init_rms_norm(cfg.d_model)
        p["ln2_post"] = init_rms_norm(cfg.d_model)
    return p


def _apply_attn_layer(p, x, cfg, *, window, theta, positions,
                      cache=None, cache_len=None, return_kv=False):
    """One decoder layer. Returns (x, aux, new_cache_or_kv)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_out, kv = attention_block(
        p["attn"], h, cfg, positions=positions, window=window,
        rope_theta=theta, causal=True, cache=cache, cache_len=cache_len)
    if cfg.post_block_norms:
        attn_out = rms_norm(attn_out, p["ln1_post"], cfg.norm_eps)
    x = x + attn_out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        ffn_out, aux = moe.moe_block(p["moe"], h, cfg)
    else:
        ffn_out = mlp_block(p["mlp"], h, cfg)
    if cfg.post_block_norms:
        ffn_out = rms_norm(ffn_out, p["ln2_post"], cfg.norm_eps)
    return x + ffn_out, aux, kv


def _layer_window_theta(cfg, is_global):
    window = jnp.where(is_global, _FULL_WINDOW, cfg.sliding_window)
    theta_g = cfg.rope_theta_global or cfg.rope_theta
    theta = jnp.where(is_global, theta_g, cfg.rope_theta)
    return window, theta


def _fill_cache_slots(kproj, vproj, positions, slots: int, keep: int):
    """Place the last ``keep`` tokens into a ``slots``-sized (rolling)
    cache so that token at position p lands in slot p % slots — the same
    rule decode uses, so wraparound eviction order stays consistent."""
    b, s = positions.shape
    ck = jnp.zeros((b, slots, *kproj.shape[2:]), kproj.dtype)
    cv = jnp.zeros_like(ck)
    cpos = jnp.full((b, slots), -1, jnp.int32)
    if keep == slots and s >= slots:
        shift = s % slots
        ck = jnp.roll(kproj[:, s - slots:], shift, axis=1)
        cv = jnp.roll(vproj[:, s - slots:], shift, axis=1)
        cpos = jnp.roll(positions[:, s - slots:], shift, axis=1)
    else:
        ck = jax.lax.dynamic_update_slice(
            ck, kproj[:, s - keep:], (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, vproj[:, s - keep:], (0, 0, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cpos, positions[:, s - keep:], (0, 0))
    return ck, cv, cpos


def _cache_write(arr, new, write_at, positions, mode: str):
    """Write `new` (B,1,...) into slot `write_at` of `arr` (B,S,...).

    "dus" is cheapest on replicated-seq caches; "onehot" expresses the
    write as einsum-add, which SPMD keeps local when the cache's seq dim
    is sharded (the dynamic_update_slice form all-gathers it).
    """
    if mode == "onehot":
        slots = arr.shape[1]
        onehot = jax.nn.one_hot(write_at, slots, dtype=arr.dtype)  # (S,)
        shaped = onehot.reshape((1, slots) + (1,) * (arr.ndim - 2))
        keep = 1.0 - shaped
        return arr * keep.astype(arr.dtype) + shaped * new.astype(arr.dtype)
    return jax.lax.dynamic_update_slice(
        arr, new.astype(arr.dtype),
        (0, write_at) + (0,) * (arr.ndim - 2))


def _is_global_vec(cfg) -> jnp.ndarray:
    pattern = cfg.block_pattern or (cfgs.ATTN_GLOBAL,) * cfg.n_layers
    return jnp.asarray([k == cfgs.ATTN_GLOBAL for k in pattern],
                       dtype=jnp.bool_)


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _stack_init(key, n: int, init_fn) -> Params:
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ============================================================================
# family: dense / moe — uniform decoder
# ============================================================================


class UniformDecoder:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- cache geometry ------------------------------------------------------
    def cache_len_slots(self, max_len: int) -> int:
        cfg = self.cfg
        pattern = cfg.block_pattern or (cfgs.ATTN_GLOBAL,) * cfg.n_layers
        if all(k == cfgs.ATTN_LOCAL for k in pattern):
            return min(max_len, cfg.sliding_window)  # rolling buffer
        return max_len

    def init(self, key) -> Params:
        cfg = self.cfg
        k_emb, k_layers, k_fin = jax.random.split(key, 3)
        return {
            "embedding": init_embedding(k_emb, cfg),
            "layers": _stack_init(k_layers, cfg.n_layers,
                                  lambda k: _init_attn_layer(k, cfg)),
            "final_norm": init_rms_norm(cfg.d_model),
        }

    def _run(self, params, x, positions, cache=None, cache_len=None):
        cfg = self.cfg
        is_global = _is_global_vec(cfg)

        def body(carry, xs):
            h, aux = carry
            if cache is None:
                p, ig = xs
                c_in = None
            else:
                p, ig, c_in = xs
            window, theta = _layer_window_theta(cfg, ig)
            h, aux_l, c_out = _apply_attn_layer(
                p, h, cfg, window=window, theta=theta, positions=positions,
                cache=c_in, cache_len=cache_len,
                return_kv=cache is not None)
            return (h, aux + aux_l), c_out

        xs = ((params["layers"], is_global) if cache is None
              else (params["layers"], is_global, cache))
        (x, aux), cache_out = jax.lax.scan(
            _maybe_remat(body, cfg), (x, jnp.zeros((), jnp.float32)), xs)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux, cache_out

    def train_logits(self, params, tokens, extras=None):
        cfg = self.cfg
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = embed(params["embedding"], tokens, cfg)
        x, aux, _ = self._run(params, x, positions)
        return unembed(params["embedding"], x, cfg), aux

    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        slots = self.cache_len_slots(max_len)
        dtype = jnp.dtype(cfg.dtype)
        kv = lambda: jnp.zeros(
            (cfg.n_layers, batch, slots, cfg.n_kv_heads, cfg.head_dim),
            dtype)
        return {"k": kv(), "v": kv(),
                "pos": jnp.full((cfg.n_layers, batch, slots), -1,
                                jnp.int32)}

    def prefill(self, params, tokens, extras=None, max_len: int = 0):
        cfg = self.cfg
        b, s = tokens.shape
        max_len = max_len or s
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = embed(params["embedding"], tokens, cfg)
        is_global = _is_global_vec(cfg)
        slots = self.cache_len_slots(max_len)
        keep = min(s, slots)

        def body(carry, xs):
            h, aux = carry
            p, ig = xs
            window, theta = _layer_window_theta(cfg, ig)
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            attn_out, _ = attention_block(
                p["attn"], hn, cfg, positions=positions, window=window,
                rope_theta=theta, causal=True)
            # recompute k/v for the cache (cheap vs attention itself)
            kproj = jnp.einsum("bsd,de->bse", hn, p["attn"]["wk"]).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            vproj = jnp.einsum("bsd,de->bse", hn, p["attn"]["wv"]).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            if "k_norm" in p["attn"]:
                kproj = rms_norm(kproj, p["attn"]["k_norm"], cfg.norm_eps)
            kproj = layers.apply_rope(kproj, positions, theta)
            ck, cv, cpos = _fill_cache_slots(kproj, vproj, positions,
                                             slots, keep)
            if cfg.post_block_norms:
                attn_out = rms_norm(attn_out, p["ln1_post"], cfg.norm_eps)
            h = h + attn_out
            hn = rms_norm(h, p["ln2"], cfg.norm_eps)
            aux_l = jnp.zeros((), jnp.float32)
            if cfg.n_experts:
                ffn_out, aux_l = moe.moe_block(p["moe"], hn, cfg)
            else:
                ffn_out = mlp_block(p["mlp"], hn, cfg)
            if cfg.post_block_norms:
                ffn_out = rms_norm(ffn_out, p["ln2_post"], cfg.norm_eps)
            return (h + ffn_out, aux + aux_l), {"k": ck, "v": cv,
                                                "pos": cpos}

        (x, aux), cache = jax.lax.scan(
            _maybe_remat(body, cfg), (x, jnp.zeros((), jnp.float32)),
            (params["layers"], is_global))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        return unembed(params["embedding"], x, cfg), cache

    def decode(self, params, token, cache, cache_len, extras=None):
        """token: (B, 1); cache_len: scalar int32 (tokens so far)."""
        cfg = self.cfg
        b = token.shape[0]
        positions = jnp.full((b, 1), cache_len, jnp.int32)
        x = embed(params["embedding"], token, cfg)
        is_global = _is_global_vec(cfg)
        slots = cache["k"].shape[2]
        write_at = jnp.mod(cache_len, slots)   # rolling when slots < seq

        def body(carry, xs):
            h = carry
            p, ig, c_in = xs
            window, theta = _layer_window_theta(cfg, ig)
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            q = jnp.einsum("bsd,de->bse", hn, p["attn"]["wq"]).reshape(
                b, 1, hq, hd)
            k = jnp.einsum("bsd,de->bse", hn, p["attn"]["wk"]).reshape(
                b, 1, hkv, hd)
            v = jnp.einsum("bsd,de->bse", hn, p["attn"]["wv"]).reshape(
                b, 1, hkv, hd)
            if "q_norm" in p["attn"]:
                q = rms_norm(q, p["attn"]["q_norm"], cfg.norm_eps)
                k = rms_norm(k, p["attn"]["k_norm"], cfg.norm_eps)
            q = layers.apply_rope(q, positions, theta)
            k = layers.apply_rope(k, positions, theta)
            ck = _cache_write(c_in["k"], k, write_at, positions,
                              cfg.cache_write)
            cv = _cache_write(c_in["v"], v, write_at, positions,
                              cfg.cache_write)
            cpos = _cache_write(c_in["pos"], positions, write_at,
                                positions, cfg.cache_write)
            out = chunked_attention(
                q, ck, cv, q_positions=positions, kv_positions=cpos,
                causal=True, window=jnp.where(ig, _FULL_WINDOW,
                                              cfg.sliding_window),
                sm_scale=hd ** -0.5, softcap=cfg.attn_logit_softcap)
            out = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, hq * hd),
                             p["attn"]["wo"])
            if cfg.post_block_norms:
                out = rms_norm(out, p["ln1_post"], cfg.norm_eps)
            h = h + out
            hn = rms_norm(h, p["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                ffn_out, _ = moe.moe_block(p["moe"], hn, cfg)
            else:
                ffn_out = mlp_block(p["mlp"], hn, cfg)
            if cfg.post_block_norms:
                ffn_out = rms_norm(ffn_out, p["ln2_post"], cfg.norm_eps)
            return h + ffn_out, {"k": ck, "v": cv, "pos": cpos}

        x, new_cache = jax.lax.scan(
            body, x, (params["layers"], is_global, cache))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(params["embedding"], x, cfg), new_cache


# ============================================================================
# family: hybrid — zamba2 (mamba2 groups + shared attention block)
# ============================================================================

ZAMBA_GROUP = 3  # [mamba2, mamba2, shared_attn]


class ZambaHybrid:
    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.n_layers % ZAMBA_GROUP == 0
        self.n_groups = cfg.n_layers // ZAMBA_GROUP
        self.n_mamba = 2 * self.n_groups

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        shared = {"ln1": init_rms_norm(cfg.d_model),
                  "ln2": init_rms_norm(cfg.d_model),
                  "attn": init_attention(ks[1], cfg),
                  "mlp": init_mlp(ks[2], cfg)}
        mamba_stack = _stack_init(ks[0], self.n_mamba,
                                  lambda k: mamba2.init_mamba2(k, cfg))
        mamba_stack = jax.tree.map(
            lambda l: l.reshape(self.n_groups, 2, *l.shape[1:]),
            mamba_stack)
        mamba_norms = jnp.zeros((self.n_groups, 2, cfg.d_model),
                                jnp.float32)
        return {"embedding": init_embedding(ks[3], cfg),
                "mamba": mamba_stack, "mamba_ln": mamba_norms,
                "shared_attn": shared,
                "final_norm": init_rms_norm(cfg.d_model)}

    def _group(self, params, h, mamba_p, mamba_ln, positions, *,
               cache=None, cache_len=None, mamba_state=None,
               decode=False):
        cfg = self.cfg
        new_states = []
        for i in range(2):
            p_i = jax.tree.map(lambda l: l[i], mamba_p)
            hn = rms_norm(h, mamba_ln[i], cfg.norm_eps)
            if decode:
                out, st = mamba2.mamba2_decode(
                    p_i, hn, jax.tree.map(lambda l: l[i], mamba_state),
                    cfg)
                new_states.append(st)
            else:
                out = mamba2.mamba2_block(p_i, hn, cfg)
            h = h + out
        sp = params["shared_attn"]
        hn = rms_norm(h, sp["ln1"], cfg.norm_eps)
        attn_out, kv = attention_block(
            sp["attn"], hn, cfg, positions=positions,
            window=cfg.sliding_window, causal=True,
            cache=cache, cache_len=cache_len)
        h = h + attn_out
        hn = rms_norm(h, sp["ln2"], cfg.norm_eps)
        h = h + mlp_block(sp["mlp"], hn, cfg)
        if decode:
            new_states = jax.tree.map(lambda *l: jnp.stack(l), *new_states)
        return h, kv, new_states

    def train_logits(self, params, tokens, extras=None):
        cfg = self.cfg
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = embed(params["embedding"], tokens, cfg)

        def body(h, xs):
            mamba_p, mamba_ln = xs
            h, _, _ = self._group(params, h, mamba_p, mamba_ln, positions)
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x,
                            (params["mamba"], params["mamba_ln"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(params["embedding"], x, cfg), jnp.zeros(
            (), jnp.float32)

    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        slots = min(max_len, cfg.sliding_window)
        kv = lambda: jnp.zeros(
            (self.n_groups, batch, slots, cfg.n_kv_heads, cfg.head_dim),
            dtype)
        m_state = mamba2.init_mamba2_state(cfg, batch)
        m_stack = jax.tree.map(
            lambda l: jnp.broadcast_to(
                l[None, None], (self.n_groups, 2, *l.shape)).copy(),
            m_state)
        return {"attn": {"k": kv(), "v": kv(),
                         "pos": jnp.full((self.n_groups, batch, slots), -1,
                                         jnp.int32)},
                "mamba": m_stack}

    def prefill(self, params, tokens, extras=None, max_len: int = 0):
        # Prefill = train-shape pass that also fills caches; done stepwise
        # over chunks is possible, but for the dry-run we emit the last
        # window of K/V (sliding-window shared attention) + mamba states.
        cfg = self.cfg
        b, s = tokens.shape
        max_len = max_len or s
        slots = min(max_len, cfg.sliding_window)
        keep = min(s, slots)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = embed(params["embedding"], tokens, cfg)

        def body(h, xs):
            mamba_p, mamba_ln = xs
            cfg_ = cfg
            new_states = []
            for i in range(2):
                p_i = jax.tree.map(lambda l: l[i], mamba_p)
                hn = rms_norm(h, mamba_ln[i], cfg_.norm_eps)
                d_in, nh, pd, n = mamba2._dims(cfg_)
                proj = jnp.einsum("bsd,de->bse", hn, p_i["in_proj"])
                z, xs_, b_, c_, dt = mamba2._split_proj(proj, cfg_)
                conv_in = jnp.concatenate([xs_, b_, c_], axis=-1)
                conv_out = jax.nn.silu(mamba2._causal_conv(
                    conv_in, p_i["conv_w"], p_i["conv_b"]))
                xs2, b2, c2 = jnp.split(conv_out, [d_in, d_in + n], axis=-1)
                dt_f = jax.nn.softplus(dt.astype(jnp.float32)
                                       + p_i["dt_bias"][None, None, :])
                a_neg = -jnp.exp(p_i["a_log"])
                xh = xs2.astype(jnp.float32).reshape(b, s, nh, pd)
                y, fin = mamba2.ssd_chunked(
                    xh, dt_f, a_neg, b2.astype(jnp.float32),
                    c2.astype(jnp.float32), cfg_.ssm_chunk)
                y = y + p_i["d_skip"][None, None, :, None] * xh
                y = y.reshape(b, s, d_in).astype(h.dtype)
                y = rms_norm(y * jax.nn.silu(z), p_i["gate_norm"],
                             cfg_.norm_eps)
                h = h + jnp.einsum("bse,ed->bsd", y, p_i["out_proj"])
                new_states.append(
                    {"conv": conv_in[:, s - (cfg_.conv_kernel - 1):]
                     .astype(conv_in.dtype),
                     "ssm": fin})
            sp = params["shared_attn"]
            hn = rms_norm(h, sp["ln1"], cfg_.norm_eps)
            attn_out, _ = attention_block(
                sp["attn"], hn, cfg_, positions=positions,
                window=cfg_.sliding_window, causal=True)
            kproj = jnp.einsum("bsd,de->bse", hn, sp["attn"]["wk"]).reshape(
                b, s, cfg_.n_kv_heads, cfg_.head_dim)
            vproj = jnp.einsum("bsd,de->bse", hn, sp["attn"]["wv"]).reshape(
                b, s, cfg_.n_kv_heads, cfg_.head_dim)
            kproj = layers.apply_rope(kproj, positions, cfg_.rope_theta)
            h = h + attn_out
            hn = rms_norm(h, sp["ln2"], cfg_.norm_eps)
            h = h + mlp_block(sp["mlp"], hn, cfg_)
            ck, cv, cpos = _fill_cache_slots(kproj, vproj, positions,
                                             slots, keep)
            cache_g = {"k": ck, "v": cv, "pos": cpos}
            states = jax.tree.map(lambda *l: jnp.stack(l), *new_states)
            return h, (cache_g, states)

        x, (attn_cache, m_states) = jax.lax.scan(
            _maybe_remat(body, cfg), x,
            (params["mamba"], params["mamba_ln"]))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        cache = {"attn": attn_cache, "mamba": m_states}
        return unembed(params["embedding"], x, cfg), cache

    def decode(self, params, token, cache, cache_len, extras=None):
        cfg = self.cfg
        b = token.shape[0]
        positions = jnp.full((b, 1), cache_len, jnp.int32)
        x = embed(params["embedding"], token, cfg)
        slots = cache["attn"]["k"].shape[2]
        write_at = jnp.mod(cache_len, slots)

        def body(h, xs):
            mamba_p, mamba_ln, c_attn, m_state = xs
            new_states = []
            for i in range(2):
                p_i = jax.tree.map(lambda l: l[i], mamba_p)
                hn = rms_norm(h, mamba_ln[i], cfg.norm_eps)
                out, st = mamba2.mamba2_decode(
                    p_i, hn, jax.tree.map(lambda l: l[i], m_state), cfg)
                new_states.append(st)
                h = h + out
            sp = params["shared_attn"]
            hn = rms_norm(h, sp["ln1"], cfg.norm_eps)
            hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            q = jnp.einsum("bsd,de->bse", hn, sp["attn"]["wq"]).reshape(
                b, 1, hq, hd)
            k = jnp.einsum("bsd,de->bse", hn, sp["attn"]["wk"]).reshape(
                b, 1, hkv, hd)
            v = jnp.einsum("bsd,de->bse", hn, sp["attn"]["wv"]).reshape(
                b, 1, hkv, hd)
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
            ck = jax.lax.dynamic_update_slice(
                c_attn["k"], k.astype(c_attn["k"].dtype),
                (0, write_at, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                c_attn["v"], v.astype(c_attn["v"].dtype),
                (0, write_at, 0, 0))
            cpos = jax.lax.dynamic_update_slice(c_attn["pos"], positions,
                                                (0, write_at))
            out = chunked_attention(
                q, ck, cv, q_positions=positions, kv_positions=cpos,
                causal=True, window=cfg.sliding_window, sm_scale=hd ** -0.5)
            out = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, hq * hd),
                             sp["attn"]["wo"])
            h = h + out
            hn = rms_norm(h, sp["ln2"], cfg.norm_eps)
            h = h + mlp_block(sp["mlp"], hn, cfg)
            states = jax.tree.map(lambda *l: jnp.stack(l), *new_states)
            return h, ({"k": ck, "v": cv, "pos": cpos}, states)

        x, (attn_cache, m_states) = jax.lax.scan(
            body, x, (params["mamba"], params["mamba_ln"],
                      cache["attn"], cache["mamba"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return (unembed(params["embedding"], x, cfg),
                {"attn": attn_cache, "mamba": m_states})


# ============================================================================
# family: ssm — xlstm (7 mLSTM : 1 sLSTM groups)
# ============================================================================

XLSTM_GROUP = 8
XLSTM_MLSTM_PER_GROUP = 7


class XLSTMStack:
    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.n_layers % XLSTM_GROUP == 0
        self.n_groups = cfg.n_layers // XLSTM_GROUP

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        m = XLSTM_MLSTM_PER_GROUP
        mlstm_stack = _stack_init(ks[0], self.n_groups * m,
                                  lambda k: xlstm.init_mlstm(k, cfg))
        mlstm_stack = jax.tree.map(
            lambda l: l.reshape(self.n_groups, m, *l.shape[1:]),
            mlstm_stack)
        slstm_stack = _stack_init(ks[1], self.n_groups,
                                  lambda k: xlstm.init_slstm(k, cfg))
        return {"embedding": init_embedding(ks[2], cfg),
                "mlstm": mlstm_stack,
                "mlstm_ln": jnp.zeros((self.n_groups, m, cfg.d_model),
                                      jnp.float32),
                "slstm": slstm_stack,
                "slstm_ln": jnp.zeros((self.n_groups, cfg.d_model),
                                      jnp.float32),
                "final_norm": init_rms_norm(cfg.d_model)}

    def _forward(self, params, x):
        cfg = self.cfg

        def body(h, xs):
            m_p, m_ln, s_p, s_ln = xs
            for i in range(XLSTM_MLSTM_PER_GROUP):
                p_i = jax.tree.map(lambda l: l[i], m_p)
                h = h + xlstm.mlstm_block(
                    p_i, rms_norm(h, m_ln[i], cfg.norm_eps), cfg)
            h = h + xlstm.slstm_block(
                s_p, rms_norm(h, s_ln, cfg.norm_eps), cfg)
            return h, None

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x,
                            (params["mlstm"], params["mlstm_ln"],
                             params["slstm"], params["slstm_ln"]))
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def train_logits(self, params, tokens, extras=None):
        cfg = self.cfg
        x = embed(params["embedding"], tokens, cfg)
        x = self._forward(params, x)
        return unembed(params["embedding"], x, cfg), jnp.zeros(
            (), jnp.float32)

    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        m = XLSTM_MLSTM_PER_GROUP
        c0, n0 = xlstm.init_mlstm_state(cfg, batch)
        rep = lambda l: jnp.broadcast_to(
            l[None, None], (self.n_groups, m, *l.shape)).copy()
        s_state = xlstm.init_slstm_state(cfg, batch)
        rep_s = lambda l: jnp.broadcast_to(
            l[None], (self.n_groups, *l.shape)).copy()
        return {"mlstm_c": rep(c0), "mlstm_n": rep(n0),
                "slstm": jax.tree.map(rep_s, s_state)}

    def prefill(self, params, tokens, extras=None, max_len: int = 0):
        # Recurrent state accumulates over the prompt; for the dry-run we
        # run the parallel form then a single decode step would continue
        # from states — here we fold the prompt through chunked mLSTM and
        # return final states per layer.
        cfg = self.cfg
        b, s = tokens.shape
        x = embed(params["embedding"], tokens, cfg)

        def body(h, xs):
            m_p, m_ln, s_p, s_ln = xs
            m_states_c, m_states_n = [], []
            for i in range(XLSTM_MLSTM_PER_GROUP):
                p_i = jax.tree.map(lambda l: l[i], m_p)
                hn = rms_norm(h, m_ln[i], cfg.norm_eps)
                d_in, nh, dk, dv = xlstm._dims(cfg)
                q = jnp.einsum("bsd,de->bse", hn, p_i["wq"]).reshape(
                    b, s, nh, dk)
                k = jnp.einsum("bsd,de->bse", hn, p_i["wk"]).reshape(
                    b, s, nh, dk)
                v = jnp.einsum("bsd,de->bse", hn, p_i["wv"]).reshape(
                    b, s, nh, dv)
                gates = jnp.einsum("bsd,de->bse", hn,
                                   p_i["wgate"]).astype(jnp.float32)
                log_f = jax.nn.log_sigmoid(gates[..., :nh])
                i_g = jax.nn.sigmoid(gates[..., nh:])
                hid, (c_fin, n_fin) = xlstm._mlstm_chunked(
                    q.astype(jnp.float32) * (dk ** -0.5),
                    k.astype(jnp.float32), v.astype(jnp.float32),
                    log_f, i_g, cfg.ssm_chunk)
                hid = hid.reshape(b, s, d_in).astype(h.dtype)
                og = jax.nn.sigmoid(
                    jnp.einsum("bsd,de->bse", hn, p_i["wog"]))
                hid = rms_norm(hid, p_i["out_norm"], cfg.norm_eps) * og
                h = h + jnp.einsum("bse,ed->bsd", hid, p_i["wo"])
                m_states_c.append(c_fin)
                m_states_n.append(n_fin)
            # sLSTM: run the sequential scan, keep final state
            hn = rms_norm(h, s_ln, cfg.norm_eps)
            xg = jnp.einsum("bsd,de->bse", hn,
                            s_p["wx"]).astype(jnp.float32)

            def sstep(st, x_t):
                new = xlstm._slstm_step(s_p, cfg, x_t, st)
                return new, new["h"]

            s_fin, hs = jax.lax.scan(sstep, xlstm.init_slstm_state(cfg, b),
                                     xg.transpose(1, 0, 2))
            hid = hs.transpose(1, 0, 2).astype(h.dtype)
            hid = rms_norm(hid, s_p["out_norm"], cfg.norm_eps)
            h = h + jnp.einsum("bse,ed->bsd", hid, s_p["wo"])
            return h, (jnp.stack(m_states_c), jnp.stack(m_states_n), s_fin)

        x, (mc, mn, s_states) = jax.lax.scan(
            _maybe_remat(body, cfg), x,
            (params["mlstm"], params["mlstm_ln"], params["slstm"],
             params["slstm_ln"]))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        cache = {"mlstm_c": mc, "mlstm_n": mn, "slstm": s_states}
        return unembed(params["embedding"], x, cfg), cache

    def decode(self, params, token, cache, cache_len, extras=None):
        cfg = self.cfg
        x = embed(params["embedding"], token, cfg)

        def body(h, xs):
            m_p, m_ln, s_p, s_ln, mc, mn, s_st = xs
            new_c, new_n = [], []
            for i in range(XLSTM_MLSTM_PER_GROUP):
                p_i = jax.tree.map(lambda l: l[i], m_p)
                hn = rms_norm(h, m_ln[i], cfg.norm_eps)
                out, (c2, n2) = xlstm.mlstm_decode(
                    p_i, hn, (mc[i], mn[i]), cfg)
                h = h + out
                new_c.append(c2)
                new_n.append(n2)
            hn = rms_norm(h, s_ln, cfg.norm_eps)
            out, s_new = xlstm.slstm_decode(s_p, hn, s_st, cfg)
            h = h + out
            return h, (jnp.stack(new_c), jnp.stack(new_n), s_new)

        x, (mc, mn, s_states) = jax.lax.scan(
            body, x, (params["mlstm"], params["mlstm_ln"],
                      params["slstm"], params["slstm_ln"],
                      cache["mlstm_c"], cache["mlstm_n"], cache["slstm"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        cache = {"mlstm_c": mc, "mlstm_n": mn, "slstm": s_states}
        return unembed(params["embedding"], x, cfg), cache


# ============================================================================
# family: encdec — whisper backbone
# ============================================================================


class WhisperEncDec:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 5)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": init_rms_norm(cfg.d_model),
                    "ln2": init_rms_norm(cfg.d_model),
                    "attn": init_attention(k1, cfg),
                    "mlp": init_mlp(k2, cfg)}

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"ln1": init_rms_norm(cfg.d_model),
                    "ln_x": init_rms_norm(cfg.d_model),
                    "ln2": init_rms_norm(cfg.d_model),
                    "attn": init_attention(k1, cfg),
                    "xattn": init_attention(k2, cfg, cross=True),
                    "mlp": init_mlp(k3, cfg)}

        return {"embedding": init_embedding(ks[0], cfg),
                "encoder": _stack_init(ks[1], cfg.encoder_layers, enc_layer),
                "enc_norm": init_rms_norm(cfg.d_model),
                "decoder": _stack_init(ks[2], cfg.n_layers, dec_layer),
                "final_norm": init_rms_norm(cfg.d_model)}

    def encode(self, params, frames):
        """frames: (B, F, d_model) stub frame embeddings."""
        cfg = self.cfg
        b, f, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))

        def body(h, p):
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            out, _ = attention_block(p["attn"], hn, cfg,
                                     positions=positions, window=None,
                                     causal=False)
            h = h + out
            hn = rms_norm(h, p["ln2"], cfg.norm_eps)
            return h + mlp_block(p["mlp"], hn, cfg), None

        h, _ = jax.lax.scan(_maybe_remat(body, cfg),
                            frames.astype(jnp.dtype(cfg.dtype)),
                            params["encoder"])
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    def _decoder_run(self, params, x, positions, enc_out=None,
                     enc_positions=None, self_cache=None, cross_cache=None,
                     cache_len=None, enc_len=None):
        cfg = self.cfg
        b = x.shape[0]

        def body(carry, xs):
            h = carry
            if self_cache is None:
                p = xs
                sc = None
                cc = None
            else:
                p, sc, cc = xs
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            out, sc_new = attention_block(
                p["attn"], hn, cfg, positions=positions, window=None,
                causal=True, cache=sc, cache_len=cache_len)
            h = h + out
            hn = rms_norm(h, p["ln_x"], cfg.norm_eps)
            if cc is not None:
                out, _ = attention_block(
                    p["xattn"], hn, cfg, positions=positions, window=None,
                    causal=False, cache=cc, cache_len=enc_len,
                    context=jnp.zeros((b, 1, cfg.d_model), h.dtype))
                cc_new = cc
            else:
                out, cc_new = attention_block(
                    p["xattn"], hn, cfg, positions=positions, window=None,
                    causal=False, context=enc_out,
                    context_positions=enc_positions)
            h = h + out
            hn = rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + mlp_block(p["mlp"], hn, cfg)
            return h, (sc_new, cc_new)

        xs = (params["decoder"] if self_cache is None
              else (params["decoder"], self_cache, cross_cache))
        x, (sc_out, cc_out) = jax.lax.scan(_maybe_remat(body, cfg), x, xs)
        return rms_norm(x, params["final_norm"], cfg.norm_eps), sc_out, \
            cc_out

    def train_logits(self, params, tokens, extras):
        cfg = self.cfg
        frames = extras["frames"]
        enc_out = self.encode(params, frames)
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        enc_positions = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32),
            (b, enc_out.shape[1]))
        x = embed(params["embedding"], tokens, cfg)
        x, _, _ = self._decoder_run(params, x, positions, enc_out,
                                    enc_positions)
        return unembed(params["embedding"], x, cfg), jnp.zeros(
            (), jnp.float32)

    def init_cache(self, batch: int, max_len: int,
                   enc_len: int = 0) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        kv = lambda s: jnp.zeros(
            (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim), dtype)
        return {"self": {"k": kv(max_len), "v": kv(max_len),
                         "pos": jnp.full((cfg.n_layers, batch, max_len),
                                         -1, jnp.int32)},
                "cross": {"k": kv(enc_len), "v": kv(enc_len)}}

    def prefill(self, params, tokens, extras, max_len: int = 0):
        """Encode audio, run decoder prompt, emit self+cross caches."""
        cfg = self.cfg
        frames = extras["frames"]
        enc_out = self.encode(params, frames)
        b, s = tokens.shape
        f = enc_out.shape[1]
        max_len = max_len or s
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        enc_positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32),
                                         (b, f))
        x = embed(params["embedding"], tokens, cfg)

        def body(h, p):
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            out, _ = attention_block(p["attn"], hn, cfg,
                                     positions=positions, window=None,
                                     causal=True)
            kproj = jnp.einsum("bsd,de->bse", hn, p["attn"]["wk"]).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            vproj = jnp.einsum("bsd,de->bse", hn, p["attn"]["wv"]).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            kproj = layers.apply_rope(kproj, positions, cfg.rope_theta)
            sk = jnp.zeros((b, max_len, cfg.n_kv_heads, cfg.head_dim),
                           kproj.dtype)
            sv = jnp.zeros_like(sk)
            spos = jnp.full((b, max_len), -1, jnp.int32)
            sk = jax.lax.dynamic_update_slice(sk, kproj, (0, 0, 0, 0))
            sv = jax.lax.dynamic_update_slice(sv, vproj, (0, 0, 0, 0))
            spos = jax.lax.dynamic_update_slice(spos, positions, (0, 0))
            h = h + out
            hn = rms_norm(h, p["ln_x"], cfg.norm_eps)
            out, cross_kv = attention_block(
                p["xattn"], hn, cfg, positions=positions, window=None,
                causal=False, context=enc_out,
                context_positions=enc_positions)
            h = h + out
            hn = rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + mlp_block(p["mlp"], hn, cfg)
            return h, ({"k": sk, "v": sv, "pos": spos}, cross_kv)

        x, (self_cache, cross_cache) = jax.lax.scan(
            _maybe_remat(body, cfg), x, params["decoder"])
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        return (unembed(params["embedding"], x, cfg),
                {"self": self_cache, "cross": cross_cache})

    def decode(self, params, token, cache, cache_len, extras=None):
        cfg = self.cfg
        b = token.shape[0]
        positions = jnp.full((b, 1), cache_len, jnp.int32)
        x = embed(params["embedding"], token, cfg)
        slots = cache["self"]["k"].shape[2]
        write_at = jnp.mod(cache_len, slots)
        enc_len = jnp.full((b,), cache["cross"]["k"].shape[2], jnp.int32)

        def body(h, xs):
            p, sc, cc = xs
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            q = jnp.einsum("bsd,de->bse", hn, p["attn"]["wq"]).reshape(
                b, 1, hq, hd)
            k = jnp.einsum("bsd,de->bse", hn, p["attn"]["wk"]).reshape(
                b, 1, hkv, hd)
            v = jnp.einsum("bsd,de->bse", hn, p["attn"]["wv"]).reshape(
                b, 1, hkv, hd)
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
            sk = jax.lax.dynamic_update_slice(
                sc["k"], k.astype(sc["k"].dtype), (0, write_at, 0, 0))
            sv = jax.lax.dynamic_update_slice(
                sc["v"], v.astype(sc["v"].dtype), (0, write_at, 0, 0))
            spos = jax.lax.dynamic_update_slice(sc["pos"], positions,
                                                (0, write_at))
            out = chunked_attention(
                q, sk, sv, q_positions=positions, kv_positions=spos,
                causal=True, window=None, sm_scale=hd ** -0.5)
            h = h + jnp.einsum("bse,ed->bsd", out.reshape(b, 1, hq * hd),
                               p["attn"]["wo"])
            hn = rms_norm(h, p["ln_x"], cfg.norm_eps)
            qx = jnp.einsum("bsd,de->bse", hn, p["xattn"]["wq"]).reshape(
                b, 1, hq, hd)
            kv_pos = jnp.broadcast_to(
                jnp.arange(cc["k"].shape[1], dtype=jnp.int32)[None, :],
                (b, cc["k"].shape[1]))
            out = chunked_attention(
                qx, cc["k"], cc["v"], q_positions=positions,
                kv_positions=kv_pos, causal=False, window=None,
                kv_lens=enc_len, sm_scale=hd ** -0.5)
            h = h + jnp.einsum("bse,ed->bsd", out.reshape(b, 1, hq * hd),
                               p["xattn"]["wo"])
            hn = rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + mlp_block(p["mlp"], hn, cfg)
            return h, ({"k": sk, "v": sv, "pos": spos}, cc)

        x, (self_cache, cross_cache) = jax.lax.scan(
            body, x, (params["decoder"], cache["self"], cache["cross"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return (unembed(params["embedding"], x, cfg),
                {"self": self_cache, "cross": cross_cache})


# ============================================================================
# family: vlm — llama-3.2-vision backbone (gated cross-attn groups)
# ============================================================================

VLM_GROUP = 5  # 4 self-attn layers + 1 gated cross-attn layer


class VLMCrossDecoder:
    def __init__(self, cfg):
        self.cfg = cfg
        assert cfg.n_layers % VLM_GROUP == 0
        self.n_groups = cfg.n_layers // VLM_GROUP
        self.n_self = self.n_groups * (VLM_GROUP - 1)

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        self_stack = _stack_init(ks[0], self.n_self,
                                 lambda k: _init_attn_layer(k, cfg))
        self_stack = jax.tree.map(
            lambda l: l.reshape(self.n_groups, VLM_GROUP - 1,
                                *l.shape[1:]), self_stack)

        def cross_layer(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": init_rms_norm(cfg.d_model),
                    "ln2": init_rms_norm(cfg.d_model),
                    "xattn": init_attention(k1, cfg, cross=True),
                    "mlp": init_mlp(k2, cfg),
                    "gate_attn": jnp.zeros((), jnp.float32),
                    "gate_mlp": jnp.zeros((), jnp.float32)}

        return {"embedding": init_embedding(ks[1], cfg),
                "self_layers": self_stack,
                "cross_layers": _stack_init(ks[2], self.n_groups,
                                            cross_layer),
                "final_norm": init_rms_norm(cfg.d_model)}

    def _cross_block(self, p, h, positions, img=None, img_positions=None,
                     cache=None, img_len=None):
        cfg = self.cfg
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        if cache is not None:
            b = h.shape[0]
            out, kv = attention_block(
                p["xattn"], hn, cfg, positions=positions, window=None,
                causal=False, cache=cache, cache_len=img_len,
                context=jnp.zeros((b, 1, cfg.d_model), h.dtype))
        else:
            out, kv = attention_block(
                p["xattn"], hn, cfg, positions=positions, window=None,
                causal=False, context=img, context_positions=img_positions)
        h = h + jnp.tanh(p["gate_attn"]).astype(h.dtype) * out
        hn = rms_norm(h, p["ln2"], cfg.norm_eps)
        h = h + (jnp.tanh(p["gate_mlp"]).astype(h.dtype)
                 * mlp_block(p["mlp"], hn, cfg))
        return h, kv

    def train_logits(self, params, tokens, extras):
        cfg = self.cfg
        img = extras["image_embeds"]          # (B, n_img, d_model)
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        img_positions = jnp.broadcast_to(
            jnp.arange(img.shape[1], dtype=jnp.int32), (b, img.shape[1]))
        x = embed(params["embedding"], tokens, cfg)
        img = img.astype(x.dtype)

        def body(carry, xs):
            h, aux = carry
            self_p, cross_p = xs

            def inner(hh, pp):
                hh, aux_l, _ = _apply_attn_layer(
                    pp, hh, cfg, window=_FULL_WINDOW, theta=cfg.rope_theta,
                    positions=positions)
                return hh, aux_l

            h, auxs = jax.lax.scan(inner, h, self_p)
            h, _ = self._cross_block(cross_p, h, positions, img=img,
                                     img_positions=img_positions)
            return (h, aux + jnp.sum(auxs)), None

        (x, aux), _ = jax.lax.scan(
            _maybe_remat(body, cfg), (x, jnp.zeros((), jnp.float32)),
            (params["self_layers"], params["cross_layers"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed(params["embedding"], x, cfg), aux

    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        kv = lambda n, s: jnp.zeros(
            (n, batch, s, cfg.n_kv_heads, cfg.head_dim), dtype)
        return {
            "self": {"k": kv(self.n_groups * (VLM_GROUP - 1), max_len)
                     .reshape(self.n_groups, VLM_GROUP - 1, batch, max_len,
                              cfg.n_kv_heads, cfg.head_dim),
                     "v": kv(self.n_groups * (VLM_GROUP - 1), max_len)
                     .reshape(self.n_groups, VLM_GROUP - 1, batch, max_len,
                              cfg.n_kv_heads, cfg.head_dim),
                     "pos": jnp.full((self.n_groups, VLM_GROUP - 1, batch,
                                      max_len), -1, jnp.int32)},
            "cross": {"k": kv(self.n_groups, cfg.n_image_tokens),
                      "v": kv(self.n_groups, cfg.n_image_tokens)},
        }

    def prefill(self, params, tokens, extras, max_len: int = 0):
        cfg = self.cfg
        img = extras["image_embeds"]
        b, s = tokens.shape
        max_len = max_len or s
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        img_positions = jnp.broadcast_to(
            jnp.arange(img.shape[1], dtype=jnp.int32), (b, img.shape[1]))
        x = embed(params["embedding"], tokens, cfg)
        img = img.astype(x.dtype)

        def body(h, xs):
            self_p, cross_p = xs

            def inner(hh, pp):
                hn = rms_norm(hh, pp["ln1"], cfg.norm_eps)
                out, _ = attention_block(
                    pp["attn"], hn, cfg, positions=positions,
                    window=None, causal=True)
                kproj = jnp.einsum("bsd,de->bse", hn,
                                   pp["attn"]["wk"]).reshape(
                    b, s, cfg.n_kv_heads, cfg.head_dim)
                vproj = jnp.einsum("bsd,de->bse", hn,
                                   pp["attn"]["wv"]).reshape(
                    b, s, cfg.n_kv_heads, cfg.head_dim)
                kproj = layers.apply_rope(kproj, positions, cfg.rope_theta)
                sk = jnp.zeros((b, max_len, cfg.n_kv_heads, cfg.head_dim),
                               kproj.dtype)
                sv = jnp.zeros_like(sk)
                spos = jnp.full((b, max_len), -1, jnp.int32)
                sk = jax.lax.dynamic_update_slice(sk, kproj, (0, 0, 0, 0))
                sv = jax.lax.dynamic_update_slice(sv, vproj, (0, 0, 0, 0))
                spos = jax.lax.dynamic_update_slice(spos, positions, (0, 0))
                hh = hh + out
                hn = rms_norm(hh, pp["ln2"], cfg.norm_eps)
                hh = hh + mlp_block(pp["mlp"], hn, cfg)
                return hh, {"k": sk, "v": sv, "pos": spos}

            h, self_cache = jax.lax.scan(inner, h, self_p)
            h, cross_kv = self._cross_block(cross_p, h, positions, img=img,
                                            img_positions=img_positions)
            return h, (self_cache, cross_kv)

        x, (self_cache, cross_cache) = jax.lax.scan(
            _maybe_remat(body, cfg), x,
            (params["self_layers"], params["cross_layers"]))
        x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        return (unembed(params["embedding"], x, cfg),
                {"self": self_cache, "cross": cross_cache})

    def decode(self, params, token, cache, cache_len, extras=None):
        cfg = self.cfg
        b = token.shape[0]
        positions = jnp.full((b, 1), cache_len, jnp.int32)
        x = embed(params["embedding"], token, cfg)
        slots = cache["self"]["k"].shape[3]
        write_at = jnp.mod(cache_len, slots)
        img_len = jnp.full((b,), cache["cross"]["k"].shape[2], jnp.int32)

        def body(h, xs):
            self_p, cross_p, sc, cc = xs

            def inner(hh, inner_xs):
                pp, c_in = inner_xs
                hn = rms_norm(hh, pp["ln1"], cfg.norm_eps)
                hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
                q = jnp.einsum("bsd,de->bse", hn, pp["attn"]["wq"]).reshape(
                    b, 1, hq, hd)
                k = jnp.einsum("bsd,de->bse", hn, pp["attn"]["wk"]).reshape(
                    b, 1, hkv, hd)
                v = jnp.einsum("bsd,de->bse", hn, pp["attn"]["wv"]).reshape(
                    b, 1, hkv, hd)
                q = layers.apply_rope(q, positions, cfg.rope_theta)
                k = layers.apply_rope(k, positions, cfg.rope_theta)
                sk = jax.lax.dynamic_update_slice(
                    c_in["k"], k.astype(c_in["k"].dtype),
                    (0, write_at, 0, 0))
                sv = jax.lax.dynamic_update_slice(
                    c_in["v"], v.astype(c_in["v"].dtype),
                    (0, write_at, 0, 0))
                spos = jax.lax.dynamic_update_slice(c_in["pos"], positions,
                                                    (0, write_at))
                out = chunked_attention(
                    q, sk, sv, q_positions=positions, kv_positions=spos,
                    causal=True, window=None, sm_scale=hd ** -0.5)
                hh = hh + jnp.einsum(
                    "bse,ed->bsd", out.reshape(b, 1, hq * hd),
                    pp["attn"]["wo"])
                hn = rms_norm(hh, pp["ln2"], cfg.norm_eps)
                hh = hh + mlp_block(pp["mlp"], hn, cfg)
                return hh, {"k": sk, "v": sv, "pos": spos}

            h, self_cache = jax.lax.scan(inner, h, (self_p, sc))
            h, _ = self._cross_block(cross_p, h, positions, cache=cc,
                                     img_len=img_len)
            return h, (self_cache, cc)

        x, (self_cache, cross_cache) = jax.lax.scan(
            body, x, (params["self_layers"], params["cross_layers"],
                      cache["self"], cache["cross"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return (unembed(params["embedding"], x, cfg),
                {"self": self_cache, "cross": cross_cache})


# ============================================================================
# dispatch
# ============================================================================

_FAMILIES = {
    "dense": UniformDecoder,
    "moe": UniformDecoder,
    "hybrid": ZambaHybrid,
    "ssm": XLSTMStack,
    "encdec": WhisperEncDec,
    "vlm": VLMCrossDecoder,
}


def build_model(cfg):
    try:
        return _FAMILIES[cfg.family](cfg)
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} "
                         f"(known: {sorted(_FAMILIES)})") from None
