"""Shared model layers: norms, RoPE, attention (full/sliding/cross), MLP.

Pure functions over parameter pytrees — no module framework. All big
matmuls keep explicit dtypes (params in cfg.dtype, accumulation f32), and
attention is *chunked* (flash-style online softmax via lax.scan over query
blocks) so 32k-token prefill never materialises an S x S score matrix.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]

# ----------------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(dtype)


def init_rms_norm(d: int) -> jnp.ndarray:
    return jnp.zeros((d,), dtype=jnp.float32)  # stored as (scale - 1)


# ----------------------------------------------------------------------------
# rotary embeddings
# ----------------------------------------------------------------------------


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta) -> jnp.ndarray:
    """x: (B, S, H, D_head); positions: (B, S) int32. theta may be a traced
    scalar (gemma3 uses different bases on local vs global layers)."""
    d_head = x.shape[-1]
    half = d_head // 2
    freq_exp = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = jnp.asarray(theta, dtype=jnp.float32) ** -freq_exp  # (half,)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq   # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# chunked (flash-style) attention, pure jnp
# ----------------------------------------------------------------------------

_NEG = -1e30


def _attend_block(q, k, v, mask, sm_scale, softcap):
    """q: (B,Hkv,G,Sq,D), k/v: (B,Hkv,Skv,D), mask: (B,1,1,Sq,Skv)."""
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * sm_scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    return jnp.where(mask, scores, _NEG)


def chunked_attention(q, k, v, *, q_positions, kv_positions, causal: bool,
                      window, kv_lens=None, sm_scale: float,
                      softcap: float = 0.0, q_chunk: int = 512,
                      kv_chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax attention without an S x S intermediate.

    q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D). GQA via Hq = G * Hkv.
    ``window`` limits attention to the last `window` positions (sliding
    window); it may be a traced scalar (per-layer dynamic). ``kv_lens``
    masks ragged KV (decode against a partially-filled cache).
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    if sq == 1:
        # decode fast path: no kv-chunk scan. One (B,H,G,1,Skv) score
        # tensor is small, and — crucially — it keeps a seq-sharded KV
        # cache local under SPMD (a scan would dynamic-slice the sharded
        # dim and force all-gathers; EXPERIMENTS.md §Perf cell 3).
        qf = q.astype(jnp.float32).reshape(b, 1, hkv, g, d)
        qf = qf.transpose(0, 2, 3, 1, 4)                  # (B,Hkv,G,1,D)
        kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)  # (B,Hkv,Skv,D)
        vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
        mask = jnp.ones((b, 1, skv), dtype=bool)
        if causal:
            mask &= kv_positions[:, None, :] <= q_positions[:, :, None]
        if window is not None:
            mask &= (q_positions[:, :, None]
                     - kv_positions[:, None, :]) < window
        if kv_lens is not None:
            mask &= kv_positions[:, None, :] < kv_lens[:, None, None]
        mask &= q_positions[:, :, None] >= 0
        mask &= kv_positions[:, None, :] >= 0
        s = _attend_block(qf, kf, vf, mask[:, None, None], sm_scale,
                          softcap)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf) / jnp.where(
            l > 0, l, 1.0)
        return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, hq, d).astype(
            q.dtype)
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    n_q, n_k = -(-sq // qc), -(-skv // kc)
    pad_q, pad_k = n_q * qc - sq, n_k * kc - skv

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qpos = q_positions
    kpos = kv_positions
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad_k)), constant_values=-1)

    # (B, Hkv, G, Sq, D) / (B, Hkv, Skv, D) layouts
    qf = qf.reshape(b, n_q * qc, hkv, g, d).transpose(0, 2, 3, 1, 4)
    kf = kf.transpose(0, 2, 1, 3)
    vf = vf.transpose(0, 2, 1, 3)

    kf_c = kf.reshape(b, hkv, n_k, kc, d)
    vf_c = vf.reshape(b, hkv, n_k, kc, d)
    kpos_c = kpos.reshape(b, n_k, kc)

    def q_block(carry, qi):
        qb = jax.lax.dynamic_slice_in_dim(qf, qi * qc, qc, axis=3)
        qp = jax.lax.dynamic_slice_in_dim(qpos, qi * qc, qc, axis=1)

        def kv_block(acc, ki):
            m_run, l_run, o_run = acc
            kb = kf_c[:, :, ki]
            vb = vf_c[:, :, ki]
            kp = kpos_c[:, ki]
            mask = jnp.ones((b, qc, kc), dtype=bool)
            if causal:
                mask &= kp[:, None, :] <= qp[:, :, None]
            if window is not None:
                mask &= (qp[:, :, None] - kp[:, None, :]) < window
            if kv_lens is not None:
                mask &= kp[:, None, :] < kv_lens[:, None, None]
            mask &= qp[:, :, None] >= 0
            mask &= kp[:, None, :] >= 0   # unwritten cache slots / padding
            mask = mask[:, None, None, :, :]
            s = _attend_block(qb, kb, vb, mask, sm_scale, softcap)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            o_new = (o_run * alpha[..., None]
                     + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb))
            return (m_new, l_new, o_new), None

        acc0 = (jnp.full((b, hkv, g, qc), _NEG, jnp.float32),
                jnp.zeros((b, hkv, g, qc), jnp.float32),
                jnp.zeros((b, hkv, g, qc, d), jnp.float32))
        (m, l, o), _ = jax.lax.scan(kv_block, acc0,
                                    jnp.arange(n_k, dtype=jnp.int32))
        l = jnp.where(l > 0, l, 1.0)
        return carry, (o / l[..., None])

    _, blocks = jax.lax.scan(q_block, None,
                             jnp.arange(n_q, dtype=jnp.int32))
    # blocks: (n_q, B, Hkv, G, qc, D) -> (B, Sq, Hq, D)
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(
        b, n_q * qc, hq, d)[:, :sq]
    return out.astype(q.dtype)


# ----------------------------------------------------------------------------
# attention block (self / cross), with KV-cache support
# ----------------------------------------------------------------------------


def init_attention(key, cfg, *, cross: bool = False) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.attn_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, dtype),
        "wo": dense_init(ks[3], cfg.attn_dim, cfg.d_model, dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = init_rms_norm(cfg.head_dim)
        p["k_norm"] = init_rms_norm(cfg.head_dim)
    return p


def attention_block(p: Params, x: jnp.ndarray, cfg, *,
                    positions: jnp.ndarray,
                    window=None,
                    rope_theta=None,
                    causal: bool = True,
                    cache: Optional[Dict[str, jnp.ndarray]] = None,
                    cache_len: Optional[jnp.ndarray] = None,
                    context: Optional[jnp.ndarray] = None,
                    context_positions: Optional[jnp.ndarray] = None):
    """Self- or cross-attention.

    Modes:
      * train/prefill: cache=None (self) or context=encoder states (cross)
      * decode: cache={'k','v'} (B, S_max, Hkv, D) + cache_len (B,) —
        writes the new token at cache_len, attends over the filled prefix.
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, hq, hd)
    kv_src = context if context is not None else x
    k = jnp.einsum("bsd,de->bse", kv_src, p["wk"]).reshape(
        b, kv_src.shape[1], hkv, hd)
    v = jnp.einsum("bsd,de->bse", kv_src, p["wv"]).reshape(
        b, kv_src.shape[1], hkv, hd)

    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    theta = cfg.rope_theta if rope_theta is None else rope_theta
    if context is None:  # rope only on self-attention
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)

    sm_scale = hd ** -0.5
    new_cache = None
    if cache is not None and context is None:
        # decode: write k/v at cache_len, attend over prefix
        idx = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        onehot = jax.nn.one_hot(idx, cache["k"].shape[1],
                                dtype=cache["k"].dtype)  # (B,s,Smax)
        ck = cache["k"] + jnp.einsum("bsm,bshd->bmhd", onehot, k)
        cv = cache["v"] + jnp.einsum("bsm,bshd->bmhd", onehot, v)
        new_cache = {"k": ck, "v": cv}
        kv_positions = jnp.broadcast_to(
            jnp.arange(ck.shape[1], dtype=jnp.int32)[None, :],
            (b, ck.shape[1]))
        out = chunked_attention(
            q, ck, cv, q_positions=positions, kv_positions=kv_positions,
            causal=False,  # masking via kv_lens + window below
            window=window, kv_lens=cache_len + s, sm_scale=sm_scale,
            softcap=cfg.attn_logit_softcap)
    elif cache is not None and context is not None:
        # decode cross-attention: cache holds precomputed context K/V
        kv_positions = jnp.broadcast_to(
            jnp.arange(cache["k"].shape[1], dtype=jnp.int32)[None, :],
            (b, cache["k"].shape[1]))
        out = chunked_attention(
            q, cache["k"], cache["v"], q_positions=positions,
            kv_positions=kv_positions, causal=False, window=None,
            kv_lens=cache_len, sm_scale=sm_scale,
            softcap=cfg.attn_logit_softcap)
        new_cache = cache
    else:
        kv_pos = (context_positions if context_positions is not None
                  else positions)
        out = chunked_attention(
            q, k, v, q_positions=positions, kv_positions=kv_pos,
            causal=causal and context is None, window=window,
            sm_scale=sm_scale, softcap=cfg.attn_logit_softcap)
        if context is not None:
            new_cache = {"k": k, "v": v}  # prefill: stash cross K/V

    out = jnp.einsum("bse,ed->bsd", out.reshape(b, s, hq * hd), p["wo"])
    return out, new_cache


# ----------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ----------------------------------------------------------------------------


def init_mlp(key, cfg, d_ff: Optional[int] = None) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], cfg.d_model, d_ff, dtype),
        "wi": dense_init(ks[1], cfg.d_model, d_ff, dtype),
        "wo": dense_init(ks[2], d_ff, cfg.d_model, dtype),
    }


def mlp_block(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    gate = act(jnp.einsum("bsd,df->bsf", x, p["wg"]))
    up = jnp.einsum("bsd,df->bsf", x, p["wi"])
    return jnp.einsum("bsf,fd->bsd", gate * up, p["wo"])


# ----------------------------------------------------------------------------
# embedding / unembedding
# ----------------------------------------------------------------------------


def init_embedding(key, cfg) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    p = {"embed": (jax.random.normal(
        key, (cfg.vocab_size, cfg.d_model), dtype=jnp.float32)
        * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size),
            dtype=jnp.float32) * 0.02).astype(dtype)
    return p


def embed(p: Params, tokens: jnp.ndarray, cfg) -> jnp.ndarray:
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype=x.dtype)
    return x


def unembed(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["embed"])
    return jnp.einsum("bsd,dv->bsv", x, p["unembed"])
