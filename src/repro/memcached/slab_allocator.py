"""A Memcached-faithful slab allocator simulator.

Models the storage hierarchy the paper measures:
  * memory is handed out one 1 MB *page* at a time from a global pool,
  * each page is assigned to one *slab class* and carved into fixed-size
    *chunks* (page_size // chunk_size per page; the remainder is page-tail
    waste, tracked separately),
  * an item goes to the smallest class whose chunk fits it; if the class
    has no free chunk and no pages remain, the class's LRU item is evicted
    (memcached's default per-class LRU), and
  * items larger than the largest chunk are rejected (SERVER_ERROR).

The paper's measurement — "Memory wasted" — is the internal fragmentation
of resident items: sum(chunk_size - item_size). That is ``stats().waste``.

Live reconfiguration (the paper's loop, applied): ``reassign`` moves one
page between classes with memcached's ``slabs reassign`` semantics (the
victim class's coldest page is reclaimed, its resident items evicted, the
page re-carved for the recipient class), and ``reconfigure`` retargets the
whole schedule: classes whose chunk size survives keep their pages and
items; vanished classes have every resident item evicted and their pages
parked in a free pool that future page grabs draw from first. Pages are
conserved across both (``pages_allocated`` never changes), and the costs
the controller's model charges — ``migration_evictions`` and
``n_reassigned_pages`` — are tracked in stats.

Multi-tenancy (the arbitration layer, PR 2): instead of a private
``mem_limit``, an allocator can draw pages from a shared
:class:`repro.core.arbiter.PagePool` (``page_pool=`` + ``tenant=``).
Every page it holds is then tenant-tagged in the pool, ``release_page``
gives the cheapest-to-reclaim page back (the cross-tenant analogue of
``slabs reassign``), and ``page_release_cost_bytes`` prices that release
for the arbiter's cost model. ``evicted_bytes`` / ``n_page_denials``
are the pressure signals the arbiter reads.

Eviction is a pluggable contract (``repro.memcached.eviction``): the
allocator tracks per-item accesses (touch-on-get / touch-on-overwrite),
delegates every victim choice to its :class:`EvictionPolicy`
(``eviction_policy=`` at construction, :meth:`set_policy` live), and
prices future evictions through the policy — ``migration_cost_bytes``
and ``page_release_cost_bytes`` report the policy's *predicted* cost,
not wholesale payload loss, so cost-aware policies approve more refits.
``evicted_hot_bytes`` (payload evicted despite a recent access) and
``reused_after_evict`` (evicted keys the traffic came back for) measure
how often the chosen victims were mistakes.

A key → class index makes ``get``/``delete`` O(1) instead of scanning
every class's LRU; the adaptive benchmarks replay millions of ops.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.distribution import PAGE_SIZE
from repro.memcached.eviction import ColdestLRU, EvictionPolicy


@dataclasses.dataclass
class SlabStats:
    n_resident: int
    n_rejected: int
    n_evicted: int
    pages_allocated: int
    item_bytes: int          # payload bytes of resident items
    allocated_bytes: int     # chunk bytes of resident items
    waste: int               # allocated_bytes - item_bytes (the paper's metric)
    page_tail_waste: int     # per-page remainder not usable as chunks
    per_class_resident: Dict[int, int]
    per_class_waste: Dict[int, int]
    n_reassigned_pages: int = 0   # pages moved between classes (live reconfig)
    migration_evictions: int = 0  # items evicted to reclaim victim pages
    evicted_bytes: int = 0        # payload bytes lost to pressure evictions
    n_page_denials: int = 0       # page grabs refused (mem_limit / pool)
    tenant: str = "default"       # pool ownership tag (multi-tenant mode)
    evicted_hot_bytes: int = 0    # evicted payload accessed < hot_window ago
    reused_after_evict: int = 0   # evicted keys the traffic asked for again
    eviction_policy: str = "coldest"   # the active policy's registry name

    @property
    def waste_fraction(self) -> float:
        return self.waste / max(self.item_bytes, 1)


@dataclasses.dataclass(frozen=True)
class ReconfigureReport:
    """Outcome of one live schedule change (the reconfiguration cost)."""

    evicted_items: int        # items lost from vanished classes
    evicted_bytes: int        # their payload bytes (the migration cost)
    reassigned_pages: int     # pages parked for re-carving
    kept_classes: Tuple[int, ...]
    new_classes: Tuple[int, ...]


class _SlabClass:
    __slots__ = ("chunk_size", "free_chunks", "lru", "pages")

    def __init__(self, chunk_size: int):
        self.chunk_size = chunk_size
        self.free_chunks = 0
        self.pages = 0
        self.lru: OrderedDict[str, int] = OrderedDict()  # key -> item size

    @property
    def resident_bytes(self) -> int:
        return sum(self.lru.values())


class SlabAllocator:
    """Slab allocator with per-class LRU eviction, memcached semantics.

    Memory comes either from an unbounded/`mem_limit`-bounded private
    pool (single-tenant, the paper's experiment shape) or from a shared
    tenant-tagged :class:`~repro.core.arbiter.PagePool`
    (``page_pool=`` + ``tenant=``, the multi-tenant mode the
    ``TenantArbiter`` drives). Live reconfiguration is page-conserving:
    ``reassign`` moves pages between classes, ``reconfigure`` retargets
    the whole schedule, ``release_page`` surrenders a page across
    tenants. ``stats()`` carries the paper's waste metric plus the
    pressure/migration counters the controller and arbiter consume.
    See ``docs/api.md`` for worked examples.
    """

    def __init__(self, chunk_sizes: Sequence[int], *,
                 mem_limit: Optional[int] = None,
                 page_size: int = PAGE_SIZE,
                 item_overhead: int = 0,
                 page_pool=None,
                 tenant: str = "default",
                 eviction_policy: Optional[EvictionPolicy] = None,
                 hot_window: int = 1000,
                 reuse_track_max: int = 100_000):
        chunk_sizes = sorted(int(c) for c in chunk_sizes)
        if not chunk_sizes:
            raise ValueError("need at least one slab class")
        if chunk_sizes[0] <= 0 or chunk_sizes[-1] > page_size:
            raise ValueError(f"chunk sizes must be in (0, {page_size}]")
        if page_pool is not None:
            if mem_limit is not None:
                raise ValueError("page_pool and mem_limit are exclusive")
            if page_pool.page_size != page_size:
                raise ValueError(
                    f"pool page_size {page_pool.page_size} != {page_size}")
            page_pool.register(tenant)
        self.page_size = page_size
        self.item_overhead = item_overhead
        self.chunk_sizes = np.asarray(chunk_sizes, dtype=np.int64)
        self.classes: List[_SlabClass] = [_SlabClass(c) for c in chunk_sizes]
        self.mem_limit = mem_limit
        self.page_pool = page_pool
        self.tenant = tenant
        self.pages_allocated = 0       # pool mode: pages currently owned
        self.free_pages = 0            # reclaimed pages awaiting re-carving
        self.n_rejected = 0
        self.n_evicted = 0
        self.evicted_bytes = 0
        self.n_page_denials = 0
        self.n_reassigned_pages = 0
        self.migration_evictions = 0
        self._total_set = 0
        self._key_class: Dict[str, _SlabClass] = {}  # O(1) get/delete index
        # -- eviction policy + per-item access tracking ----------------------
        self.policy: EvictionPolicy = eviction_policy or ColdestLRU()
        self.hot_window = int(hot_window)        # ops: "recently accessed"
        self.reuse_track_max = int(reuse_track_max)
        self.op_clock = 0                        # set/get/delete event clock
        self.evicted_hot_bytes = 0
        self.reused_after_evict = 0
        self._last_access: Dict[str, int] = {}   # key -> op_clock of touch
        self._evicted_keys: OrderedDict[str, None] = OrderedDict()  # FIFO
        for cls in self.classes:
            self.policy.watch(cls)

    # -- class selection ---------------------------------------------------
    def class_for(self, total_size: int) -> Optional[int]:
        idx = int(np.searchsorted(self.chunk_sizes, total_size, side="left"))
        if idx >= len(self.classes):
            return None
        return idx

    # -- memory management -------------------------------------------------
    def _grab_page(self, cls: _SlabClass) -> bool:
        if self.free_pages:
            self.free_pages -= 1
        elif self.page_pool is not None:
            if not self.page_pool.acquire(self.tenant):
                self.n_page_denials += 1
                return False
            self.pages_allocated += 1
        elif (self.mem_limit is not None
                and (self.pages_allocated + 1) * self.page_size
                > self.mem_limit):
            self.n_page_denials += 1
            return False
        else:
            self.pages_allocated += 1
        cls.pages += 1
        cls.free_chunks += self.page_size // cls.chunk_size
        return True

    # -- eviction bookkeeping ------------------------------------------------
    def set_policy(self, policy: EvictionPolicy) -> None:
        """Swap the eviction policy live. Per-class policy state is
        rebuilt from the current residents (LRU order preserved);
        counters and access history carry over."""
        self.policy = policy
        for cls in self.classes:
            policy.watch(cls)

    def _note_reuse(self, key: str) -> None:
        """The traffic asked for a previously-evicted key — the ground
        truth the predicted eviction costs are judged against."""
        if key in self._evicted_keys:
            del self._evicted_keys[key]
            self.reused_after_evict += 1

    def _track_eviction(self, key: str, vbytes: int) -> None:
        last = self._last_access.pop(key, None)
        if last is not None and self.op_clock - last <= self.hot_window:
            self.evicted_hot_bytes += vbytes
        self._evicted_keys[key] = None
        if len(self._evicted_keys) > self.reuse_track_max:
            self._evicted_keys.popitem(last=False)

    def _evict_item(self, cls: _SlabClass, key: str, *,
                    migration: bool) -> int:
        """Evict one resident of ``cls`` (chosen by the policy), doing
        all index/counter/policy bookkeeping. Returns payload bytes."""
        vbytes = cls.lru.pop(key)
        del self._key_class[key]
        cls.free_chunks += 1
        self._track_eviction(key, vbytes)
        self.policy.on_remove(cls, key)
        if migration:
            self.migration_evictions += 1
        else:
            self.n_evicted += 1
            self.evicted_bytes += vbytes
        return vbytes

    def set(self, key: str, value_size: int) -> bool:
        """Store an item; returns False when rejected (too large)."""
        total = value_size + self.item_overhead
        self._total_set += 1
        self.op_clock += 1
        idx = self.class_for(total)
        if idx is None:
            self.n_rejected += 1
            return False
        self._note_reuse(key)
        cls = self.classes[idx]
        prev = self._key_class.get(key)
        if prev is cls:                         # overwrite in place
            cls.lru.move_to_end(key)
            cls.lru[key] = total
            self._last_access[key] = self.op_clock
            self.policy.on_access(cls, key)
            return True
        if cls.free_chunks == 0 and not self._grab_page(cls):
            if not cls.lru:                     # nothing to evict
                self.n_rejected += 1
                return False
            self._evict_item(cls, self.policy.select_victim(cls),
                             migration=False)
        cls.free_chunks -= 1
        cls.lru[key] = total
        self._key_class[key] = cls
        self._last_access[key] = self.op_clock
        if prev is not None:   # size moved the key to a new class
            del prev.lru[key]
            prev.free_chunks += 1
            self.policy.on_remove(prev, key)
        self.policy.on_insert(cls, key, total)
        return True

    def get(self, key: str) -> bool:
        self.op_clock += 1
        cls = self._key_class.get(key)
        if cls is None:
            self._note_reuse(key)    # a miss on an evicted key: the
            return False             # eviction was a realized mistake
        cls.lru.move_to_end(key)
        self._last_access[key] = self.op_clock
        self.policy.on_access(cls, key)
        return True

    def delete(self, key: str) -> bool:
        cls = self._key_class.pop(key, None)
        if cls is None:
            return False
        del cls.lru[key]
        cls.free_chunks += 1
        self._last_access.pop(key, None)
        self.policy.on_remove(cls, key)
        return True

    # -- live reconfiguration ------------------------------------------------
    def reassign(self, src: int, dst: int) -> int:
        """Move one page from class ``src`` to class ``dst`` (class indexes),
        with memcached ``slabs reassign`` semantics: reclaim the victim
        class's cheapest page (victims chosen by the eviction policy;
        LRU-coldest under the default ``ColdestLRU``) by evicting its
        resident items, then re-carve the page into the recipient's
        chunk size. Returns evicted items.
        """
        if src == dst:
            raise ValueError("src and dst must differ")
        s_cls, d_cls = self.classes[src], self.classes[dst]
        if s_cls.pages == 0:
            raise ValueError(f"class {s_cls.chunk_size} has no pages")
        evicted, _ = self._reclaim_coldest_page(s_cls)
        d_cls.pages += 1
        d_cls.free_chunks += self.page_size // d_cls.chunk_size
        return evicted

    def _reclaim_coldest_page(self, cls: _SlabClass) -> Tuple[int, int]:
        """Reclaim one page from ``cls``: evict the policy's page
        victims until a full page of chunks is free, then un-carve that
        page. (The simulator does not track page membership; "the
        cheapest page" is modelled as the cheapest items beyond the
        free chunks — LRU-oldest under ``ColdestLRU``, lowest-ranked
        under ``RankedPageEviction``.) Returns
        ``(evicted_items, payload_bytes)``.
        """
        per_page = self.page_size // cls.chunk_size
        needed = per_page - cls.free_chunks
        evicted = ebytes = 0
        if needed > 0:
            for victim in self.policy.page_victims(cls, needed):
                ebytes += self._evict_item(cls, victim, migration=True)
                evicted += 1
        cls.free_chunks -= per_page
        cls.pages -= 1
        self.n_reassigned_pages += 1
        return evicted, ebytes

    # -- cross-tenant page surrender (the arbiter's execution primitive) -----
    def _release_cost(self, cls: _SlabClass) -> float:
        """Predicted payload cost if ``cls``'s cheapest page is
        reclaimed now — the eviction policy's
        ``page_reclaim_cost_bytes`` over the residents beyond the free
        chunks (raw bytes under ``ColdestLRU``; re-reference-weighted
        under the cost-aware policies)."""
        per_page = self.page_size // cls.chunk_size
        needed = per_page - cls.free_chunks
        if needed <= 0:
            return 0
        return self.policy.page_reclaim_cost_bytes(cls, needed)

    def _cheapest_release_class(self) -> Optional[_SlabClass]:
        """The class whose coldest page is cheapest to reclaim (None
        when no class holds a page)."""
        candidates = [c for c in self.classes if c.pages]
        if not candidates:
            return None
        return min(candidates, key=self._release_cost)

    def page_release_cost_bytes(self) -> Optional[float]:
        """Predicted eviction cost of :meth:`release_page` right now —
        the donor-side term of the arbiter's transfer cost model, priced
        by the eviction policy (exact payload bytes under ``ColdestLRU``,
        re-reference-weighted under the cost-aware policies). 0 when
        a parked free page can be surrendered without evicting; None
        when the allocator holds no page at all."""
        if self.free_pages:
            return 0
        cls = self._cheapest_release_class()
        return None if cls is None else self._release_cost(cls)

    def release_page(self) -> Tuple[int, int]:
        """Surrender one owned page (to the shared pool when attached).

        Parked free pages go first (no evictions); otherwise the class
        whose coldest page is cheapest to reclaim loses that page with
        ``slabs reassign`` eviction semantics. Returns
        ``(evicted_items, evicted_bytes)``.
        """
        evicted = ebytes = 0
        if self.free_pages:
            self.free_pages -= 1
        else:
            cls = self._cheapest_release_class()
            if cls is None:
                raise ValueError("no page to release")
            evicted, ebytes = self._reclaim_coldest_page(cls)
        self.pages_allocated -= 1
        if self.page_pool is not None:
            self.page_pool.release(self.tenant)
        return evicted, ebytes

    def migration_cost_bytes(self, new_chunk_sizes: Sequence[int]) -> float:
        """Predicted eviction cost of reconfiguring to
        ``new_chunk_sizes`` — the quantity the controller's cost model
        charges against predicted savings. The eviction policy prices
        each vanishing class (``class_teardown_cost_bytes``): under
        ``ColdestLRU`` this is the full resident payload (wholesale
        loss, the conservative legacy model); cost-aware policies
        charge only the bytes likely to be re-referenced."""
        new = {int(c) for c in new_chunk_sizes}
        return sum(self.policy.class_teardown_cost_bytes(cls)
                   for cls in self.classes if cls.chunk_size not in new)

    def reconfigure(self, new_chunk_sizes: Sequence[int]
                    ) -> ReconfigureReport:
        """Retarget the schedule live. Surviving chunk sizes keep their
        pages and resident items; vanished classes evict everything and
        park their pages in the free pool (``pages_allocated`` conserved).
        """
        new_sizes = sorted({int(c) for c in new_chunk_sizes})
        if not new_sizes:
            raise ValueError("need at least one slab class")
        if new_sizes[0] <= 0 or new_sizes[-1] > self.page_size:
            raise ValueError(
                f"chunk sizes must be in (0, {self.page_size}]")
        by_size = {cls.chunk_size: cls for cls in self.classes}
        kept = []
        classes: List[_SlabClass] = []
        for size in new_sizes:
            old = by_size.pop(size, None)
            if old is not None:
                kept.append(size)
                classes.append(old)
            else:
                classes.append(_SlabClass(size))
        evicted_items = 0
        evicted_bytes = 0
        reassigned = 0
        for victim in by_size.values():
            evicted_items += len(victim.lru)
            evicted_bytes += victim.resident_bytes
            for key, vbytes in victim.lru.items():
                del self._key_class[key]
                self._track_eviction(key, vbytes)
            victim.lru.clear()
            self.policy.forget(victim)
            reassigned += victim.pages
            self.free_pages += victim.pages
        self.classes = classes
        self.chunk_sizes = np.asarray(new_sizes, dtype=np.int64)
        self.n_reassigned_pages += reassigned
        self.migration_evictions += evicted_items
        return ReconfigureReport(
            evicted_items=evicted_items, evicted_bytes=evicted_bytes,
            reassigned_pages=reassigned, kept_classes=tuple(kept),
            new_classes=tuple(new_sizes))

    # -- measurement ---------------------------------------------------------
    def referenced_bytes(self, window: int) -> int:
        """Payload bytes of residents touched (set/get) within the last
        ``window`` ops of this allocator's clock — the *useful* half of
        resident payload under re-reference traffic. Resident bytes
        nobody references again are memory holes in every sense that
        matters to an operator; the eviction-policy benchmarks measure
        holes against this instead of raw residency, so a policy cannot
        look good by hoarding dead bytes."""
        cut = self.op_clock - int(window)
        la = self._last_access
        return sum(size for cls in self.classes
                   for key, size in cls.lru.items()
                   if la.get(key, cut) > cut)

    def stats(self) -> SlabStats:
        item_bytes = 0
        allocated = 0
        tail = 0
        per_resident: Dict[int, int] = {}
        per_waste: Dict[int, int] = {}
        n_resident = 0
        for cls in self.classes:
            sizes = cls.lru.values()
            n = len(cls.lru)
            n_resident += n
            b = sum(sizes)
            item_bytes += b
            allocated += n * cls.chunk_size
            tail += cls.pages * (self.page_size % cls.chunk_size)
            per_resident[cls.chunk_size] = n
            per_waste[cls.chunk_size] = n * cls.chunk_size - b
        return SlabStats(
            n_resident=n_resident, n_rejected=self.n_rejected,
            n_evicted=self.n_evicted, pages_allocated=self.pages_allocated,
            item_bytes=item_bytes, allocated_bytes=allocated,
            waste=allocated - item_bytes, page_tail_waste=tail,
            per_class_resident=per_resident, per_class_waste=per_waste,
            n_reassigned_pages=self.n_reassigned_pages,
            migration_evictions=self.migration_evictions,
            evicted_bytes=self.evicted_bytes,
            n_page_denials=self.n_page_denials,
            tenant=self.tenant,
            evicted_hot_bytes=self.evicted_hot_bytes,
            reused_after_evict=self.reused_after_evict,
            eviction_policy=self.policy.name)


def run_workload(chunk_sizes: Sequence[int], sizes: np.ndarray, *,
                 mem_limit: Optional[int] = None,
                 item_overhead: int = 0,
                 page_size: int = PAGE_SIZE) -> SlabStats:
    """Insert ``sizes[i]`` as key ``i`` (unique keys, insert-only — the
    paper's experiment shape) and return final stats."""
    alloc = SlabAllocator(chunk_sizes, mem_limit=mem_limit,
                          page_size=page_size, item_overhead=item_overhead)
    for i, s in enumerate(np.asarray(sizes).tolist()):
        alloc.set(str(i), int(s))
    return alloc.stats()
