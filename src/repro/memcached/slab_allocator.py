"""A Memcached-faithful slab allocator simulator.

Models the storage hierarchy the paper measures:
  * memory is handed out one 1 MB *page* at a time from a global pool,
  * each page is assigned to one *slab class* and carved into fixed-size
    *chunks* (page_size // chunk_size per page; the remainder is page-tail
    waste, tracked separately),
  * an item goes to the smallest class whose chunk fits it; if the class
    has no free chunk and no pages remain, the class's LRU item is evicted
    (memcached's default per-class LRU), and
  * items larger than the largest chunk are rejected (SERVER_ERROR).

The paper's measurement — "Memory wasted" — is the internal fragmentation
of resident items: sum(chunk_size - item_size). That is ``stats().waste``.

Live reconfiguration (the paper's loop, applied): ``reassign`` moves one
page between classes with memcached's ``slabs reassign`` semantics (the
victim class's coldest page is reclaimed, its resident items evicted, the
page re-carved for the recipient class), and ``reconfigure`` retargets the
whole schedule: classes whose chunk size survives keep their pages and
items; vanished classes have every resident item evicted and their pages
parked in a free pool that future page grabs draw from first. Pages are
conserved across both (``pages_allocated`` never changes), and the costs
the controller's model charges — ``migration_evictions`` and
``n_reassigned_pages`` — are tracked in stats.

A key → class index makes ``get``/``delete`` O(1) instead of scanning
every class's LRU; the adaptive benchmarks replay millions of ops.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.distribution import PAGE_SIZE


@dataclasses.dataclass
class SlabStats:
    n_resident: int
    n_rejected: int
    n_evicted: int
    pages_allocated: int
    item_bytes: int          # payload bytes of resident items
    allocated_bytes: int     # chunk bytes of resident items
    waste: int               # allocated_bytes - item_bytes (the paper's metric)
    page_tail_waste: int     # per-page remainder not usable as chunks
    per_class_resident: Dict[int, int]
    per_class_waste: Dict[int, int]
    n_reassigned_pages: int = 0   # pages moved between classes (live reconfig)
    migration_evictions: int = 0  # items evicted to reclaim victim pages

    @property
    def waste_fraction(self) -> float:
        return self.waste / max(self.item_bytes, 1)


@dataclasses.dataclass(frozen=True)
class ReconfigureReport:
    """Outcome of one live schedule change (the reconfiguration cost)."""

    evicted_items: int        # items lost from vanished classes
    evicted_bytes: int        # their payload bytes (the migration cost)
    reassigned_pages: int     # pages parked for re-carving
    kept_classes: Tuple[int, ...]
    new_classes: Tuple[int, ...]


class _SlabClass:
    __slots__ = ("chunk_size", "free_chunks", "lru", "pages")

    def __init__(self, chunk_size: int):
        self.chunk_size = chunk_size
        self.free_chunks = 0
        self.pages = 0
        self.lru: OrderedDict[str, int] = OrderedDict()  # key -> item size

    @property
    def resident_bytes(self) -> int:
        return sum(self.lru.values())


class SlabAllocator:
    """Slab allocator with per-class LRU eviction, memcached semantics."""

    def __init__(self, chunk_sizes: Sequence[int], *,
                 mem_limit: Optional[int] = None,
                 page_size: int = PAGE_SIZE,
                 item_overhead: int = 0):
        chunk_sizes = sorted(int(c) for c in chunk_sizes)
        if not chunk_sizes:
            raise ValueError("need at least one slab class")
        if chunk_sizes[0] <= 0 or chunk_sizes[-1] > page_size:
            raise ValueError(f"chunk sizes must be in (0, {page_size}]")
        self.page_size = page_size
        self.item_overhead = item_overhead
        self.chunk_sizes = np.asarray(chunk_sizes, dtype=np.int64)
        self.classes: List[_SlabClass] = [_SlabClass(c) for c in chunk_sizes]
        self.mem_limit = mem_limit
        self.pages_allocated = 0
        self.free_pages = 0            # reclaimed pages awaiting re-carving
        self.n_rejected = 0
        self.n_evicted = 0
        self.n_reassigned_pages = 0
        self.migration_evictions = 0
        self._total_set = 0
        self._key_class: Dict[str, _SlabClass] = {}  # O(1) get/delete index

    # -- class selection ---------------------------------------------------
    def class_for(self, total_size: int) -> Optional[int]:
        idx = int(np.searchsorted(self.chunk_sizes, total_size, side="left"))
        if idx >= len(self.classes):
            return None
        return idx

    # -- memory management -------------------------------------------------
    def _grab_page(self, cls: _SlabClass) -> bool:
        if self.free_pages:
            self.free_pages -= 1
        elif (self.mem_limit is not None
                and (self.pages_allocated + 1) * self.page_size
                > self.mem_limit):
            return False
        else:
            self.pages_allocated += 1
        cls.pages += 1
        cls.free_chunks += self.page_size // cls.chunk_size
        return True

    def set(self, key: str, value_size: int) -> bool:
        """Store an item; returns False when rejected (too large)."""
        total = value_size + self.item_overhead
        self._total_set += 1
        idx = self.class_for(total)
        if idx is None:
            self.n_rejected += 1
            return False
        cls = self.classes[idx]
        prev = self._key_class.get(key)
        if prev is cls:                         # overwrite in place
            cls.lru.move_to_end(key)
            cls.lru[key] = total
            return True
        if cls.free_chunks == 0 and not self._grab_page(cls):
            if not cls.lru:                     # nothing to evict
                self.n_rejected += 1
                return False
            victim, _ = cls.lru.popitem(last=False)  # evict class LRU head
            del self._key_class[victim]
            self.n_evicted += 1
            cls.free_chunks += 1
        cls.free_chunks -= 1
        cls.lru[key] = total
        self._key_class[key] = cls
        if prev is not None:   # size moved the key to a new class
            del prev.lru[key]
            prev.free_chunks += 1
        return True

    def get(self, key: str) -> bool:
        cls = self._key_class.get(key)
        if cls is None:
            return False
        cls.lru.move_to_end(key)
        return True

    def delete(self, key: str) -> bool:
        cls = self._key_class.pop(key, None)
        if cls is None:
            return False
        del cls.lru[key]
        cls.free_chunks += 1
        return True

    # -- live reconfiguration ------------------------------------------------
    def reassign(self, src: int, dst: int) -> int:
        """Move one page from class ``src`` to class ``dst`` (class indexes),
        with memcached ``slabs reassign`` semantics: reclaim the victim
        class's coldest page by evicting its resident items, then re-carve
        the page into the recipient's chunk size. Returns evicted items.
        """
        if src == dst:
            raise ValueError("src and dst must differ")
        s_cls, d_cls = self.classes[src], self.classes[dst]
        if s_cls.pages == 0:
            raise ValueError(f"class {s_cls.chunk_size} has no pages")
        per_page = self.page_size // s_cls.chunk_size
        evicted = 0
        # The simulator does not track page membership; the coldest page
        # is modelled as the LRU-oldest items beyond the free chunks.
        while s_cls.free_chunks < per_page:
            victim, _ = s_cls.lru.popitem(last=False)
            del self._key_class[victim]
            s_cls.free_chunks += 1
            evicted += 1
        s_cls.free_chunks -= per_page
        s_cls.pages -= 1
        d_cls.pages += 1
        d_cls.free_chunks += self.page_size // d_cls.chunk_size
        self.n_reassigned_pages += 1
        self.migration_evictions += evicted
        return evicted

    def migration_cost_bytes(self, new_chunk_sizes: Sequence[int]) -> int:
        """Predicted eviction bytes of reconfiguring to ``new_chunk_sizes``
        (resident payload of classes that would vanish) — the quantity the
        controller's cost model charges against predicted savings."""
        new = {int(c) for c in new_chunk_sizes}
        return sum(cls.resident_bytes for cls in self.classes
                   if cls.chunk_size not in new)

    def reconfigure(self, new_chunk_sizes: Sequence[int]
                    ) -> ReconfigureReport:
        """Retarget the schedule live. Surviving chunk sizes keep their
        pages and resident items; vanished classes evict everything and
        park their pages in the free pool (``pages_allocated`` conserved).
        """
        new_sizes = sorted({int(c) for c in new_chunk_sizes})
        if not new_sizes:
            raise ValueError("need at least one slab class")
        if new_sizes[0] <= 0 or new_sizes[-1] > self.page_size:
            raise ValueError(
                f"chunk sizes must be in (0, {self.page_size}]")
        by_size = {cls.chunk_size: cls for cls in self.classes}
        kept = []
        classes: List[_SlabClass] = []
        for size in new_sizes:
            old = by_size.pop(size, None)
            if old is not None:
                kept.append(size)
                classes.append(old)
            else:
                classes.append(_SlabClass(size))
        evicted_items = 0
        evicted_bytes = 0
        reassigned = 0
        for victim in by_size.values():
            evicted_items += len(victim.lru)
            evicted_bytes += victim.resident_bytes
            for key in victim.lru:
                del self._key_class[key]
            victim.lru.clear()
            reassigned += victim.pages
            self.free_pages += victim.pages
        self.classes = classes
        self.chunk_sizes = np.asarray(new_sizes, dtype=np.int64)
        self.n_reassigned_pages += reassigned
        self.migration_evictions += evicted_items
        return ReconfigureReport(
            evicted_items=evicted_items, evicted_bytes=evicted_bytes,
            reassigned_pages=reassigned, kept_classes=tuple(kept),
            new_classes=tuple(new_sizes))

    # -- measurement ---------------------------------------------------------
    def stats(self) -> SlabStats:
        item_bytes = 0
        allocated = 0
        tail = 0
        per_resident: Dict[int, int] = {}
        per_waste: Dict[int, int] = {}
        n_resident = 0
        for cls in self.classes:
            sizes = cls.lru.values()
            n = len(cls.lru)
            n_resident += n
            b = sum(sizes)
            item_bytes += b
            allocated += n * cls.chunk_size
            tail += cls.pages * (self.page_size % cls.chunk_size)
            per_resident[cls.chunk_size] = n
            per_waste[cls.chunk_size] = n * cls.chunk_size - b
        return SlabStats(
            n_resident=n_resident, n_rejected=self.n_rejected,
            n_evicted=self.n_evicted, pages_allocated=self.pages_allocated,
            item_bytes=item_bytes, allocated_bytes=allocated,
            waste=allocated - item_bytes, page_tail_waste=tail,
            per_class_resident=per_resident, per_class_waste=per_waste,
            n_reassigned_pages=self.n_reassigned_pages,
            migration_evictions=self.migration_evictions)


def run_workload(chunk_sizes: Sequence[int], sizes: np.ndarray, *,
                 mem_limit: Optional[int] = None,
                 item_overhead: int = 0,
                 page_size: int = PAGE_SIZE) -> SlabStats:
    """Insert ``sizes[i]`` as key ``i`` (unique keys, insert-only — the
    paper's experiment shape) and return final stats."""
    alloc = SlabAllocator(chunk_sizes, mem_limit=mem_limit,
                          page_size=page_size, item_overhead=item_overhead)
    for i, s in enumerate(np.asarray(sizes).tolist()):
        alloc.set(str(i), int(s))
    return alloc.stats()
