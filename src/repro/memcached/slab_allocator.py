"""A Memcached-faithful slab allocator simulator.

Models the storage hierarchy the paper measures:
  * memory is handed out one 1 MB *page* at a time from a global pool,
  * each page is assigned to one *slab class* and carved into fixed-size
    *chunks* (page_size // chunk_size per page; the remainder is page-tail
    waste, tracked separately),
  * an item goes to the smallest class whose chunk fits it; if the class
    has no free chunk and no pages remain, the class's LRU item is evicted
    (memcached's default per-class LRU), and
  * items larger than the largest chunk are rejected (SERVER_ERROR).

The paper's measurement — "Memory wasted" — is the internal fragmentation
of resident items: sum(chunk_size - item_size). That is ``stats().waste``.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.distribution import PAGE_SIZE


@dataclasses.dataclass
class SlabStats:
    n_resident: int
    n_rejected: int
    n_evicted: int
    pages_allocated: int
    item_bytes: int          # payload bytes of resident items
    allocated_bytes: int     # chunk bytes of resident items
    waste: int               # allocated_bytes - item_bytes (the paper's metric)
    page_tail_waste: int     # per-page remainder not usable as chunks
    per_class_resident: Dict[int, int]
    per_class_waste: Dict[int, int]

    @property
    def waste_fraction(self) -> float:
        return self.waste / max(self.item_bytes, 1)


class _SlabClass:
    __slots__ = ("chunk_size", "free_chunks", "lru", "pages")

    def __init__(self, chunk_size: int):
        self.chunk_size = chunk_size
        self.free_chunks = 0
        self.pages = 0
        self.lru: OrderedDict[str, int] = OrderedDict()  # key -> item size


class SlabAllocator:
    """Slab allocator with per-class LRU eviction, memcached semantics."""

    def __init__(self, chunk_sizes: Sequence[int], *,
                 mem_limit: Optional[int] = None,
                 page_size: int = PAGE_SIZE,
                 item_overhead: int = 0):
        chunk_sizes = sorted(int(c) for c in chunk_sizes)
        if not chunk_sizes:
            raise ValueError("need at least one slab class")
        if chunk_sizes[0] <= 0 or chunk_sizes[-1] > page_size:
            raise ValueError(f"chunk sizes must be in (0, {page_size}]")
        self.page_size = page_size
        self.item_overhead = item_overhead
        self.chunk_sizes = np.asarray(chunk_sizes, dtype=np.int64)
        self.classes: List[_SlabClass] = [_SlabClass(c) for c in chunk_sizes]
        self.mem_limit = mem_limit
        self.pages_allocated = 0
        self.n_rejected = 0
        self.n_evicted = 0
        self._total_set = 0

    # -- class selection ---------------------------------------------------
    def class_for(self, total_size: int) -> Optional[int]:
        idx = int(np.searchsorted(self.chunk_sizes, total_size, side="left"))
        if idx >= len(self.classes):
            return None
        return idx

    # -- memory management -------------------------------------------------
    def _grab_page(self, cls: _SlabClass) -> bool:
        if (self.mem_limit is not None
                and (self.pages_allocated + 1) * self.page_size
                > self.mem_limit):
            return False
        self.pages_allocated += 1
        cls.pages += 1
        cls.free_chunks += self.page_size // cls.chunk_size
        return True

    def set(self, key: str, value_size: int) -> bool:
        """Store an item; returns False when rejected (too large)."""
        total = value_size + self.item_overhead
        self._total_set += 1
        idx = self.class_for(total)
        if idx is None:
            self.n_rejected += 1
            return False
        cls = self.classes[idx]
        if key in cls.lru:                      # overwrite in place
            cls.lru.move_to_end(key)
            cls.lru[key] = total
            return True
        if cls.free_chunks == 0 and not self._grab_page(cls):
            if not cls.lru:                     # nothing to evict
                self.n_rejected += 1
                return False
            cls.lru.popitem(last=False)         # evict class LRU head
            self.n_evicted += 1
            cls.free_chunks += 1
        cls.free_chunks -= 1
        cls.lru[key] = total
        return True

    def get(self, key: str) -> bool:
        for cls in self.classes:
            if key in cls.lru:
                cls.lru.move_to_end(key)
                return True
        return False

    def delete(self, key: str) -> bool:
        for cls in self.classes:
            if key in cls.lru:
                del cls.lru[key]
                cls.free_chunks += 1
                return True
        return False

    # -- measurement ---------------------------------------------------------
    def stats(self) -> SlabStats:
        item_bytes = 0
        allocated = 0
        tail = 0
        per_resident: Dict[int, int] = {}
        per_waste: Dict[int, int] = {}
        n_resident = 0
        for cls in self.classes:
            sizes = cls.lru.values()
            n = len(cls.lru)
            n_resident += n
            b = sum(sizes)
            item_bytes += b
            allocated += n * cls.chunk_size
            tail += cls.pages * (self.page_size % cls.chunk_size)
            per_resident[cls.chunk_size] = n
            per_waste[cls.chunk_size] = n * cls.chunk_size - b
        return SlabStats(
            n_resident=n_resident, n_rejected=self.n_rejected,
            n_evicted=self.n_evicted, pages_allocated=self.pages_allocated,
            item_bytes=item_bytes, allocated_bytes=allocated,
            waste=allocated - item_bytes, page_tail_waste=tail,
            per_class_resident=per_resident, per_class_waste=per_waste)


def run_workload(chunk_sizes: Sequence[int], sizes: np.ndarray, *,
                 mem_limit: Optional[int] = None,
                 item_overhead: int = 0,
                 page_size: int = PAGE_SIZE) -> SlabStats:
    """Insert ``sizes[i]`` as key ``i`` (unique keys, insert-only — the
    paper's experiment shape) and return final stats."""
    alloc = SlabAllocator(chunk_sizes, mem_limit=mem_limit,
                          page_size=page_size, item_overhead=item_overhead)
    for i, s in enumerate(np.asarray(sizes).tolist()):
        alloc.set(str(i), int(s))
    return alloc.stats()
