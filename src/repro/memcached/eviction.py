"""Pluggable eviction policies for the slab layer — the cost half of
cost-aware refitting.

The paper's greedy refit only pays off when the migration cost it is
charged is honest. ``SlabAllocator`` historically evicted a victim
class's coldest items wholesale and priced every evicted payload byte
at full cost, which inflates the controller's migration cost model and
vetoes refits (and arbiter transfers) that would reduce memory holes.
Memshare (Cidon et al., 2017) shows rank-based victim selection —
evict the page whose residents are least likely to be re-referenced —
recovers most of that cost, and memcached's own segmented LRU is the
stock mechanism for separating one-hit wonders from the working set.

This module makes the eviction decision a *contract* rather than a
hardcoded behaviour (see ``docs/eviction.md`` for the full contract):

* :class:`EvictionPolicy` — the protocol. A policy observes item
  lifecycle events (`on_insert` / `on_access` / `on_remove`), selects
  victims (`select_victim` for one capacity eviction,
  `page_victims` for a page reclaim), and *prices* future evictions
  (`page_reclaim_cost_bytes`, `class_teardown_cost_bytes`) — the two
  numbers the :class:`~repro.core.controller.SlabController` cost
  model and the :class:`~repro.core.arbiter.TenantArbiter` donor
  selection consume.
* :class:`ColdestLRU` — the extracted legacy behaviour: pure
  per-class LRU, wholesale cost accounting (every resident byte of a
  victim is charged). Bit-compatible with the pre-policy allocator.
* :class:`SegmentedLRU` — memcached's HOT/WARM/COLD queues: new items
  enter HOT, re-referenced COLD items are promoted to WARM, a
  per-segment crawl demotes overflow (HOT→WARM when the item was
  re-referenced in HOT, →COLD otherwise). Victims come from COLD
  first; predicted costs weight each byte by its segment's
  re-reference weight.
* :class:`RankedPageEviction` — Memshare-style: every resident keeps
  a decayed re-reference score; a page reclaim evicts the residents
  whose scores are lowest (the cheapest "page"), and predicted costs
  charge only ``bytes x p(re-reference)``.

Policies are duck-typed against a minimal *slab-class view*: any
object with ``chunk_size`` (int) and ``lru`` (an ``OrderedDict``
mapping key → stored size, least recently used first). Both
``repro.memcached.SlabAllocator._SlabClass`` and the retained-chunk
holders inside :class:`repro.serving.KVSlabPool` satisfy it, so the
same three policies price byte chunks and KV token pages.
"""
from __future__ import annotations

import heapq
import math
from collections import OrderedDict
from itertools import islice
from typing import Dict, Iterable, List, Protocol, Tuple, runtime_checkable


@runtime_checkable
class EvictionPolicy(Protocol):
    """The eviction-policy contract (full prose: ``docs/eviction.md``).

    Lifecycle events — called by the owning allocator, after its own
    bookkeeping, so ``cls.lru`` already reflects the event:

    * ``on_insert(cls, key, size)`` — ``key`` became resident in ``cls``.
    * ``on_access(cls, key)``       — resident ``key`` was re-referenced
      (get hit, or overwrite in the same class).
    * ``on_remove(cls, key)``       — ``key`` left ``cls`` (delete,
      eviction, or cross-class move). Must be O(1)-ish and idempotent
      for unknown keys.
    * ``watch(cls)``                — (re)build per-class state from
      ``cls.lru`` (policy attached mid-run, LRU order preserved).
    * ``forget(cls)``               — ``cls`` was torn down
      (``reconfigure``); drop its state.

    Selection — keys must be residents of ``cls``; the caller performs
    the actual removal (and then calls ``on_remove``):

    * ``select_victim(cls)``     — one key for a capacity eviction.
    * ``page_victims(cls, n)``   — ``n`` keys whose eviction frees one
      page, cheapest first (the simulator models "the cheapest page"
      as the n cheapest chunks, since it does not track page
      membership).

    Cost prediction — the honest numbers the refit/transfer cost
    models charge *instead of* wholesale payload loss:

    * ``page_reclaim_cost_bytes(cls, n)``  — predicted payload cost of
      evicting ``page_victims(cls, n)`` now.
    * ``class_teardown_cost_bytes(cls)``   — predicted payload cost of
      evicting every resident of ``cls`` (the ``reconfigure`` term).
    * ``rereference_weight(cls, key)``     — the per-item ``p`` in
      ``[0, 1]`` behind both predictions (1 = certain re-reference,
      charged at full cost).

    Invariant (tested in ``tests/test_eviction.py``): predicted cost
    never exceeds the raw payload bytes of the same victims, and
    ``ColdestLRU`` predicts exactly the realized eviction bytes.
    """

    name: str

    def watch(self, cls) -> None: ...
    def forget(self, cls) -> None: ...
    def on_insert(self, cls, key: str, size: int) -> None: ...
    def on_access(self, cls, key: str) -> None: ...
    def on_remove(self, cls, key: str) -> None: ...
    def select_victim(self, cls) -> str: ...
    def page_victims(self, cls, n: int) -> List[str]: ...
    def page_reclaim_cost_bytes(self, cls, n: int) -> float: ...
    def class_teardown_cost_bytes(self, cls) -> float: ...
    def rereference_weight(self, cls, key: str) -> float: ...


# ---------------------------------------------------------------------------
# ColdestLRU — the legacy behaviour, extracted
# ---------------------------------------------------------------------------

class ColdestLRU:
    """Pure per-class LRU with wholesale cost accounting.

    Victims are the LRU-oldest residents (``cls.lru`` head); predicted
    costs charge every victim byte at full price
    (``rereference_weight == 1``). This is exactly what
    ``SlabAllocator`` did before the policy contract existed — the
    conservative baseline every comparison in ``docs/eviction.md``
    measures against.
    """

    name = "coldest"

    # lifecycle: the allocator's own LRU order is the whole state
    def watch(self, cls) -> None:
        pass

    def forget(self, cls) -> None:
        pass

    def on_insert(self, cls, key: str, size: int) -> None:
        pass

    def on_access(self, cls, key: str) -> None:
        pass

    def on_remove(self, cls, key: str) -> None:
        pass

    def select_victim(self, cls) -> str:
        return next(iter(cls.lru))

    def page_victims(self, cls, n: int) -> List[str]:
        return list(islice(cls.lru, n))

    def page_reclaim_cost_bytes(self, cls, n: int) -> float:
        return sum(islice(cls.lru.values(), n))

    def class_teardown_cost_bytes(self, cls) -> float:
        return sum(cls.lru.values())

    def rereference_weight(self, cls, key: str) -> float:
        return 1.0


# ---------------------------------------------------------------------------
# SegmentedLRU — memcached's HOT/WARM/COLD queues
# ---------------------------------------------------------------------------

class SegmentedLRU:
    """Memcached-style segmented LRU (HOT / WARM / COLD).

    * New items enter HOT.
    * A re-reference marks the item *active* in its segment (HOT/WARM:
      also moves it to the segment's MRU end); a re-referenced COLD
      item is promoted to WARM.
    * The per-segment crawl (run after every mutation) caps HOT and
      WARM at ``hot_max`` / ``warm_max`` fractions of the class's
      residents: overflowing HOT items demote to WARM when active,
      COLD otherwise; overflowing WARM items are re-queued in WARM
      when active (flag cleared), demoted to COLD otherwise.
    * Victims come from COLD first, then WARM, then HOT — each in LRU
      order.

    Predicted costs weight each victim byte by its segment's
    re-reference weight (``w_hot`` / ``w_warm`` / ``w_cold``): a COLD
    byte is nearly free to evict, a HOT byte costs full price. The
    crawl guarantees ``len(HOT) <= ceil(hot_max * n)`` and
    ``len(WARM) <= ceil(warm_max * n)`` after every event (the
    invariant ``tests/test_eviction.py`` checks).
    """

    name = "segmented"

    _HOT, _WARM, _COLD = 0, 1, 2

    def __init__(self, *, hot_max: float = 0.32, warm_max: float = 0.32,
                 w_hot: float = 1.0, w_warm: float = 0.5,
                 w_cold: float = 0.05):
        if not 0.0 < hot_max < 1.0 or not 0.0 < warm_max < 1.0:
            raise ValueError("segment caps must be in (0, 1)")
        self.hot_max = hot_max
        self.warm_max = warm_max
        self.weights = (w_hot, w_warm, w_cold)
        # per-class: three OrderedDicts key -> active flag
        self._segs: Dict[int, Tuple[OrderedDict, OrderedDict, OrderedDict]] \
            = {}

    def _state(self, cls) -> Tuple[OrderedDict, OrderedDict, OrderedDict]:
        st = self._segs.get(id(cls))
        if st is None:
            st = (OrderedDict(), OrderedDict(), OrderedDict())
            self._segs[id(cls)] = st
            for key in cls.lru:       # adopt existing residents (LRU order)
                st[self._HOT][key] = False
            self._crawl(cls, st)
        return st

    def watch(self, cls) -> None:
        self._segs.pop(id(cls), None)
        self._state(cls)

    def forget(self, cls) -> None:
        self._segs.pop(id(cls), None)

    # -- events --------------------------------------------------------------
    def on_insert(self, cls, key: str, size: int) -> None:
        st = self._state(cls)
        st[self._HOT][key] = False
        st[self._HOT].move_to_end(key)
        self._crawl(cls, st)

    def on_access(self, cls, key: str) -> None:
        st = self._state(cls)
        hot, warm, cold = st
        if key in hot:
            hot[key] = True
            hot.move_to_end(key)
        elif key in warm:
            warm[key] = True
            warm.move_to_end(key)
        elif key in cold:
            del cold[key]
            warm[key] = True          # promotion on re-reference
            self._crawl(cls, st)

    def on_remove(self, cls, key: str) -> None:
        st = self._segs.get(id(cls))
        if st is None:
            return
        for seg in st:
            if key in seg:
                del seg[key]
                return

    def _crawl(self, cls, st) -> None:
        """Demote segment overflow until the caps hold."""
        hot, warm, cold = st
        n = len(cls.lru)
        hot_cap = math.ceil(self.hot_max * n)
        warm_cap = math.ceil(self.warm_max * n)
        while len(hot) > hot_cap:
            key, active = hot.popitem(last=False)
            (warm if active else cold)[key] = False
        while len(warm) > warm_cap:
            key, active = warm.popitem(last=False)
            if active:
                warm[key] = False     # second chance at WARM's MRU end
            else:
                cold[key] = False

    # -- selection -----------------------------------------------------------
    def _victim_order(self, cls) -> Iterable[Tuple[str, int]]:
        st = self._state(cls)
        for seg_idx in (self._COLD, self._WARM, self._HOT):
            for key in st[seg_idx]:
                yield key, seg_idx

    def select_victim(self, cls) -> str:
        return next(iter(self._victim_order(cls)))[0]

    def page_victims(self, cls, n: int) -> List[str]:
        return [k for k, _ in islice(self._victim_order(cls), n)]

    # -- cost ----------------------------------------------------------------
    def page_reclaim_cost_bytes(self, cls, n: int) -> float:
        return sum(cls.lru[k] * self.weights[seg]
                   for k, seg in islice(self._victim_order(cls), n))

    def class_teardown_cost_bytes(self, cls) -> float:
        return sum(cls.lru[k] * self.weights[seg]
                   for k, seg in self._victim_order(cls))

    def rereference_weight(self, cls, key: str) -> float:
        st = self._state(cls)
        for seg_idx in (self._HOT, self._WARM, self._COLD):
            if key in st[seg_idx]:
                return self.weights[seg_idx]
        return 1.0     # unknown key: conservative


# ---------------------------------------------------------------------------
# RankedPageEviction — Memshare-style decayed re-reference ranking
# ---------------------------------------------------------------------------

class RankedPageEviction:
    """Rank-based victim selection over decayed re-reference scores.

    Every resident keeps a score that decays exponentially with the
    policy's event clock (half-life ``half_life`` events) and gains
    +1 on each re-reference — a streaming estimate of re-reference
    *rate*, the per-item analogue of the controller's decayed size
    sketch. The mapping ``p = score / (score + 1)`` turns the rate
    into the re-reference likelihood the cost models charge.

    * A page reclaim (``page_victims``) sorts the class's residents by
      decayed score and evicts the lowest — Memshare's "evict the page
      whose residents are least likely to be re-referenced", with the
      n cheapest chunks standing in for the cheapest page (the
      simulator does not track page membership).
    * A single capacity eviction scans only the ``scan_width``
      LRU-oldest residents and evicts the lowest-scored of them
      (bounded work on the hot path, same spirit as Redis's sampled
      LFU) — so a merely-unlucky hot item near the LRU tail survives.
    * Predicted costs are ``sum(bytes_i * p_i)`` over the victims:
      evicting a dead key is (correctly) almost free.
    """

    name = "ranked"

    def __init__(self, *, half_life: float = 4000.0,
                 insert_score: float = 0.5, scan_width: int = 32):
        if half_life <= 0:
            raise ValueError("half_life must be positive")
        self.half_life = float(half_life)
        self.insert_score = float(insert_score)
        self.scan_width = int(scan_width)
        self._decay = math.log(2.0) / self.half_life
        self._tick = 0
        # per-class: key -> (score_at_stamp, stamp)
        self._scores: Dict[int, Dict[str, Tuple[float, int]]] = {}

    def _state(self, cls) -> Dict[str, Tuple[float, int]]:
        st = self._scores.get(id(cls))
        if st is None:
            st = {key: (self.insert_score, self._tick) for key in cls.lru}
            self._scores[id(cls)] = st
        return st

    def watch(self, cls) -> None:
        self._scores.pop(id(cls), None)
        self._state(cls)

    def forget(self, cls) -> None:
        self._scores.pop(id(cls), None)

    def score(self, cls, key: str) -> float:
        """Current (decayed) re-reference score of a resident."""
        st = self._state(cls)
        val, stamp = st.get(key, (self.insert_score, self._tick))
        return val * math.exp(-self._decay * (self._tick - stamp))

    def rereference_weight(self, cls, key: str) -> float:
        s = self.score(cls, key)
        return s / (s + 1.0)

    # -- events --------------------------------------------------------------
    def on_insert(self, cls, key: str, size: int) -> None:
        self._tick += 1
        self._state(cls)[key] = (self.insert_score, self._tick)

    def on_access(self, cls, key: str) -> None:
        self._tick += 1
        self._state(cls)[key] = (self.score(cls, key) + 1.0, self._tick)

    def on_remove(self, cls, key: str) -> None:
        st = self._scores.get(id(cls))
        if st is not None:
            st.pop(key, None)

    # -- selection -----------------------------------------------------------
    def select_victim(self, cls) -> str:
        candidates = islice(cls.lru, self.scan_width)
        return min(candidates, key=lambda k: self.score(cls, k))

    def page_victims(self, cls, n: int) -> List[str]:
        if n >= len(cls.lru):
            return list(cls.lru)
        # O(m log n), not a full sort: donor pricing runs this for every
        # class of every tenant at each arbitration round
        return heapq.nsmallest(n, cls.lru, key=lambda k: self.score(cls, k))

    # -- cost ----------------------------------------------------------------
    def page_reclaim_cost_bytes(self, cls, n: int) -> float:
        return sum(cls.lru[k] * self.rereference_weight(cls, k)
                   for k in self.page_victims(cls, n))

    def class_teardown_cost_bytes(self, cls) -> float:
        return sum(cls.lru[k] * self.rereference_weight(cls, k)
                   for k in cls.lru)


_POLICIES = {
    "coldest": ColdestLRU,
    "segmented": SegmentedLRU,
    "ranked": RankedPageEviction,
}


def make_policy(name: str, **kwargs) -> EvictionPolicy:
    """Build a policy by its registry name (the benchmarks' ``--policy``
    axis): ``"coldest"`` | ``"segmented"`` | ``"ranked"``."""
    try:
        return _POLICIES[name](**kwargs)
    except KeyError:
        raise ValueError(
            f"unknown eviction policy {name!r}; "
            f"choose from {sorted(_POLICIES)}") from None
