"""Workload generation for the paper's experiments (Tables 1-5), plus
non-stationary variants the online controller adapts to.

The paper's runs are stationary — one (mu, sigma) operating point per
table — which can never exercise the *loop* half of its contribution.
The non-stationary generators below provide the scenarios where online
adaptation wins or loses:

* ``phase_shift_traffic`` — an abrupt jump between two paper operating
  points mid-stream (a deploy / tenant change),
* ``drift_traffic``       — gradual linear drift of the byte-space
  moments from one operating point to another (organic growth),
* ``diurnal_traffic``     — a periodic mixture of two operating points
  (day/night traffic mix).

Multi-tenant (what the arbiter serves): ``multitenant_phased_ops``
interleaves N tenants' op streams over one shared pool, each tenant's
arrival intensity a raised cosine shifted out of phase with the others
(tenants peak at different times — the setting where cross-tenant page
arbitration has something to win), with TTL-style deletes so an
off-peak tenant's pages accumulate free chunks (the holes arbitration
reclaims).

Re-reference skew (what the eviction policies serve):
``zipfian_rereference_ops`` draws get/set traffic over a fixed key
universe with Zipf-distributed popularity — a small hot set is
re-referenced constantly while a long tail of one-hit wonders streams
through. Under this skew the *choice* of eviction victim is
measurable: evicting a hot resident forces a read-through refill
(``reused_after_evict``), while evicting tail keys is free — exactly
the asymmetry the cost-aware policies in ``repro.memcached.eviction``
exploit.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.distribution import (PAGE_SIZE, PAPER_N_ITEMS,
                                     PAPER_WORKLOADS, PaperWorkload,
                                     lognormal_params_from_moments,
                                     sample_lognormal_sizes,
                                     sample_multimodal_sizes, size_histogram)


def paper_traffic(workload: PaperWorkload, *, n_items: int = PAPER_N_ITEMS,
                  seed: int = 0, log_space_sigma: bool = False
                  ) -> np.ndarray:
    """Item sizes for one of the paper's operating points."""
    rng = np.random.default_rng(seed + workload.table)
    return sample_lognormal_sizes(
        rng, n_items, workload.mu, workload.sigma,
        max_size=PAGE_SIZE, log_space_sigma=log_space_sigma)


def paper_histogram(workload: PaperWorkload, *,
                    n_items: int = PAPER_N_ITEMS, seed: int = 0,
                    log_space_sigma: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray]:
    return size_histogram(paper_traffic(workload, n_items=n_items, seed=seed,
                                        log_space_sigma=log_space_sigma))


def all_paper_workloads() -> Tuple[PaperWorkload, ...]:
    return PAPER_WORKLOADS


# -- non-stationary workloads (what the adaptive controller serves) ---------

def phase_shift_traffic(a: PaperWorkload, b: PaperWorkload, *,
                        n_items: int = PAPER_N_ITEMS,
                        shift_at: float = 0.5,
                        seed: int = 0) -> np.ndarray:
    """Abrupt operating-point change: sizes ~ ``a`` until ``shift_at`` of
    the stream, then ~ ``b``."""
    if not 0.0 < shift_at < 1.0:
        raise ValueError(f"shift_at must be in (0, 1), got {shift_at}")
    n_a = int(n_items * shift_at)
    rng = np.random.default_rng(seed)
    part_a = sample_lognormal_sizes(rng, n_a, a.mu, a.sigma,
                                    max_size=PAGE_SIZE)
    part_b = sample_lognormal_sizes(rng, n_items - n_a, b.mu, b.sigma,
                                    max_size=PAGE_SIZE)
    return np.concatenate([part_a, part_b])


def drift_traffic(a: PaperWorkload, b: PaperWorkload, *,
                  n_items: int = PAPER_N_ITEMS,
                  seed: int = 0) -> np.ndarray:
    """Gradual drift: the byte-space (mean, std) interpolate linearly from
    ``a`` to ``b`` across the stream; item ``i`` is drawn at the
    interpolated operating point."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0.0, 1.0, n_items)
    mean = (1.0 - t) * a.mu + t * b.mu
    std = (1.0 - t) * a.sigma + t * b.sigma
    mu_log, sigma_log = lognormal_params_from_moments(mean, std)
    raw = np.exp(mu_log + sigma_log * rng.standard_normal(n_items))
    return np.clip(np.rint(raw), 1, PAGE_SIZE).astype(np.int64)


def diurnal_traffic(a: PaperWorkload, b: PaperWorkload, *,
                    n_items: int = PAPER_N_ITEMS,
                    period: int = 200_000,
                    seed: int = 0) -> np.ndarray:
    """Periodic mixture: item ``i`` is drawn from ``b`` with probability
    ``0.5 * (1 - cos(2*pi*i/period))`` (pure-``a`` troughs, pure-``b``
    peaks) — the day/night shape of production cache traffic."""
    rng = np.random.default_rng(seed)
    i = np.arange(n_items)
    p_b = 0.5 * (1.0 - np.cos(2.0 * np.pi * i / period))
    from_b = rng.random(n_items) < p_b
    sizes_a = sample_lognormal_sizes(rng, n_items, a.mu, a.sigma,
                                     max_size=PAGE_SIZE)
    sizes_b = sample_lognormal_sizes(rng, n_items, b.mu, b.sigma,
                                     max_size=PAGE_SIZE)
    return np.where(from_b, sizes_b, sizes_a)


def diurnal_multimodal_traffic(day_modes: Sequence[Tuple[float, float, float]],
                               night_modes: Sequence[
                                   Tuple[float, float, float]], *,
                               n_items: int = PAPER_N_ITEMS,
                               period: int = 200_000,
                               seed: int = 0) -> np.ndarray:
    """Periodic swap between two MULTI-MODAL size mixtures.

    ``day_modes`` / ``night_modes`` are ``(weight, mean, std)``
    log-normal mode tuples (``sample_multimodal_sizes``); item ``i`` is
    drawn from the day mixture with probability
    ``0.5 * (1 - cos(2*pi*i/period))`` — pure-night troughs, pure-day
    peaks. Unlike :func:`diurnal_traffic` (two unimodal operating
    points, where a few classes cover the union for good), the union
    of two multi-modal phases needs roughly twice the classes of
    either phase alone — under a scarce class budget the optimal
    schedule genuinely *tracks* the phase, which is the regime the
    forecast-driven controller is for
    (``benchmarks/forecast_bench.py``).
    """
    rng = np.random.default_rng(seed)
    i = np.arange(n_items)
    p_day = 0.5 * (1.0 - np.cos(2.0 * np.pi * i / period))
    from_day = rng.random(n_items) < p_day
    day = sample_multimodal_sizes(rng, n_items, tuple(day_modes),
                                  max_size=PAGE_SIZE)
    night = sample_multimodal_sizes(rng, n_items, tuple(night_modes),
                                    max_size=PAGE_SIZE)
    return np.where(from_day, day, night)


# -- multi-tenant workloads (what the arbiter serves) ------------------------

@dataclasses.dataclass(frozen=True)
class TenantOp:
    """One operation of an interleaved multi-tenant stream."""

    tenant: int          # index into the workload list
    op: str              # "set" | "delete" | "get"
    key: str
    size: int            # item payload bytes (0 for deletes; for gets,
    #                      the key's payload — the read-through refill
    #                      size a driver stores on a miss)


def multitenant_phased_ops(workloads: Sequence[PaperWorkload], *,
                           n_sets: int = PAPER_N_ITEMS,
                           period: int = 0,
                           lifetime: int = 0,
                           base_rate: float = 0.1,
                           trough_mix: float = 0.0,
                           seed: int = 0) -> List[TenantOp]:
    """Interleaved op streams for N tenants peaking out of phase.

    Tenant ``t``'s arrival intensity at set ``i`` is
    ``base_rate + (1 - base_rate) * 0.5 * (1 - cos(2*pi*(i/period -
    t/N)))`` — raised cosines offset by ``1/N`` of a period, so exactly
    one tenant is near peak at any time. Each stored item is deleted
    ``~lifetime`` sets later (uniform 0.5x-1.5x jitter) — cache-TTL
    churn, so a tenant past its peak holds pages full of free chunks.

    ``trough_mix > 0`` additionally makes each tenant's *size
    distribution* non-stationary: at its deepest trough a fraction
    ``trough_mix`` of its items is drawn from the NEXT tenant's
    operating point (fading to zero at its peak) — per-tenant drift the
    intra-tenant controllers must chase while the arbiter moves pages.

    Returns ``n_sets`` set ops with their deletes interleaved in arrival
    order (total length < 2 * n_sets; items whose TTL survives the
    stream are never deleted). ``period`` defaults to half the stream,
    ``lifetime`` to a third of the period.
    """
    n_t = len(workloads)
    if n_t < 2:
        raise ValueError("need at least two tenants")
    period = period or max(2, n_sets // 2)
    lifetime = lifetime or max(1, period // 3)
    rng = np.random.default_rng(seed)
    sizes = [sample_lognormal_sizes(rng, n_sets, w.mu, w.sigma,
                                    max_size=PAGE_SIZE) for w in workloads]
    alt_sizes = [sample_lognormal_sizes(
        rng, n_sets, workloads[(t + 1) % n_t].mu,
        workloads[(t + 1) % n_t].sigma, max_size=PAGE_SIZE)
        for t in range(n_t)]
    step = np.arange(n_sets)[:, None]
    phase = np.arange(n_t)[None, :] / n_t
    cosarg = 2.0 * np.pi * (step / period - phase)
    intensity = base_rate + (1.0 - base_rate) * 0.5 * (1.0 - np.cos(cosarg))
    intensity /= intensity.sum(axis=1, keepdims=True)
    picks = (rng.random(n_sets)[:, None]
             > np.cumsum(intensity, axis=1)).sum(axis=1)
    troughness = 0.5 * (1.0 + np.cos(cosarg))   # 1 at trough, 0 at peak
    use_alt = rng.random(n_sets)
    ttls = rng.uniform(0.5, 1.5, n_sets) * lifetime
    ops: List[TenantOp] = []
    due: List[Tuple[int, int, int, str]] = []   # (expiry, seq, tenant, key)
    counters = [0] * n_t
    for i in range(n_sets):
        while due and due[0][0] <= i:
            _, _, dt, dkey = heapq.heappop(due)
            ops.append(TenantOp(dt, "delete", dkey, 0))
        tn = int(picks[i])
        key = f"t{tn}:{counters[tn]}"
        pool = (alt_sizes
                if use_alt[i] < trough_mix * troughness[i, tn] else sizes)
        ops.append(TenantOp(tn, "set", key, int(pool[tn][counters[tn]])))
        counters[tn] += 1
        heapq.heappush(due, (i + int(ttls[i]), i, tn, key))
    return ops


# -- re-reference-skewed workloads (what the eviction policies serve) --------

def zipfian_rereference_ops(workloads: Sequence[PaperWorkload], *,
                            n_ops: int = PAPER_N_ITEMS,
                            universe: int = 0,
                            get_frac: float = 0.7,
                            zipf_s: float = 1.1,
                            shift_at: float = 0.5,
                            head_frac: float = 0.05,
                            alt_workloads: Optional[
                                Sequence[PaperWorkload]] = None,
                            period: int = 0,
                            base_rate: float = 0.1,
                            seed: int = 0) -> List[TenantOp]:
    """Zipf-skewed get/set traffic over a fixed key universe, with a
    mid-stream tail shift.

    Each tenant owns ``universe`` keys; key ``j`` is drawn with
    probability proportional to ``1 / (j+1)**zipf_s`` (rank-1 keys are
    re-referenced constantly, the tail is one-hit wonders). Every op is
    a ``get`` with probability ``get_frac``, else a ``set``; both
    sample the same Zipf popularity, and a key's payload size is fixed
    at its first draw from the tenant's operating point. Gets carry
    that size so a driver can model a read-through cache (miss =>
    refill ``set``) — the loop that makes a wrongly-chosen eviction
    victim cost real bytes.

    At ``shift_at`` of the stream the *tail* changes identity: keys
    below the Zipf head (the top ``head_frac`` of ranks) are replaced
    by fresh keys whose sizes come from ``alt_workloads`` (defaults to
    the workload list rotated by one; pass explicitly for a single
    tenant). The hot head keeps its keys and sizes throughout. This is
    the scenario cost-aware eviction is about: after the shift the
    cache is full of stale phase-one tail items that will never be
    re-referenced — a wholesale cost model prices them at full payload
    and vetoes the refit toward the new tail sizes, while a rank-based
    model knows they are dead. ``shift_at=0`` disables the shift.

    With more than one workload, tenants' arrival intensities are the
    same out-of-phase raised cosines as ``multitenant_phased_ops``
    (``period`` defaults to half the stream), so the arbiter has pages
    to move while the policies pick victims. ``universe`` defaults to
    ``n_ops // (4 * n_tenants)`` — several times a constrained pool's
    capacity, so eviction is continuous.
    """
    n_t = len(workloads)
    if n_t < 1:
        raise ValueError("need at least one workload")
    if not 0.0 <= get_frac <= 1.0:
        raise ValueError(f"get_frac must be in [0, 1], got {get_frac}")
    universe = universe or max(64, n_ops // max(1, 4 * n_t))
    rng = np.random.default_rng(seed)
    probs = np.arange(1, universe + 1, dtype=np.float64) ** -zipf_s
    probs /= probs.sum()
    sizes = [sample_lognormal_sizes(rng, universe, w.mu, w.sigma,
                                    max_size=PAGE_SIZE) for w in workloads]
    if alt_workloads is None and n_t > 1:
        alt_workloads = [workloads[(t + 1) % n_t] for t in range(n_t)]
    alt_sizes = (None if alt_workloads is None else
                 [sample_lognormal_sizes(rng, universe, w.mu, w.sigma,
                                         max_size=PAGE_SIZE)
                  for w in alt_workloads])
    if n_t > 1:
        period = period or max(2, n_ops // 2)
        step = np.arange(n_ops)[:, None]
        phase = np.arange(n_t)[None, :] / n_t
        cosarg = 2.0 * np.pi * (step / period - phase)
        intensity = (base_rate
                     + (1.0 - base_rate) * 0.5 * (1.0 - np.cos(cosarg)))
        intensity /= intensity.sum(axis=1, keepdims=True)
        picks = (rng.random(n_ops)[:, None]
                 > np.cumsum(intensity, axis=1)).sum(axis=1)
    else:
        picks = np.zeros(n_ops, dtype=np.int64)
    key_idx = rng.choice(universe, size=n_ops, p=probs)
    is_get = rng.random(n_ops) < get_frac
    head_cut = max(1, int(head_frac * universe))
    shift_op = int(shift_at * n_ops) if (shift_at and alt_sizes is not None
                                         ) else n_ops
    ops: List[TenantOp] = []
    for i, (t, j, g) in enumerate(zip(picks, key_idx, is_get)):
        t, j = int(t), int(j)
        if i >= shift_op and j >= head_cut:     # post-shift tail key
            key, size = f"t{t}:b{j}", int(alt_sizes[t][j])
        else:
            key, size = f"t{t}:z{j}", int(sizes[t][j])
        ops.append(TenantOp(t, "get" if g else "set", key, size))
    return ops
