"""Workload generation for the paper's experiments (Tables 1-5), plus
non-stationary variants the online controller adapts to.

The paper's runs are stationary — one (mu, sigma) operating point per
table — which can never exercise the *loop* half of its contribution.
The non-stationary generators below provide the scenarios where online
adaptation wins or loses:

* ``phase_shift_traffic`` — an abrupt jump between two paper operating
  points mid-stream (a deploy / tenant change),
* ``drift_traffic``       — gradual linear drift of the byte-space
  moments from one operating point to another (organic growth),
* ``diurnal_traffic``     — a periodic mixture of two operating points
  (day/night traffic mix).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.distribution import (PAGE_SIZE, PAPER_N_ITEMS,
                                     PAPER_WORKLOADS, PaperWorkload,
                                     lognormal_params_from_moments,
                                     sample_lognormal_sizes, size_histogram)


def paper_traffic(workload: PaperWorkload, *, n_items: int = PAPER_N_ITEMS,
                  seed: int = 0, log_space_sigma: bool = False
                  ) -> np.ndarray:
    """Item sizes for one of the paper's operating points."""
    rng = np.random.default_rng(seed + workload.table)
    return sample_lognormal_sizes(
        rng, n_items, workload.mu, workload.sigma,
        max_size=PAGE_SIZE, log_space_sigma=log_space_sigma)


def paper_histogram(workload: PaperWorkload, *,
                    n_items: int = PAPER_N_ITEMS, seed: int = 0,
                    log_space_sigma: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray]:
    return size_histogram(paper_traffic(workload, n_items=n_items, seed=seed,
                                        log_space_sigma=log_space_sigma))


def all_paper_workloads() -> Tuple[PaperWorkload, ...]:
    return PAPER_WORKLOADS


# -- non-stationary workloads (what the adaptive controller serves) ---------

def phase_shift_traffic(a: PaperWorkload, b: PaperWorkload, *,
                        n_items: int = PAPER_N_ITEMS,
                        shift_at: float = 0.5,
                        seed: int = 0) -> np.ndarray:
    """Abrupt operating-point change: sizes ~ ``a`` until ``shift_at`` of
    the stream, then ~ ``b``."""
    if not 0.0 < shift_at < 1.0:
        raise ValueError(f"shift_at must be in (0, 1), got {shift_at}")
    n_a = int(n_items * shift_at)
    rng = np.random.default_rng(seed)
    part_a = sample_lognormal_sizes(rng, n_a, a.mu, a.sigma,
                                    max_size=PAGE_SIZE)
    part_b = sample_lognormal_sizes(rng, n_items - n_a, b.mu, b.sigma,
                                    max_size=PAGE_SIZE)
    return np.concatenate([part_a, part_b])


def drift_traffic(a: PaperWorkload, b: PaperWorkload, *,
                  n_items: int = PAPER_N_ITEMS,
                  seed: int = 0) -> np.ndarray:
    """Gradual drift: the byte-space (mean, std) interpolate linearly from
    ``a`` to ``b`` across the stream; item ``i`` is drawn at the
    interpolated operating point."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0.0, 1.0, n_items)
    mean = (1.0 - t) * a.mu + t * b.mu
    std = (1.0 - t) * a.sigma + t * b.sigma
    mu_log, sigma_log = lognormal_params_from_moments(mean, std)
    raw = np.exp(mu_log + sigma_log * rng.standard_normal(n_items))
    return np.clip(np.rint(raw), 1, PAGE_SIZE).astype(np.int64)


def diurnal_traffic(a: PaperWorkload, b: PaperWorkload, *,
                    n_items: int = PAPER_N_ITEMS,
                    period: int = 200_000,
                    seed: int = 0) -> np.ndarray:
    """Periodic mixture: item ``i`` is drawn from ``b`` with probability
    ``0.5 * (1 - cos(2*pi*i/period))`` (pure-``a`` troughs, pure-``b``
    peaks) — the day/night shape of production cache traffic."""
    rng = np.random.default_rng(seed)
    i = np.arange(n_items)
    p_b = 0.5 * (1.0 - np.cos(2.0 * np.pi * i / period))
    from_b = rng.random(n_items) < p_b
    sizes_a = sample_lognormal_sizes(rng, n_items, a.mu, a.sigma,
                                     max_size=PAGE_SIZE)
    sizes_b = sample_lognormal_sizes(rng, n_items, b.mu, b.sigma,
                                     max_size=PAGE_SIZE)
    return np.where(from_b, sizes_b, sizes_a)
