"""Workload generation for the paper's experiments (Tables 1-5), plus
non-stationary variants the online controller adapts to.

The paper's runs are stationary — one (mu, sigma) operating point per
table — which can never exercise the *loop* half of its contribution.
The non-stationary generators below provide the scenarios where online
adaptation wins or loses:

* ``phase_shift_traffic`` — an abrupt jump between two paper operating
  points mid-stream (a deploy / tenant change),
* ``drift_traffic``       — gradual linear drift of the byte-space
  moments from one operating point to another (organic growth),
* ``diurnal_traffic``     — a periodic mixture of two operating points
  (day/night traffic mix).

Multi-tenant (what the arbiter serves): ``multitenant_phased_ops``
interleaves N tenants' op streams over one shared pool, each tenant's
arrival intensity a raised cosine shifted out of phase with the others
(tenants peak at different times — the setting where cross-tenant page
arbitration has something to win), with TTL-style deletes so an
off-peak tenant's pages accumulate free chunks (the holes arbitration
reclaims).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.distribution import (PAGE_SIZE, PAPER_N_ITEMS,
                                     PAPER_WORKLOADS, PaperWorkload,
                                     lognormal_params_from_moments,
                                     sample_lognormal_sizes, size_histogram)


def paper_traffic(workload: PaperWorkload, *, n_items: int = PAPER_N_ITEMS,
                  seed: int = 0, log_space_sigma: bool = False
                  ) -> np.ndarray:
    """Item sizes for one of the paper's operating points."""
    rng = np.random.default_rng(seed + workload.table)
    return sample_lognormal_sizes(
        rng, n_items, workload.mu, workload.sigma,
        max_size=PAGE_SIZE, log_space_sigma=log_space_sigma)


def paper_histogram(workload: PaperWorkload, *,
                    n_items: int = PAPER_N_ITEMS, seed: int = 0,
                    log_space_sigma: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray]:
    return size_histogram(paper_traffic(workload, n_items=n_items, seed=seed,
                                        log_space_sigma=log_space_sigma))


def all_paper_workloads() -> Tuple[PaperWorkload, ...]:
    return PAPER_WORKLOADS


# -- non-stationary workloads (what the adaptive controller serves) ---------

def phase_shift_traffic(a: PaperWorkload, b: PaperWorkload, *,
                        n_items: int = PAPER_N_ITEMS,
                        shift_at: float = 0.5,
                        seed: int = 0) -> np.ndarray:
    """Abrupt operating-point change: sizes ~ ``a`` until ``shift_at`` of
    the stream, then ~ ``b``."""
    if not 0.0 < shift_at < 1.0:
        raise ValueError(f"shift_at must be in (0, 1), got {shift_at}")
    n_a = int(n_items * shift_at)
    rng = np.random.default_rng(seed)
    part_a = sample_lognormal_sizes(rng, n_a, a.mu, a.sigma,
                                    max_size=PAGE_SIZE)
    part_b = sample_lognormal_sizes(rng, n_items - n_a, b.mu, b.sigma,
                                    max_size=PAGE_SIZE)
    return np.concatenate([part_a, part_b])


def drift_traffic(a: PaperWorkload, b: PaperWorkload, *,
                  n_items: int = PAPER_N_ITEMS,
                  seed: int = 0) -> np.ndarray:
    """Gradual drift: the byte-space (mean, std) interpolate linearly from
    ``a`` to ``b`` across the stream; item ``i`` is drawn at the
    interpolated operating point."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0.0, 1.0, n_items)
    mean = (1.0 - t) * a.mu + t * b.mu
    std = (1.0 - t) * a.sigma + t * b.sigma
    mu_log, sigma_log = lognormal_params_from_moments(mean, std)
    raw = np.exp(mu_log + sigma_log * rng.standard_normal(n_items))
    return np.clip(np.rint(raw), 1, PAGE_SIZE).astype(np.int64)


def diurnal_traffic(a: PaperWorkload, b: PaperWorkload, *,
                    n_items: int = PAPER_N_ITEMS,
                    period: int = 200_000,
                    seed: int = 0) -> np.ndarray:
    """Periodic mixture: item ``i`` is drawn from ``b`` with probability
    ``0.5 * (1 - cos(2*pi*i/period))`` (pure-``a`` troughs, pure-``b``
    peaks) — the day/night shape of production cache traffic."""
    rng = np.random.default_rng(seed)
    i = np.arange(n_items)
    p_b = 0.5 * (1.0 - np.cos(2.0 * np.pi * i / period))
    from_b = rng.random(n_items) < p_b
    sizes_a = sample_lognormal_sizes(rng, n_items, a.mu, a.sigma,
                                     max_size=PAGE_SIZE)
    sizes_b = sample_lognormal_sizes(rng, n_items, b.mu, b.sigma,
                                     max_size=PAGE_SIZE)
    return np.where(from_b, sizes_b, sizes_a)


# -- multi-tenant workloads (what the arbiter serves) ------------------------

@dataclasses.dataclass(frozen=True)
class TenantOp:
    """One operation of an interleaved multi-tenant stream."""

    tenant: int          # index into the workload list
    op: str              # "set" | "delete"
    key: str
    size: int            # item payload bytes (0 for deletes)


def multitenant_phased_ops(workloads: Sequence[PaperWorkload], *,
                           n_sets: int = PAPER_N_ITEMS,
                           period: int = 0,
                           lifetime: int = 0,
                           base_rate: float = 0.1,
                           trough_mix: float = 0.0,
                           seed: int = 0) -> List[TenantOp]:
    """Interleaved op streams for N tenants peaking out of phase.

    Tenant ``t``'s arrival intensity at set ``i`` is
    ``base_rate + (1 - base_rate) * 0.5 * (1 - cos(2*pi*(i/period -
    t/N)))`` — raised cosines offset by ``1/N`` of a period, so exactly
    one tenant is near peak at any time. Each stored item is deleted
    ``~lifetime`` sets later (uniform 0.5x-1.5x jitter) — cache-TTL
    churn, so a tenant past its peak holds pages full of free chunks.

    ``trough_mix > 0`` additionally makes each tenant's *size
    distribution* non-stationary: at its deepest trough a fraction
    ``trough_mix`` of its items is drawn from the NEXT tenant's
    operating point (fading to zero at its peak) — per-tenant drift the
    intra-tenant controllers must chase while the arbiter moves pages.

    Returns ``n_sets`` set ops with their deletes interleaved in arrival
    order (total length < 2 * n_sets; items whose TTL survives the
    stream are never deleted). ``period`` defaults to half the stream,
    ``lifetime`` to a third of the period.
    """
    n_t = len(workloads)
    if n_t < 2:
        raise ValueError("need at least two tenants")
    period = period or max(2, n_sets // 2)
    lifetime = lifetime or max(1, period // 3)
    rng = np.random.default_rng(seed)
    sizes = [sample_lognormal_sizes(rng, n_sets, w.mu, w.sigma,
                                    max_size=PAGE_SIZE) for w in workloads]
    alt_sizes = [sample_lognormal_sizes(
        rng, n_sets, workloads[(t + 1) % n_t].mu,
        workloads[(t + 1) % n_t].sigma, max_size=PAGE_SIZE)
        for t in range(n_t)]
    step = np.arange(n_sets)[:, None]
    phase = np.arange(n_t)[None, :] / n_t
    cosarg = 2.0 * np.pi * (step / period - phase)
    intensity = base_rate + (1.0 - base_rate) * 0.5 * (1.0 - np.cos(cosarg))
    intensity /= intensity.sum(axis=1, keepdims=True)
    picks = (rng.random(n_sets)[:, None]
             > np.cumsum(intensity, axis=1)).sum(axis=1)
    troughness = 0.5 * (1.0 + np.cos(cosarg))   # 1 at trough, 0 at peak
    use_alt = rng.random(n_sets)
    ttls = rng.uniform(0.5, 1.5, n_sets) * lifetime
    ops: List[TenantOp] = []
    due: List[Tuple[int, int, int, str]] = []   # (expiry, seq, tenant, key)
    counters = [0] * n_t
    for i in range(n_sets):
        while due and due[0][0] <= i:
            _, _, dt, dkey = heapq.heappop(due)
            ops.append(TenantOp(dt, "delete", dkey, 0))
        tn = int(picks[i])
        key = f"t{tn}:{counters[tn]}"
        pool = (alt_sizes
                if use_alt[i] < trough_mix * troughness[i, tn] else sizes)
        ops.append(TenantOp(tn, "set", key, int(pool[tn][counters[tn]])))
        counters[tn] += 1
        heapq.heappush(due, (i + int(ttls[i]), i, tn, key))
    return ops
