"""Workload generation for the paper's experiments (Tables 1-5)."""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.distribution import (PAGE_SIZE, PAPER_N_ITEMS,
                                     PAPER_WORKLOADS, PaperWorkload,
                                     sample_lognormal_sizes, size_histogram)


def paper_traffic(workload: PaperWorkload, *, n_items: int = PAPER_N_ITEMS,
                  seed: int = 0, log_space_sigma: bool = False
                  ) -> np.ndarray:
    """Item sizes for one of the paper's operating points."""
    rng = np.random.default_rng(seed + workload.table)
    return sample_lognormal_sizes(
        rng, n_items, workload.mu, workload.sigma,
        max_size=PAGE_SIZE, log_space_sigma=log_space_sigma)


def paper_histogram(workload: PaperWorkload, *,
                    n_items: int = PAPER_N_ITEMS, seed: int = 0,
                    log_space_sigma: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray]:
    return size_histogram(paper_traffic(workload, n_items=n_items, seed=seed,
                                        log_space_sigma=log_space_sigma))


def all_paper_workloads() -> Tuple[PaperWorkload, ...]:
    return PAPER_WORKLOADS
