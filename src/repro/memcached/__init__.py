"""The paper's testbed: a Memcached-faithful slab-allocator simulator."""
from repro.memcached.eviction import (ColdestLRU, EvictionPolicy,
                                      RankedPageEviction, SegmentedLRU,
                                      make_policy)
from repro.memcached.metrics import WasteComparison, compare_schedules
from repro.memcached.slab_allocator import (ReconfigureReport, SlabAllocator,
                                            SlabStats, run_workload)
from repro.memcached.traffic import (TenantOp, all_paper_workloads,
                                     diurnal_multimodal_traffic,
                                     diurnal_traffic, drift_traffic,
                                     multitenant_phased_ops, paper_histogram,
                                     paper_traffic, phase_shift_traffic,
                                     zipfian_rereference_ops)

__all__ = [
    "WasteComparison", "compare_schedules", "ReconfigureReport",
    "SlabAllocator", "SlabStats", "run_workload", "all_paper_workloads",
    "diurnal_multimodal_traffic",
    "diurnal_traffic", "drift_traffic", "paper_histogram", "paper_traffic",
    "phase_shift_traffic", "TenantOp", "multitenant_phased_ops",
    "EvictionPolicy", "ColdestLRU", "SegmentedLRU", "RankedPageEviction",
    "make_policy", "zipfian_rereference_ops",
]
