"""The paper's testbed: a Memcached-faithful slab-allocator simulator."""
from repro.memcached.metrics import WasteComparison, compare_schedules
from repro.memcached.slab_allocator import (SlabAllocator, SlabStats,
                                            run_workload)
from repro.memcached.traffic import (all_paper_workloads, paper_histogram,
                                     paper_traffic)

__all__ = [
    "WasteComparison", "compare_schedules", "SlabAllocator", "SlabStats",
    "run_workload", "all_paper_workloads", "paper_histogram",
    "paper_traffic",
]
