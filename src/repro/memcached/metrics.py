"""Waste accounting shared by the simulator and the benchmarks."""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.distribution import PAGE_SIZE, size_histogram
from repro.core.waste import waste_exact


@dataclasses.dataclass(frozen=True)
class WasteComparison:
    old_chunks: np.ndarray
    new_chunks: np.ndarray
    old_waste: int
    new_waste: int

    @property
    def recovered_frac(self) -> float:
        if self.old_waste == 0:
            return 0.0
        return 1.0 - self.new_waste / self.old_waste


def compare_schedules(old_chunks: Sequence[int], new_chunks: Sequence[int],
                      sizes: np.ndarray, *,
                      page_size: int = PAGE_SIZE) -> WasteComparison:
    support, freqs = size_histogram(sizes)
    return WasteComparison(
        old_chunks=np.asarray(sorted(old_chunks), dtype=np.int64),
        new_chunks=np.asarray(sorted(new_chunks), dtype=np.int64),
        old_waste=waste_exact(old_chunks, support, freqs,
                              page_size=page_size),
        new_waste=waste_exact(new_chunks, support, freqs,
                              page_size=page_size))
