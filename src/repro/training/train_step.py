"""Training step: loss, remat, microbatch gradient accumulation.

``make_train_step`` builds the jittable step for any model in the zoo:

    state' , metrics = train_step(state, batch)

with microbatching via lax.scan (sequential gradient accumulation) so
giant global batches (e.g. 256 x 4096 tokens) hold only one microbatch
of activations at a time — the knob that bounds activation memory in the
dry-run. Optional int8 error-feedback compression is applied to the
accumulated gradient before the optimizer (the cross-pod reduce then
carries 4x fewer bytes; see grad_compress).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.training import grad_compress
from repro.training.optimizer import (AdamWConfig, OptState, adamw_update,
                                      init_opt_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1          # gradient-accumulation steps
    z_loss: float = 1e-4           # logit-norm regularizer (stability)
    compress_grads: bool = False   # int8 + error feedback
    accum_dtype: str = "float32"   # grad-accumulation buffer dtype


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    residuals: Any                 # error-feedback (None if off)


def init_train_state(params: Any, tcfg: TrainConfig) -> TrainState:
    residuals = (grad_compress.init_residuals(params)
                 if tcfg.compress_grads else None)
    return TrainState(params=params,
                      opt=init_opt_state(params, tcfg.optimizer),
                      residuals=residuals)


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray, *,
            z_loss: float = 0.0) -> jnp.ndarray:
    """Next-token cross entropy (labels already shifted) + z-loss."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - tgt)
    if z_loss:
        nll = nll + z_loss * jnp.mean(jnp.square(logz))
    return nll


def make_train_step(model, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    batch = {"tokens": (B, S+1) int32, + modality extras}; microbatching
    splits B into tcfg.microbatches sequential slices.
    """

    def loss_fn(params, tokens, extras):
        logits, aux = model.train_logits(params, tokens[:, :-1], extras)
        return lm_loss(logits, tokens[:, 1:], z_loss=tcfg.z_loss) + aux

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        tokens = batch["tokens"]
        extras = {k: v for k, v in batch.items() if k != "tokens"} or None
        m = tcfg.microbatches
        if m == 1:
            loss, grads = grad_fn(state.params, tokens, extras)
        else:
            b = tokens.shape[0]
            mb = b // m
            resh = lambda t: t.reshape(m, mb, *t.shape[1:])
            tokens_mb = resh(tokens)
            extras_mb = (jax.tree.map(resh, extras)
                         if extras is not None else None)

            def acc_body(carry, xs):
                loss_acc, grad_acc = carry
                tok = xs[0]
                ex = xs[1] if extras is not None else None
                loss, grads = grad_fn(state.params, tok, ex)
                return (loss_acc + loss,
                        jax.tree.map(
                            lambda a, g: a + g.astype(a.dtype),
                            grad_acc, grads)), None

            adt = jnp.dtype(tcfg.accum_dtype)
            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), state.params)
            xs = ((tokens_mb, extras_mb) if extras is not None
                  else (tokens_mb,))
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zero_grads), xs)
            loss = loss / m
            grads = jax.tree.map(lambda g: g / m, grads)

        residuals = state.residuals
        if tcfg.compress_grads:
            grads, residuals = grad_compress.compressed_grads(
                grads, residuals)

        params, opt, metrics = adamw_update(state.params, grads,
                                            state.opt, tcfg.optimizer)
        metrics = dict(metrics, loss=loss)
        return TrainState(params, opt, residuals), metrics

    return train_step
