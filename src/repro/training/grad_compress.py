"""int8 gradient compression with error feedback (distributed-opt trick).

For the slow cross-pod hop: quantize each gradient leaf to int8 with a
per-leaf scale before the 'pod'-axis all-reduce, keep the quantization
residual locally, and add it back into the next step's gradient (error
feedback, à la 1-bit Adam / EF-SGD). Intra-pod reduction stays full
precision. Exposed as a gradient transform wrapped around the grad fn;
the compressed reduce is expressed with shard_map + psum over 'pod'.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(x: jnp.ndarray,
                        residual: jnp.ndarray | None = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Apply error-feedback int8 round-trip; returns (value, new_residual).

    Used at the pod boundary: the value that crosses the wire is the
    dequantized int8; the residual stays on-device.
    """
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual.astype(jnp.float32)
    q, scale = quantize_int8(xf)
    deq = dequantize_int8(q, scale)
    return deq.astype(x.dtype), (xf - deq).astype(jnp.float32)


def init_residuals(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_grads(grads: Any, residuals: Any) -> Tuple[Any, Any]:
    """Error-feedback compress every leaf; returns (grads', residuals')."""
    out = jax.tree.map(compress_decompress, grads, residuals)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_r


def compression_ratio(grads: Any) -> float:
    """Wire bytes int8 / wire bytes native (diagnostic)."""
    native = sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
    wire = sum(g.size + 4 for g in jax.tree.leaves(grads))
    return wire / max(native, 1)
