"""AdamW with ZeRO-1 sharded moments (dependency-free, pytree-based).

Moments can be kept in bf16 (``moment_dtype``) for very large models
(arctic-480b), trading a little optimizer fidelity for ~2x state memory.
State sharding comes from ``repro.sharding.zero_spec``: each moment leaf
is additionally sharded over the data axis, so optimizer state scales
with 1/(data x model) like real ZeRO-1.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dtype=dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params: Any, grads: Any, state: OptState,
                 cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_f = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu_f = nu.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        mu_hat = mu_f / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu_f / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu_f.astype(mdt), nu_f.astype(mdt)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics
