"""Elastic scaling + straggler mitigation.

Node-failure story (1000+-node posture):
  1. Heartbeat/step-time watchdog flags a dead or straggling host.
  2. The job restarts on the surviving topology (possibly fewer or more
     data-parallel replicas — the model axis is fixed by the config).
  3. ``remesh()`` rebuilds the mesh for the new device count and
     re-places the last checkpoint onto it (CheckpointManager.restore
     already loads host-side, so any source topology restores onto any
     target topology).
  4. ``rescale_batch()`` re-derives per-replica batch so the *global*
     batch (and thus the learning-rate schedule) is preserved when the
     data axis shrinks/grows.

``StepTimer`` implements straggler detection: an EMA + deviation gate
flags steps slower than mean + k*dev; persistent stragglers trigger the
caller's policy (checkpoint-now, drop-host, or alert).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding import param_spec, to_shardings


def remesh(devices: Optional[list] = None, *, model_parallel: int,
           pod_shape: Optional[Tuple[int, int]] = None) -> Mesh:
    """Build the largest legal mesh for the surviving device set.

    data axis = n_devices // model_parallel (model axis is fixed by the
    checkpointed parameter layout; data/pod axes absorb topology change).
    """
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    if n % model_parallel:
        usable = (n // model_parallel) * model_parallel
        devices = devices[:usable]
        n = usable
    data = n // model_parallel
    if pod_shape is not None:
        pods, per_pod = pod_shape
        if pods * per_pod != data:
            raise ValueError(f"pod_shape {pod_shape} != data {data}")
        arr = np.asarray(devices).reshape(pods, per_pod, model_parallel)
        return Mesh(arr, ("pod", "data", "model"))
    arr = np.asarray(devices).reshape(data, model_parallel)
    return Mesh(arr, ("data", "model"))


def replace_state_on_mesh(state: Any, mesh: Mesh) -> Any:
    """Re-place a host-restored train state onto a (new) mesh."""
    spec = param_spec(state, mesh)
    return jax.tree.map(jax.device_put, state,
                        to_shardings(spec, mesh))


def rescale_batch(global_batch: int, mesh: Mesh) -> int:
    """Per-data-replica batch preserving the global batch size."""
    data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if global_batch % data:
        raise ValueError(
            f"global batch {global_batch} not divisible by data "
            f"parallelism {data}; adjust microbatching")
    return global_batch // data


@dataclasses.dataclass
class StepTimer:
    """EMA-based straggler detector for the training loop."""

    alpha: float = 0.05
    threshold: float = 4.0   # flag if step > mean + threshold * dev
    warmup: int = 10

    _mean: float = 0.0
    _dev: float = 0.0
    _count: int = 0
    _t0: float = 0.0
    stragglers: List[Tuple[int, float]] = dataclasses.field(
        default_factory=list)

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        """Record a step; returns True if it was a straggler."""
        dt = time.perf_counter() - self._t0
        self._count += 1
        if self._count <= self.warmup:
            self._mean = (self._mean * (self._count - 1) + dt) / self._count
            self._dev = max(self._dev, abs(dt - self._mean))
            return False
        is_straggler = dt > self._mean + self.threshold * max(
            self._dev, 1e-4)
        if is_straggler:
            self.stragglers.append((step, dt))
        else:  # only update stats on healthy steps
            self._mean = (1 - self.alpha) * self._mean + self.alpha * dt
            self._dev = ((1 - self.alpha) * self._dev
                         + self.alpha * abs(dt - self._mean))
        return is_straggler

    @property
    def mean_step_time(self) -> float:
        return self._mean
