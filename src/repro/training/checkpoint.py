"""Fault-tolerant checkpointing: atomic sharded writes, async, resharding.

Design (production posture, dependency-free):
  * one ``step_NNNNNNNN/`` directory per checkpoint,
  * each pytree leaf saved as its own .npy (device_get'd shard-merged),
    with a JSON manifest (treedef, shapes, dtypes, step, wall-time),
  * writes go to ``<dir>.tmp`` then os.rename — a crashed writer can
    never leave a half-checkpoint that restore would pick up,
  * an async writer thread moves serialization off the step path
    (``save(..., blocking=False)``), with ``wait()`` to join before the
    next save (single-writer discipline),
  * restore targets *any* mesh: leaves land as host arrays and are
    re-placed with jax.device_put against the new sharding
    (elastic restart after topology change — see elastic.py),
  * retention: keep the newest ``keep`` checkpoints, delete the rest.
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.treeutil import simple_keystr

_MANIFEST = "manifest.json"


def _leaf_paths(tree: Any) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {simple_keystr(p, separator="."): l for p, l in flat}


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Serialize ``tree`` at ``step``. Non-blocking mode device_gets
        synchronously (cheap, avoids racing the next update) and writes
        files on a background thread."""
        self.wait()
        host_leaves = {}
        for k, v in _leaf_paths(tree).items():
            arr = np.asarray(jax.device_get(v))
            if arr.dtype.kind not in "biufc":  # bf16 etc: np.load can't
                arr = arr.astype(np.float32)   # read it back; widen on
            host_leaves[k] = arr               # disk, re-narrow on restore
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "time": time.time(),
            "treedef": str(treedef),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host_leaves.items()},
            "extra": extra or {},
        }
        final = self._step_dir(step)

        def write():
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for k, v in host_leaves.items():
                np.save(os.path.join(tmp, k + ".npy"), v)
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f, indent=1)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return final

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.directory, name,
                                                    _MANIFEST)):
                steps.append(int(name[5:]))
        return max(steps) if steps else None

    def restore(self, template: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Rebuild ``template``-shaped pytree from disk. ``shardings``
        (optional pytree of NamedSharding) re-places leaves onto the
        *current* mesh — which may differ from the saving mesh."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no checkpoints under {self.directory}")
        d = self._step_dir(step)
        names = list(_leaf_paths(template))
        host = {}
        for k in names:
            host[k] = np.load(os.path.join(d, k + ".npy"))
        leaves_t, treedef = jax.tree_util.tree_flatten(template)
        flat_names = list(_leaf_paths(template))
        new_leaves = []
        for name, tleaf in zip(flat_names, leaves_t):
            arr = host[name]
            if tuple(arr.shape) != tuple(tleaf.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != template "
                    f"{tleaf.shape}")
            if arr.dtype != tleaf.dtype:  # jnp casts cover bf16 & friends
                arr = np.asarray(jnp.asarray(arr).astype(tleaf.dtype))
            new_leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return tree

    # -- internals ----------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def _gc(self) -> None:
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
