"""Training substrate: optimizer, train step, checkpoints, elasticity."""
from repro.training.checkpoint import CheckpointManager
from repro.training.elastic import (StepTimer, remesh, replace_state_on_mesh,
                                    rescale_batch)
from repro.training.optimizer import (AdamWConfig, OptState, adamw_update,
                                      init_opt_state, lr_schedule)
from repro.training.train_step import (TrainConfig, TrainState,
                                       init_train_state, lm_loss,
                                       make_train_step)

__all__ = ["CheckpointManager", "StepTimer", "remesh",
           "replace_state_on_mesh", "rescale_batch", "AdamWConfig",
           "OptState", "adamw_update", "init_opt_state", "lr_schedule",
           "TrainConfig", "TrainState", "init_train_state", "lm_loss",
           "make_train_step"]
