"""Scenario torture suite: trace replay, chaos events, adversarial drift.

The layer that turns the repo's benchmarks into an adversarial test
harness — see ``docs/scenarios.md``:

* :mod:`repro.scenarios.trace` — published cache-trace CSV schemas
  (Twitter SoCC'20 / Meta CacheLib) ⇄ ``TenantOp`` streams, with a
  key-coherent down-sampler and a synthetic-trace writer so CI never
  downloads anything.
* :mod:`repro.scenarios.chaos` — injectable events over any op stream:
  tenant join/leave, flash crowds, size-distribution steps, TTL storms.
* :mod:`repro.scenarios.adversary` — hill-climb over drift schedules
  maximizing controller regret vs the hindsight dp-optimal schedule;
  worst finds persist under ``fixtures/`` as pinned regressions.
* :mod:`repro.scenarios.invariants` — conservation / sketch-mass /
  dispatch-accounting / KV-token / fleet-consistency checkers the
  bench gates CI on.
"""
from repro.scenarios.adversary import (DriftSchedule, EvalResult,
                                       SearchResult, WORST_FIXTURE, evaluate,
                                       load_fixture, replay_fixture,
                                       save_fixture, search)
from repro.scenarios.chaos import (ChaosResult, FlashCrowd, SizeStep,
                                   TenantJoin, TenantLeave, TTLStorm,
                                   apply_chaos, tenants_of)
from repro.scenarios.invariants import (check_all, check_conservation,
                                        check_dispatch_accounting,
                                        check_fleet, check_kv_pool,
                                        check_sketch_mass)
from repro.scenarios.trace import (META_SCHEMA, TWITTER_SCHEMA, TraceSchema,
                                   downsample, format_trace, parse_trace,
                                   synthetic_trace_ops, trace_histogram,
                                   trace_requests, write_trace)

__all__ = [
    "TraceSchema", "TWITTER_SCHEMA", "META_SCHEMA", "parse_trace",
    "format_trace", "write_trace", "synthetic_trace_ops", "downsample",
    "trace_histogram", "trace_requests",
    "TenantJoin", "TenantLeave", "FlashCrowd", "SizeStep", "TTLStorm",
    "ChaosResult", "apply_chaos", "tenants_of",
    "DriftSchedule", "EvalResult", "SearchResult", "evaluate", "search",
    "save_fixture", "load_fixture", "replay_fixture", "WORST_FIXTURE",
    "check_all", "check_conservation", "check_sketch_mass",
    "check_dispatch_accounting", "check_fleet", "check_kv_pool",
]
