"""Chaos events: fault-shaped perturbations injected into any op stream.

The "Idiosyncrasies of Programmable Caching Engines" catalogue of engine
edge cases is exactly what the repo's smooth synthetic mixtures never
exercise: tenants that appear and vanish mid-run, flash crowds that
multiply one tenant's arrivals for a window, size-distribution step
changes that break the seasonal-naive forecast, and TTL storms that
tombstone half the resident set in one burst. :func:`apply_chaos` takes
any ``TenantOp`` stream (synthetic generator output or a parsed trace)
plus a list of events and returns the perturbed stream — so every
existing driver (``TenantArbiter``, the benches, ``KVSlabPool`` length
feeds) tortures unchanged.

Events fire at *base-stream op indices* (``at``), and the result
carries a ``marks`` timeline of where each event landed in the OUTPUT
stream — the torture bench hands those to
``SlabController.note_event`` / ``TenantArbiter.note_event`` so
forecast-miss refits (reactive refits chasing an event the forecaster
could not see) are measurable.

All perturbations are deterministic given ``seed``: redraws use one
seeded generator, and per-key remaps hash the key, so a get's
read-through refill size always matches the set it would restore.
"""
from __future__ import annotations

import dataclasses
import heapq
import zlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.distribution import (PAGE_SIZE, PaperWorkload,
                                     lognormal_params_from_moments)
from repro.memcached.traffic import TenantOp


@dataclasses.dataclass(frozen=True)
class TenantJoin:
    """A new tenant starts sending traffic at op ``at``: one set with
    probability ``rate`` per base op, sizes from ``workload``, each
    item deleted ``~lifetime`` base ops later (0 = no churn)."""

    at: int
    tenant: int
    workload: PaperWorkload
    rate: float = 0.5
    lifetime: int = 0

    @property
    def label(self) -> str:
        return f"join:t{self.tenant}"


@dataclasses.dataclass(frozen=True)
class TenantLeave:
    """Tenant ``tenant`` disconnects at op ``at``: its remaining base
    ops are dropped, and with ``flush`` its live keys are deleted in
    one tombstone burst (the cache-side shadow of a teardown)."""

    at: int
    tenant: int
    flush: bool = True

    @property
    def label(self) -> str:
        return f"leave:t{self.tenant}"


@dataclasses.dataclass(frozen=True)
class FlashCrowd:
    """For base ops in ``[at, at + duration)``, every set of ``tenant``
    is amplified ``boost``× with derived fresh keys; the crowd's keys
    are deleted when the window closes (the spike dissipates, leaving
    the hole-riddled pages behind)."""

    at: int
    duration: int
    tenant: int
    boost: int = 3

    @property
    def label(self) -> str:
        return f"flash:t{self.tenant}x{self.boost}"


@dataclasses.dataclass(frozen=True)
class SizeStep:
    """From op ``at`` on, item sizes step to a new distribution —
    ``factor`` rescales every size, or ``workload`` redraws each key's
    size from a new operating point (stable per key, so refills match).
    ``tenant=None`` hits every tenant. A step is the forecast-breaking
    event: seasonal-naive prediction replays the old period's sizes,
    which after the step are simply wrong."""

    at: int
    tenant: Optional[int] = None
    factor: Optional[float] = None
    workload: Optional[PaperWorkload] = None

    def __post_init__(self):
        if (self.factor is None) == (self.workload is None):
            raise ValueError("SizeStep needs exactly one of factor/workload")

    @property
    def label(self) -> str:
        who = "all" if self.tenant is None else f"t{self.tenant}"
        what = (f"x{self.factor}" if self.factor is not None
                else f"w{self.workload.table}")
        return f"sizestep:{who}{what}"


@dataclasses.dataclass(frozen=True)
class TTLStorm:
    """At op ``at``, a fraction ``frac`` of currently-live keys (of
    ``tenant``, or all) is deleted in one burst — the mass-expiry
    tombstone wave that punches free chunks through resident pages."""

    at: int
    frac: float = 0.5
    tenant: Optional[int] = None

    @property
    def label(self) -> str:
        who = "all" if self.tenant is None else f"t{self.tenant}"
        return f"ttlstorm:{who}@{self.frac}"


ChaosEvent = (TenantJoin, TenantLeave, FlashCrowd, SizeStep, TTLStorm)


@dataclasses.dataclass
class ChaosResult:
    """The perturbed stream plus the event timeline over it."""

    ops: List[TenantOp]
    marks: List[Tuple[int, str]]    # (output op index, event label)

    def __iter__(self):
        return iter(self.ops)

    def __len__(self):
        return len(self.ops)


def _stable_unit(key: str, salt: int) -> float:
    """Deterministic uniform in [0, 1) from a key (remap stability)."""
    return zlib.crc32(f"{salt}:{key}".encode()) / float(1 << 32)


def _redraw_size(key: str, workload: PaperWorkload, salt: int,
                 max_size: int) -> int:
    """A per-key size drawn from ``workload``'s lognormal via two key
    hashes and Box-Muller — stable for the key, so a read-through
    refill restores exactly what a set stored."""
    u1 = max(_stable_unit(key, salt), 1e-12)
    u2 = _stable_unit(key, salt + 1)
    z = np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)
    mu_log, sigma_log = lognormal_params_from_moments(
        np.asarray([workload.mu]), np.asarray([workload.sigma]))
    raw = float(np.exp(mu_log[0] + sigma_log[0] * z))
    return int(np.clip(np.rint(raw), 1, max_size))


def apply_chaos(ops: Sequence[TenantOp], events: Sequence[object], *,
                seed: int = 0, max_size: int = PAGE_SIZE) -> ChaosResult:
    """Replay ``ops`` through the event list, emitting the perturbed
    stream. Single pass; deterministic given ``seed``; events fire in
    ``at`` order (ties: list order)."""
    rng = np.random.default_rng(seed)
    for e in events:
        if not isinstance(e, ChaosEvent):
            raise TypeError(f"not a chaos event: {e!r}")
    events = sorted(events, key=lambda e: e.at)
    out: List[TenantOp] = []
    marks: List[Tuple[int, str]] = []
    live: Dict[str, int] = {}           # key -> tenant, live resident view
    gone: Set[int] = set()              # tenants that left
    joins: List[TenantJoin] = []        # active join generators
    join_ctr: Dict[int, int] = {}
    steps: List[SizeStep] = []          # active size steps, in fire order
    crowds: List[FlashCrowd] = []       # active flash-crowd windows
    # (due base index, seq, tenant, key): join-churn + crowd-dissipate
    scheduled: List[tuple] = []
    seq = 0
    ev_i = 0

    def emit(op: TenantOp) -> None:
        if op.op == "set":
            live[op.key] = op.tenant
        elif op.op == "delete":
            live.pop(op.key, None)
        out.append(op)

    def schedule(due: int, tenant: int, key: str) -> None:
        nonlocal seq
        heapq.heappush(scheduled, (due, seq, tenant, key))
        seq += 1

    def remap(op: TenantOp) -> TenantOp:
        """Apply active size steps to a set/get payload size."""
        size = op.size
        for st in steps:
            if st.tenant is not None and st.tenant != op.tenant:
                continue
            if st.factor is not None:
                size = int(np.clip(np.rint(size * st.factor), 1, max_size))
            else:
                size = _redraw_size(op.key, st.workload, st.at, max_size)
        return op if size == op.size else dataclasses.replace(op, size=size)

    n_base = len(ops)
    for i in range(n_base + 1):          # +1: drain events/schedules at end
        while scheduled and scheduled[0][0] <= i:
            _, _, d_tenant, d_key = heapq.heappop(scheduled)
            if d_key in live:
                emit(TenantOp(d_tenant, "delete", d_key, 0))
        while ev_i < len(events) and events[ev_i].at <= i:
            ev = events[ev_i]
            ev_i += 1
            marks.append((len(out), ev.label))
            if isinstance(ev, TenantJoin):
                joins.append(ev)
                join_ctr.setdefault(ev.tenant, 0)
            elif isinstance(ev, TenantLeave):
                gone.add(ev.tenant)
                joins = [j for j in joins if j.tenant != ev.tenant]
                if ev.flush:
                    for key in sorted(k for k, t in live.items()
                                      if t == ev.tenant):
                        emit(TenantOp(ev.tenant, "delete", key, 0))
            elif isinstance(ev, SizeStep):
                steps.append(ev)
            elif isinstance(ev, FlashCrowd):
                crowds.append(ev)
            elif isinstance(ev, TTLStorm):
                keys = sorted(k for k, t in live.items()
                              if ev.tenant is None or t == ev.tenant)
                n_kill = int(ev.frac * len(keys))
                for key in rng.permutation(keys)[:n_kill].tolist():
                    emit(TenantOp(live[key], "delete", key, 0))
        if i == n_base:
            break
        for j in joins:
            if rng.random() < j.rate:
                key = f"t{j.tenant}:c{join_ctr[j.tenant]}"
                join_ctr[j.tenant] += 1
                size = _redraw_size(key, j.workload, j.at, max_size)
                emit(remap(TenantOp(j.tenant, "set", key, size)))
                if j.lifetime:
                    due = i + int(rng.uniform(0.5, 1.5) * j.lifetime)
                    schedule(due, j.tenant, key)
        op = ops[i]
        if op.tenant in gone:
            continue
        if op.op in ("set", "get"):
            op = remap(op)
        emit(op)
        if op.op == "set":
            for c in crowds:
                if (c.tenant == op.tenant
                        and c.at <= i < c.at + c.duration):
                    for rep in range(max(0, c.boost - 1)):
                        clone = f"{op.key}#f{rep}"
                        emit(TenantOp(op.tenant, "set", clone, op.size))
                        schedule(c.at + c.duration, op.tenant, clone)
    return ChaosResult(ops=out, marks=marks)


def tenants_of(ops: Sequence[TenantOp],
               events: Sequence[object] = ()) -> List[int]:
    """Every tenant index the perturbed stream can mention — base
    stream tenants plus joiners — so a driver can register them all
    up front."""
    seen = {op.tenant for op in ops}
    seen.update(e.tenant for e in events
                if isinstance(e, TenantJoin))
    return sorted(seen)
