"""Cache-trace replay: published CSV schemas → ``TenantOp`` streams.

Every workload the repo has measured so far is synthetic; the paper's
premise is that slab schedules must survive *real* traffic. This module
is the adapter: it parses the published cache-trace CSV shape — the
Twitter production traces (SoCC'20, one row per request:
``timestamp, key, key size, value size, client id, operation, TTL``)
and the Meta/CacheLib kvcache shape (``op_time, key, key_size, op,
op_count, size, ttl``) — into the same
:class:`~repro.memcached.traffic.TenantOp` stream the arbiter and the
benchmarks already replay, with the ``client id`` column as the tenant
tag.

Because CI must run with **no external downloads**, the module is
symmetric: :func:`format_trace` renders any ``TenantOp`` stream back
into trace rows, and :func:`synthetic_trace_ops` builds realistic op
streams from the repo's own generators — so
``parse_trace(format_trace(ops)) == ops`` round-trips and the torture
bench exercises the full parse path on a trace it wrote itself.
Pointing :func:`parse_trace` at a real downloaded trace file is the
same one call.

:func:`downsample` thins a trace by *key* (all ops of a sampled key
survive together), so set/delete pairing and the re-reference structure
of the stream are preserved at any sampling rate — per-op sampling
would orphan deletes and destroy hit ratios.
"""
from __future__ import annotations

import dataclasses
import heapq
import os
import zlib
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Union

import numpy as np

from repro.core.distribution import PAGE_SIZE
from repro.memcached.traffic import (TenantOp, multitenant_phased_ops,
                                     zipfian_rereference_ops)

# Column roles a schema may assign. "-" ignores a column.
_ROLES = ("timestamp", "key", "key_size", "value_size", "client_id",
          "op", "ttl", "-")


@dataclasses.dataclass(frozen=True)
class TraceSchema:
    """One CSV trace dialect: which column holds which role, and which
    operation names mean set / get / delete (anything else is treated
    as a ``get`` — ``incr``/``cas``/``touch`` all read the key)."""

    columns: tuple                       # role name per CSV column
    set_ops: frozenset = frozenset(
        {"set", "add", "replace", "cas", "append", "prepend", "store"})
    get_ops: frozenset = frozenset({"get", "gets", "read"})
    delete_ops: frozenset = frozenset({"delete", "del", "remove"})
    size_includes_key: bool = True       # item size = key_size + value_size

    def __post_init__(self):
        bad = [c for c in self.columns if c not in _ROLES]
        if bad:
            raise ValueError(f"unknown column roles {bad}; valid: {_ROLES}")
        for role in ("key", "op"):
            if role not in self.columns:
                raise ValueError(f"schema must place a {role!r} column")


#: The Twitter production cache-trace shape (SoCC'20 open data set).
TWITTER_SCHEMA = TraceSchema(columns=(
    "timestamp", "key", "key_size", "value_size", "client_id", "op", "ttl"))

#: The Meta/CacheLib kvcache trace shape (op_count collapsed per row).
META_SCHEMA = TraceSchema(columns=(
    "timestamp", "key", "key_size", "op", "-", "value_size", "ttl"))


def _default_tenant_of() -> Callable[[str], int]:
    """Map client ids to dense tenant indices: a trailing integer in the
    id wins (``c17`` → 17 — what :func:`format_trace` emits, so round
    trips are exact); otherwise first-seen order."""
    seen: Dict[str, int] = {}

    def tenant_of(client: str) -> int:
        digits = ""
        for ch in reversed(client):
            if not ch.isdigit():
                break
            digits = ch + digits
        if digits:
            return int(digits)
        if client not in seen:
            seen[client] = len(seen)
        return seen[client]

    return tenant_of


def parse_trace(source: Union[str, Iterable[str]], *,
                schema: TraceSchema = TWITTER_SCHEMA,
                tenant_of: Optional[Callable[[str], int]] = None,
                max_tenants: int = 0,
                max_ops: Optional[int] = None,
                max_size: int = PAGE_SIZE,
                delimiter: str = ",") -> List[TenantOp]:
    """Parse one trace (a path or an iterable of CSV lines) into the
    ``TenantOp`` stream the arbiter replays.

    * ``set`` rows become set ops; a positive TTL column schedules the
      matching delete at ``timestamp + ttl`` (emitted in timestamp
      order, memcached lazy-expiry style: a later overwrite refreshes
      the TTL; items whose TTL outlives the trace are never deleted).
    * ``get`` rows carry the key's last-known stored size (the
      read-through refill size) — falling back to the row's own value
      size for keys first seen through a get.
    * item size is ``key_size + value_size`` when the schema says
      stored items carry their key (memcached does), clamped to
      ``[0, max_size]`` so one corrupt row cannot poison a replay.
    * ``max_tenants > 0`` folds the client-id space onto that many
      tenants (trace client ids number thousands; the arbiter wants a
      handful of tenant tags).

    Blank lines and ``#`` comments are skipped; short rows raise.
    """
    tenant_fn = tenant_of or _default_tenant_of()
    idx = {role: i for i, role in enumerate(schema.columns) if role != "-"}
    need = max(idx.values()) + 1
    ops: List[TenantOp] = []
    # (expiry_ts, seq, tenant, key, ttl_tag): lazy-expiry heap
    due: List[tuple] = []
    live_ttl: Dict[str, float] = {}      # key -> current expiry timestamp
    last_size: Dict[str, int] = {}       # key -> last stored size
    ts = 0.0
    lines = _iter_lines(source, delimiter)
    for seq, row in enumerate(lines):
        if len(row) < need:
            raise ValueError(
                f"trace row {seq} has {len(row)} columns, schema needs "
                f"{need}: {row!r}")
        if "timestamp" in idx:
            ts = float(row[idx["timestamp"]])
        while due and due[0][0] <= ts:
            _, _, d_tenant, d_key, d_expiry = heapq.heappop(due)
            if live_ttl.get(d_key) == d_expiry:     # not refreshed since
                del live_ttl[d_key]
                ops.append(TenantOp(d_tenant, "delete", d_key, 0))
                if max_ops is not None and len(ops) >= max_ops:
                    return ops
        key = row[idx["key"]]
        op = row[idx["op"]].strip().lower()
        tenant = tenant_fn(row[idx["client_id"]]) if "client_id" in idx else 0
        if max_tenants:
            tenant %= max_tenants
        size = _row_size(row, idx, schema, max_size)
        if op in schema.delete_ops:
            live_ttl.pop(key, None)
            ops.append(TenantOp(tenant, "delete", key, 0))
        elif op in schema.set_ops:
            last_size[key] = size
            ttl = float(row[idx["ttl"]]) if "ttl" in idx else 0.0
            if ttl > 0:
                expiry = ts + ttl
                live_ttl[key] = expiry
                heapq.heappush(due, (expiry, seq, tenant, key, expiry))
            else:
                live_ttl.pop(key, None)
            ops.append(TenantOp(tenant, "set", key, size))
        else:                            # get / gets / incr / cas / ...
            ops.append(TenantOp(tenant, "get", key,
                                last_size.get(key, size)))
        if max_ops is not None and len(ops) >= max_ops:
            return ops
    return ops


def _iter_lines(source: Union[str, Iterable[str]],
                delimiter: str) -> Iterator[List[str]]:
    if isinstance(source, str):
        with open(source) as f:
            yield from _iter_lines(f, delimiter)
        return
    for line in source:
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        yield line.split(delimiter)


def _row_size(row: List[str], idx: Dict[str, int], schema: TraceSchema,
              max_size: int) -> int:
    size = 0
    if "value_size" in idx:
        size += int(float(row[idx["value_size"]]))
    if schema.size_includes_key and "key_size" in idx:
        size += int(float(row[idx["key_size"]]))
    return max(0, min(size, max_size))


# -- rendering (the synthetic-trace writer CI replays) -----------------------

def format_trace(ops: Iterable[TenantOp], *,
                 schema: TraceSchema = TWITTER_SCHEMA,
                 delimiter: str = ",") -> Iterator[str]:
    """Render a ``TenantOp`` stream as trace rows in ``schema``'s
    dialect: timestamps are the op index, client ids are ``c<tenant>``
    (so the default parser maps them straight back), deletes are
    explicit rows (TTL 0 — the stream already carries its churn), and
    sizes ride the value-size column. ``parse_trace(format_trace(ops))``
    reproduces ``ops`` exactly."""
    for i, op in enumerate(ops):
        row = ["0"] * len(schema.columns)
        for j, role in enumerate(schema.columns):
            if role == "timestamp":
                row[j] = str(i)
            elif role == "key":
                row[j] = op.key
            elif role == "value_size":
                row[j] = str(op.size if op.op != "delete" else 0)
            elif role == "client_id":
                row[j] = f"c{op.tenant}"
            elif role == "op":
                row[j] = op.op
        yield delimiter.join(row)


def write_trace(path: str, ops: Iterable[TenantOp], *,
                schema: TraceSchema = TWITTER_SCHEMA) -> str:
    """Write ``ops`` as a trace file (atomically: temp + rename, so a
    killed writer can never leave a truncated trace for the next run
    to replay). Returns ``path``."""
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        for line in format_trace(ops, schema=schema):
            f.write(line + "\n")
    os.replace(tmp, path)
    return path


def synthetic_trace_ops(kind: str = "phased", *, n_ops: int = 10_000,
                        n_tenants: int = 3, seed: int = 0,
                        workloads=None) -> List[TenantOp]:
    """A realistic op stream from the repo's own generators, for trace
    round-trips without downloads: ``"phased"`` (out-of-phase tenant
    peaks + TTL churn) or ``"zipfian"`` (Zipf re-references with a
    mid-stream tail shift)."""
    from repro.core.distribution import PAPER_WORKLOADS
    workloads = (PAPER_WORKLOADS[:n_tenants] if workloads is None
                 else workloads)
    if kind == "phased":
        return multitenant_phased_ops(workloads, n_sets=n_ops,
                                      trough_mix=0.5, seed=seed)
    if kind == "zipfian":
        return zipfian_rereference_ops(workloads, n_ops=n_ops, seed=seed)
    raise ValueError(f"unknown synthetic trace kind {kind!r}")


# -- down-sampling -----------------------------------------------------------

def _key_sampler(keep: float, seed: int) -> Callable[[str], bool]:
    """The shared key-hash predicate behind :func:`downsample` and
    :func:`trace_requests`: salted crc32 below the keep threshold.
    Deterministic per (key, seed), so any consumer thinning the same
    trace keeps exactly the same keys."""
    if not 0.0 < keep <= 1.0:
        raise ValueError(f"keep must be in (0, 1], got {keep}")
    if keep == 1.0:
        return lambda key: True
    cut = int(keep * (1 << 32))
    salt = f"{seed}:".encode()
    return lambda key: zlib.crc32(salt + key.encode()) < cut


def downsample(ops: Iterable[TenantOp], keep: float, *,
               seed: int = 0) -> List[TenantOp]:
    """Thin a trace to ~``keep`` of its keys, deterministically.

    Sampling is by *key hash* (salted with ``seed``): every op of a
    sampled key survives, every op of a dropped key vanishes — so
    set/delete pairs stay paired and a key's re-reference pattern is
    intact, which per-op sampling would destroy. ``keep=1`` is the
    identity."""
    kept = _key_sampler(keep, seed)
    return [op for op in ops if kept(op.key)]


# -- trace -> open-loop serving workload --------------------------------------

def trace_requests(ops: Iterable[TenantOp], *,
                   ops_per_tick: float = 64.0,
                   bytes_per_token: int = 64,
                   min_prompt: int = 1,
                   output_max: int = 16,
                   keep: float = 1.0, seed: int = 0,
                   max_requests: Optional[int] = None) -> List:
    """Convert a tenant-tagged ``TenantOp`` trace into the open-loop
    serving workload ``OfflineHarness``/``ContinuousBatcher`` replay —
    the bridge from the memcached-side fixtures to the serving side.

    Every ``set`` op becomes one :class:`~repro.serving.scheduler.Request`
    (gets and deletes carry no stored payload to prefill — they are
    skipped, like reads hitting a serving cache):

    * ``arrival`` — the op's index in the FULL trace divided by
      ``ops_per_tick``: trace order is the arrival clock, and because
      the index is taken before thinning, a downsampled replay keeps
      every surviving request at its original arrival time;
    * ``prompt_len`` — the stored size in tokens
      (``ceil(size / bytes_per_token)``, at least ``min_prompt``);
    * ``output_len`` — ``1 + crc32(key) % output_max``: deterministic
      per key, so the same key re-set later decodes the same length in
      any run that sampled it;
    * ``tenant`` — ``"t<tenant>"`` (register these on the pool — the
      harness auto-registers unknown tags on submit).

    ``keep < 1`` thins by the same salted key hash as
    :func:`downsample`, so `serving_bench --trace` at any sampling rate
    replays exactly the keys the memcached-side replay kept.
    """
    from repro.serving.scheduler import Request
    kept = _key_sampler(keep, seed)
    out: List = []
    for i, op in enumerate(ops):
        if op.op != "set" or not kept(op.key):
            continue
        prompt = max(min_prompt,
                     -(-int(op.size) // int(bytes_per_token)))
        output = 1 + zlib.crc32(op.key.encode()) % int(output_max)
        out.append(Request(rid=len(out), prompt_len=prompt,
                           output_len=output,
                           arrival=i / float(ops_per_tick),
                           tenant=f"t{op.tenant}"))
        if max_requests is not None and len(out) >= max_requests:
            break
    return out


def trace_histogram(ops: Iterable[TenantOp]):
    """``(support, freqs)`` of the stored sizes in a trace — what an
    offline fitter (or the adversary's oracle) consumes."""
    sizes = np.asarray([op.size for op in ops if op.op == "set"],
                       dtype=np.int64)
    if sizes.size == 0:
        return (np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64))
    support, freqs = np.unique(sizes, return_counts=True)
    return support.astype(np.int64), freqs.astype(np.int64)
