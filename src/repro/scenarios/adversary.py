"""Adversarial drift search: find the schedule change that hurts most.

The benchmarks show the adaptive controller winning on drift patterns
*we* chose. The honest question is the opposite one: what drift pattern
would an adversary choose? This module searches over **drift
schedules** — piecewise-stationary size streams, each segment drawn
from one of the paper's operating points — for the one that maximizes
the controller's *regret* against the hindsight-optimal static schedule
(:func:`repro.core.dp_optimal.dp_optimal` fit on the whole stream).

Regret is where the controller's hysteresis shows its cost: a stream
that flips between far-apart operating points just slower than the
cooldown, or parks most of its mass where the decayed sketch has
already forgotten it, makes every refit arrive late and every late
refit pay twice. Positive regret = the static oracle would have beaten
adaptation on that stream.

The evaluation is **allocator-free and exactly deterministic**: the
stream drives a real :class:`~repro.core.controller.SlabController`
(drift gate, cooldown, hysteresis — the full pipeline), but candidate
frontiers are scored with exact integer :func:`waste_exact` instead of
the f32 kernel, so a found schedule replays bit-identically on any
platform. That is what makes :func:`save_fixture` /
:func:`replay_fixture` usable as a **pinned regression test**: the
worst schedule ever found is checked in under ``fixtures/`` and CI
replays it, asserting the recorded regret to the byte — any controller
change that silently worsens (or quietly "fixes") worst-case behaviour
trips the pin and must update the fixture deliberately.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.controller import ControllerConfig, ScoreRequest, SlabController
from repro.core.distribution import (PAGE_SIZE, PAPER_WORKLOADS,
                                     lognormal_params_from_moments)
from repro.core.dp_optimal import dp_optimal
from repro.core.slab_policy import schedule_with_default_tail
from repro.core.waste import waste_exact

#: Checked-in adversarial fixtures live next to this module.
FIXTURE_DIR = os.path.join(os.path.dirname(__file__), "fixtures")
WORST_FIXTURE = os.path.join(FIXTURE_DIR, "worst_drift.json")


@dataclasses.dataclass(frozen=True)
class DriftSchedule:
    """One piecewise-stationary size stream: ``segments`` is a tuple of
    ``(workload_index, fraction)`` pairs — each segment draws its share
    of the ``n_items`` stream from that :data:`PAPER_WORKLOADS`
    operating point's lognormal. Fractions are normalized; ``seed``
    fixes every draw."""

    segments: Tuple[Tuple[int, float], ...]
    n_items: int = 8000
    seed: int = 0

    def __post_init__(self):
        if not self.segments:
            raise ValueError("a drift schedule needs at least one segment")
        for widx, frac in self.segments:
            if not 0 <= widx < len(PAPER_WORKLOADS):
                raise ValueError(f"workload index {widx} out of range")
            if frac <= 0:
                raise ValueError(f"segment fraction must be > 0, got {frac}")

    def sizes(self) -> np.ndarray:
        """Materialize the stream (int64 sizes in ``[1, PAGE_SIZE]``)."""
        rng = np.random.default_rng(self.seed)
        fracs = np.asarray([f for _, f in self.segments], dtype=np.float64)
        bounds = np.rint(np.cumsum(fracs / fracs.sum())
                         * self.n_items).astype(np.int64)
        bounds[-1] = self.n_items
        out: List[np.ndarray] = []
        start = 0
        for (widx, _), end in zip(self.segments, bounds.tolist()):
            n = max(0, end - start)
            start = end
            w = PAPER_WORKLOADS[widx]
            mu_log, sigma_log = lognormal_params_from_moments(
                np.asarray([w.mu]), np.asarray([w.sigma]))
            draws = rng.lognormal(mean=mu_log[0], sigma=sigma_log[0], size=n)
            out.append(np.clip(np.rint(draws), 1, PAGE_SIZE)
                       .astype(np.int64))
        return (np.concatenate(out) if out
                else np.zeros(0, dtype=np.int64))

    def to_json(self) -> Dict:
        return {"segments": [[int(w), float(f)] for w, f in self.segments],
                "n_items": int(self.n_items), "seed": int(self.seed)}

    @classmethod
    def from_json(cls, obj: Dict) -> "DriftSchedule":
        return cls(segments=tuple((int(w), float(f))
                                  for w, f in obj["segments"]),
                   n_items=int(obj["n_items"]), seed=int(obj["seed"]))


@dataclasses.dataclass
class EvalResult:
    """One schedule's regret accounting (exact int bytes)."""

    schedule: DriftSchedule
    regret: int              # adaptive_waste - oracle_waste
    adaptive_waste: int      # controller's schedule, scored window by window
    oracle_waste: int        # hindsight static dp schedule, same windows
    oracle_chunks: np.ndarray
    n_refits: int
    n_windows: int


def _hist(sizes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    support, freqs = np.unique(sizes, return_counts=True)
    return support.astype(np.int64), freqs.astype(np.int64)


def _check_exact(controller: SlabController):
    """Run one due drift check with exact-integer candidate scoring —
    the same gate pipeline ``maybe_refit`` runs, minus the f32 kernel,
    so results are bit-stable across platforms."""
    req = controller.begin_check(None)
    if not isinstance(req, ScoreRequest):
        return req
    scores = np.asarray([waste_exact(row, req.support, req.freqs,
                                     page_size=req.page_size)
                         for row in req.rows], dtype=np.float64)
    return controller.finish_check(req, scores)


def evaluate(schedule: DriftSchedule, *, k: int = 6,
             check_every: int = 1000,
             config: Optional[ControllerConfig] = None) -> EvalResult:
    """Regret of the adaptive controller on ``schedule``'s stream.

    The stream is split into windows of ``check_every`` items. Window 0
    is warmup: the controller starts from the dp-optimal fit on it (the
    most charitable initialization) and adopts it as the drift
    reference. Every later window is **served before it is observed**:
    its waste is charged against the schedule the controller believed
    in at the window's start, then the window feeds the sketch and the
    controller may refit. Both sides deploy with the covering default
    tail (:func:`schedule_with_default_tail`) exactly as the arbiter
    deploys refits — so regret measures hole waste under late/wrong
    adaptation, not the trivial catastrophe of an uncovered size. The
    oracle is one static :func:`~repro.core.dp_optimal.dp_optimal`
    schedule fit with hindsight on exactly the scored windows — an
    opponent the controller can only beat by adapting well.
    """
    sizes = schedule.sizes()
    if sizes.size < 2 * check_every:
        raise ValueError(
            f"schedule too short: {sizes.size} items < 2 windows of "
            f"{check_every}")
    cfg = config or ControllerConfig(
        k=k, check_every=check_every,
        min_items_between_refits=check_every, page_size=PAGE_SIZE)
    warm = sizes[:check_every]
    controller = SlabController(dp_optimal(*_hist(warm), k).chunks,
                                config=cfg)
    controller.observe_many(warm)
    _check_exact(controller)                 # adopts warmup as reference
    windows = [sizes[at:at + check_every]
               for at in range(check_every, sizes.size, check_every)]
    scored = np.concatenate(windows)
    oracle = dp_optimal(*_hist(scored), k)
    oracle_deployed = schedule_with_default_tail(oracle.chunks,
                                                 page_size=cfg.page_size)
    adaptive_waste = 0
    oracle_waste = 0
    for window in windows:
        support, freqs = _hist(window)
        deployed = schedule_with_default_tail(controller.chunks,
                                              page_size=cfg.page_size)
        adaptive_waste += waste_exact(deployed, support, freqs,
                                      page_size=cfg.page_size)
        oracle_waste += waste_exact(oracle_deployed, support, freqs,
                                    page_size=cfg.page_size)
        controller.observe_many(window)
        _check_exact(controller)
    return EvalResult(schedule=schedule,
                      regret=int(adaptive_waste - oracle_waste),
                      adaptive_waste=int(adaptive_waste),
                      oracle_waste=int(oracle_waste),
                      oracle_chunks=oracle.chunks,
                      n_refits=controller.n_refits,
                      n_windows=len(windows))


# -- the search --------------------------------------------------------------

def _random_schedule(rng: np.random.Generator, *, n_items: int,
                     max_segments: int) -> DriftSchedule:
    n_seg = int(rng.integers(2, max_segments + 1))
    widx = rng.integers(0, len(PAPER_WORKLOADS), size=n_seg)
    fracs = rng.dirichlet(np.ones(n_seg)) * 0.9 + 0.1 / n_seg
    return DriftSchedule(
        segments=tuple((int(w), round(float(f), 4))
                       for w, f in zip(widx, fracs)),
        n_items=n_items, seed=int(rng.integers(1 << 16)))


def _mutate(sched: DriftSchedule, rng: np.random.Generator, *,
            max_segments: int) -> DriftSchedule:
    segs = [list(s) for s in sched.segments]
    move = rng.integers(0, 4)
    if move == 0:                        # retarget one segment's workload
        i = int(rng.integers(0, len(segs)))
        segs[i][0] = int(rng.integers(0, len(PAPER_WORKLOADS)))
    elif move == 1:                      # jitter the split points
        for s in segs:
            s[1] = max(0.02, s[1] * float(rng.uniform(0.6, 1.6)))
    elif move == 2 and len(segs) < max_segments:    # split a segment
        i = int(rng.integers(0, len(segs)))
        w, f = segs[i]
        segs[i] = [w, f / 2]
        segs.insert(i + 1, [int(rng.integers(0, len(PAPER_WORKLOADS))),
                            f / 2])
    elif move == 3 and len(segs) > 2:    # merge two neighbours
        i = int(rng.integers(0, len(segs) - 1))
        segs[i][1] += segs[i + 1][1]
        del segs[i + 1]
    seed = (sched.seed if rng.random() < 0.7
            else int(rng.integers(1 << 16)))
    return DriftSchedule(
        segments=tuple((w, round(f, 4)) for w, f in segs),
        n_items=sched.n_items, seed=seed)


@dataclasses.dataclass
class SearchResult:
    best: EvalResult
    n_evals: int
    history: List[int]       # best regret after each evaluation


def search(n_evals: int = 40, *, seed: int = 0, n_items: int = 8000,
           k: int = 6, check_every: int = 1000, max_segments: int = 5,
           restart_every: int = 12) -> SearchResult:
    """Bounded hill-climb over drift schedules, maximizing regret.

    Random start, one mutation per step, greedy accept, random restart
    every ``restart_every`` non-improving steps (the landscape is full
    of local optima where the controller happens to adapt cleanly).
    Deterministic given ``seed``; cost is ``n_evals`` exact
    evaluations, no allocator in the loop."""
    rng = np.random.default_rng(seed)
    current = _random_schedule(rng, n_items=n_items,
                               max_segments=max_segments)
    cur_eval = evaluate(current, k=k, check_every=check_every)
    best = cur_eval
    history = [best.regret]
    stale = 0
    for _ in range(n_evals - 1):
        if stale >= restart_every:
            cand = _random_schedule(rng, n_items=n_items,
                                    max_segments=max_segments)
            stale = 0
        else:
            cand = _mutate(cur_eval.schedule, rng,
                           max_segments=max_segments)
        try:
            cand_eval = evaluate(cand, k=k, check_every=check_every)
        except ValueError:               # degenerate mutation (too short)
            history.append(best.regret)
            continue
        if cand_eval.regret > cur_eval.regret:
            cur_eval = cand_eval
            stale = 0
        else:
            stale += 1
        if cand_eval.regret > best.regret:
            best = cand_eval
        history.append(best.regret)
    return SearchResult(best=best, n_evals=len(history), history=history)


# -- fixtures: persist the worst schedule found ------------------------------

def save_fixture(path: str, result: EvalResult, *,
                 k: int = 6, check_every: int = 1000,
                 found_by: Optional[Dict] = None) -> str:
    """Persist an evaluated schedule as a replayable fixture (atomic
    write). The recorded waste numbers are exact ints — replay asserts
    them to the byte."""
    payload = {
        "schedule": result.schedule.to_json(),
        "k": int(k),
        "check_every": int(check_every),
        "regret": int(result.regret),
        "adaptive_waste": int(result.adaptive_waste),
        "oracle_waste": int(result.oracle_waste),
        "oracle_chunks": [int(c) for c in result.oracle_chunks],
        "n_refits": int(result.n_refits),
        "n_windows": int(result.n_windows),
        "found_by": found_by or {},
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path


def load_fixture(path: str = WORST_FIXTURE) -> Dict:
    with open(path) as f:
        fixture = json.load(f)
    fixture["schedule"] = DriftSchedule.from_json(fixture["schedule"])
    return fixture


def replay_fixture(path: str = WORST_FIXTURE, *,
                   strict: bool = True) -> EvalResult:
    """Re-evaluate a persisted fixture. With ``strict`` (the pinned
    regression mode), the replayed regret/waste must equal the recorded
    bytes exactly — a mismatch means controller behaviour changed."""
    fixture = load_fixture(path)
    result = evaluate(fixture["schedule"], k=fixture["k"],
                      check_every=fixture["check_every"])
    if strict:
        for field in ("regret", "adaptive_waste", "oracle_waste"):
            got = getattr(result, field)
            if got != fixture[field]:
                raise AssertionError(
                    f"fixture {os.path.basename(path)} drifted: {field} "
                    f"replayed {got} != recorded {fixture[field]} — "
                    f"controller behaviour changed; re-run the adversary "
                    f"search and update the fixture deliberately")
    return result
