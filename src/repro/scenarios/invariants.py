"""Invariant checks the torture suite enforces under every scenario.

Each checker returns a list of violation strings (empty = healthy), so
the bench can count violations across a whole matrix and CI can fail on
any non-empty result while still printing *what* broke. The invariants
are the ones past bugs actually violated (see CHANGES.md: the
``_prune`` mass leak, negative waste charges) plus the conservation and
dispatch-accounting contracts the arbiter and device sketch advertise.
"""
from __future__ import annotations

from typing import List


def check_conservation(pool) -> List[str]:
    """``ResourcePool`` conservation: free + Σ owned == total, and no
    tenant below its floor or above its quota bookkeeping."""
    out: List[str] = []
    if not pool.conserved:
        owned = sum(t.owned for t in pool.tenants().values())
        out.append(
            f"pool not conserved: free={pool.free_units} + owned={owned} "
            f"!= total={pool.total_units}")
    for name, t in pool.tenants().items():
        if t.owned < 0:
            out.append(f"tenant {name!r} owns {t.owned} < 0 units")
        if t.quota is not None and t.quota < 0:
            out.append(f"tenant {name!r} quota {t.quota} < 0")
    return out


def check_sketch_mass(sketch, *, rel_tol: float = 1e-6) -> List[str]:
    """Decayed-sketch mass accounting: the running ``_total`` must equal
    the sum of the (synced) per-bin weights — the exact invariant the
    PR-4 ``_prune`` leak violated — and the decayed effective count can
    never exceed the lifetime observation count."""
    out: List[str] = []
    total = float(sketch.effective_count)
    if hasattr(sketch, "_synced_weights"):          # host dict sketch
        recomputed = float(sum(sketch._synced_weights().values()))
    else:                                           # device dense sketch
        _, weights = sketch.snapshot_weights()
        recomputed = float(weights.sum())
    scale = max(abs(total), abs(recomputed), 1.0)
    if abs(total - recomputed) > rel_tol * scale:
        out.append(
            f"sketch mass leak: effective_count={total} != "
            f"sum(weights)={recomputed}")
    if total > sketch.n_observed * (1.0 + rel_tol) + rel_tol:
        out.append(
            f"decayed mass {total} exceeds lifetime n_observed="
            f"{sketch.n_observed}")
    return out


def check_dispatch_accounting(sketch, *, max_windows: int = None
                              ) -> List[str]:
    """Device-observe launch accounting: a host sketch never dispatches;
    a fused device sketch dispatches at most once per flushed window
    (pass ``max_windows`` = number of cadence windows driven)."""
    out: List[str] = []
    n = getattr(sketch, "n_dispatches", 0)
    if not hasattr(sketch, "weights_device"):       # host path
        if n != 0:
            out.append(f"host sketch reports {n} device dispatches")
    elif max_windows is not None and n > max_windows:
        out.append(
            f"fused sketch dispatched {n} times for {max_windows} "
            f"windows (contract: <= 1 per window)")
    return out


def check_kv_pool(kv_pool) -> List[str]:
    """``KVSlabPool`` token accounting: allocated + retained + free can
    never exceed the pool (carving may strand sub-min-class remainders,
    so the sum may fall short — never over), and nothing is negative."""
    out: List[str] = []
    s = kv_pool.stats()
    for field in ("allocated_tokens", "retained_tokens", "free_tokens"):
        v = getattr(s, field)
        if v < 0:
            out.append(f"kv pool {field}={v} < 0")
    covered = s.allocated_tokens + s.retained_tokens + s.free_tokens
    if covered > s.pool_tokens:
        out.append(
            f"kv pool over-committed: allocated={s.allocated_tokens} + "
            f"retained={s.retained_tokens} + free={s.free_tokens} > "
            f"pool={s.pool_tokens}")
    return out


def check_fleet(arbiter) -> List[str]:
    """Fleet-consistency contract for ``TenantArbiter(fleet=True)``
    (no-op on a legacy arbiter): the stacked arrays, the pool records
    they masquerade as, the allocators' own page counts, and the row
    bookkeeping must all tell one story, and freed rows must hold zero
    mass everywhere — including their stacked device-sketch rows,
    summed in one launch however many rows are free."""
    f = getattr(arbiter, "fleet", None)
    if f is None:
        return []
    out: List[str] = []
    import numpy as np
    pool = arbiter.pool
    # stacked totals: active rows' owned + pool free == pool total
    owned_sum = int(f.owned[f.active].sum())
    if owned_sum + pool.free_units != pool.total_units:
        out.append(
            f"fleet not conserved: sum(owned[active])={owned_sum} + "
            f"free={pool.free_units} != total={pool.total_units}")
    if f.n_active != len(arbiter.tenants):
        out.append(f"fleet has {f.n_active} active rows for "
                   f"{len(arbiter.tenants)} tenants")
    for name, t in arbiter.tenants.items():
        row = f.row_of.get(name)
        if row is None or f.name_of[row] != name or not f.active[row]:
            out.append(f"tenant {name!r} row bookkeeping broken "
                       f"(row={row})")
            continue
        if int(f.owned[row]) != pool.owned(name):
            out.append(
                f"tenant {name!r}: fleet owned={int(f.owned[row])} != "
                f"pool view {pool.owned(name)}")
        q = pool.quota(name)
        if int(f.quota[row]) != (-1 if q is None else q):
            out.append(
                f"tenant {name!r}: fleet quota={int(f.quota[row])} != "
                f"pool view {q}")
        pages = getattr(t.allocator, "pages_allocated", None)
        if pages is not None and pages != int(f.owned[row]):
            out.append(
                f"tenant {name!r}: allocator holds {pages} pages, "
                f"fleet row says {int(f.owned[row])}")
        if int(f.check_every[row]) != t.controller.config.check_every:
            out.append(f"tenant {name!r}: cadence mirror check_every="
                       f"{int(f.check_every[row])} != config "
                       f"{t.controller.config.check_every}")
        if int(f.since_check[row]) != t.controller._since_check:
            out.append(f"tenant {name!r}: cadence mirror since_check="
                       f"{int(f.since_check[row])} != controller "
                       f"{t.controller._since_check}")
    free = ~f.active
    for field in ("owned", "floor", "n_denied", "pressure",
                  "window_demand", "since_check", "check_every",
                  "ring_len"):
        v = getattr(f, field)[free]
        if v.size and np.abs(v).sum() != 0:
            out.append(f"free fleet rows carry nonzero {field}")
    if free.any():
        if not (f.quota[free] == -1).all():
            out.append("free fleet rows carry a quota")
        if f.ring and np.abs(f.demand_ring[free]).sum() != 0:
            out.append("free fleet rows carry demand-ring mass")
        if f.sketch is not None:
            mass = float(abs(f.sketch[np.nonzero(free)[0]]).sum())
            if mass != 0.0:
                out.append(f"free fleet rows carry sketch mass {mass}")
    return out


def check_hot_path_counters(obj) -> List[str]:
    """The ``@hot_path(counters=...)`` contract at runtime: every
    counter a hot-path annotation on ``obj``'s class declares must
    exist on the instance as a non-negative number. This is the dynamic
    half of slablint's CC001 — the registry
    (``repro.analysis.registry.HOT_PATHS``) is the shared source of
    truth, so an annotation drifting from the real accounting fails
    here and in the lint job alike."""
    from repro.analysis.registry import HOT_PATHS

    out: List[str] = []
    cls = type(obj)
    for entry in HOT_PATHS.values():
        fn = entry["fn"]
        if getattr(cls, fn.__name__, None) is not fn:
            continue
        for counter in entry["counters"]:
            v = getattr(obj, counter, None)
            if v is None:
                out.append(
                    f"{cls.__name__}.{fn.__name__} declares hot-path "
                    f"counter {counter!r} the instance lacks")
            elif v < 0:
                out.append(
                    f"hot-path counter {cls.__name__}.{counter} is "
                    f"negative: {v}")
    return out


def check_all(*, pool=None, sketches=(), kv_pool=None,
              max_windows: int = None, arbiter=None) -> List[str]:
    """Run every applicable checker; one flat violation list."""
    out: List[str] = []
    if pool is not None:
        out.extend(check_conservation(pool))
    for sketch in sketches:
        out.extend(check_sketch_mass(sketch))
        out.extend(check_dispatch_accounting(sketch,
                                             max_windows=max_windows))
        out.extend(check_hot_path_counters(sketch))
    if kv_pool is not None:
        out.extend(check_kv_pool(kv_pool))
    if arbiter is not None:
        out.extend(check_fleet(arbiter))
        out.extend(check_hot_path_counters(arbiter))
    return out
