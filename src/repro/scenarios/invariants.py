"""Invariant checks the torture suite enforces under every scenario.

Each checker returns a list of violation strings (empty = healthy), so
the bench can count violations across a whole matrix and CI can fail on
any non-empty result while still printing *what* broke. The invariants
are the ones past bugs actually violated (see CHANGES.md: the
``_prune`` mass leak, negative waste charges) plus the conservation and
dispatch-accounting contracts the arbiter and device sketch advertise.
"""
from __future__ import annotations

from typing import List


def check_conservation(pool) -> List[str]:
    """``ResourcePool`` conservation: free + Σ owned == total, and no
    tenant below its floor or above its quota bookkeeping."""
    out: List[str] = []
    if not pool.conserved:
        owned = sum(t.owned for t in pool.tenants().values())
        out.append(
            f"pool not conserved: free={pool.free_units} + owned={owned} "
            f"!= total={pool.total_units}")
    for name, t in pool.tenants().items():
        if t.owned < 0:
            out.append(f"tenant {name!r} owns {t.owned} < 0 units")
        if t.quota is not None and t.quota < 0:
            out.append(f"tenant {name!r} quota {t.quota} < 0")
    return out


def check_sketch_mass(sketch, *, rel_tol: float = 1e-6) -> List[str]:
    """Decayed-sketch mass accounting: the running ``_total`` must equal
    the sum of the (synced) per-bin weights — the exact invariant the
    PR-4 ``_prune`` leak violated — and the decayed effective count can
    never exceed the lifetime observation count."""
    out: List[str] = []
    total = float(sketch.effective_count)
    if hasattr(sketch, "_synced_weights"):          # host dict sketch
        recomputed = float(sum(sketch._synced_weights().values()))
    else:                                           # device dense sketch
        _, weights = sketch.snapshot_weights()
        recomputed = float(weights.sum())
    scale = max(abs(total), abs(recomputed), 1.0)
    if abs(total - recomputed) > rel_tol * scale:
        out.append(
            f"sketch mass leak: effective_count={total} != "
            f"sum(weights)={recomputed}")
    if total > sketch.n_observed * (1.0 + rel_tol) + rel_tol:
        out.append(
            f"decayed mass {total} exceeds lifetime n_observed="
            f"{sketch.n_observed}")
    return out


def check_dispatch_accounting(sketch, *, max_windows: int = None
                              ) -> List[str]:
    """Device-observe launch accounting: a host sketch never dispatches;
    a fused device sketch dispatches at most once per flushed window
    (pass ``max_windows`` = number of cadence windows driven)."""
    out: List[str] = []
    n = getattr(sketch, "n_dispatches", 0)
    if not hasattr(sketch, "weights_device"):       # host path
        if n != 0:
            out.append(f"host sketch reports {n} device dispatches")
    elif max_windows is not None and n > max_windows:
        out.append(
            f"fused sketch dispatched {n} times for {max_windows} "
            f"windows (contract: <= 1 per window)")
    return out


def check_kv_pool(kv_pool) -> List[str]:
    """``KVSlabPool`` token accounting: allocated + retained + free can
    never exceed the pool (carving may strand sub-min-class remainders,
    so the sum may fall short — never over), and nothing is negative."""
    out: List[str] = []
    s = kv_pool.stats()
    for field in ("allocated_tokens", "retained_tokens", "free_tokens"):
        v = getattr(s, field)
        if v < 0:
            out.append(f"kv pool {field}={v} < 0")
    covered = s.allocated_tokens + s.retained_tokens + s.free_tokens
    if covered > s.pool_tokens:
        out.append(
            f"kv pool over-committed: allocated={s.allocated_tokens} + "
            f"retained={s.retained_tokens} + free={s.free_tokens} > "
            f"pool={s.pool_tokens}")
    return out


def check_all(*, pool=None, sketches=(), kv_pool=None,
              max_windows: int = None) -> List[str]:
    """Run every applicable checker; one flat violation list."""
    out: List[str] = []
    if pool is not None:
        out.extend(check_conservation(pool))
    for sketch in sketches:
        out.extend(check_sketch_mass(sketch))
        out.extend(check_dispatch_accounting(sketch,
                                             max_windows=max_windows))
    if kv_pool is not None:
        out.extend(check_kv_pool(kv_pool))
    return out
