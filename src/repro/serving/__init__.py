"""Serving: slab-pool KV allocation (the paper's technique), decode steps,
continuous batching, and the offline-scale batched harness."""
from repro.serving.kv_slab_pool import (ALIGN, Allocation, KVSlabPool,
                                        KVTenantQuotaView, PoolStats,
                                        TenantTokens, default_pow2_classes,
                                        quantize_lengths,
                                        token_quota_arbiter)
from repro.serving.offline_harness import HarnessResult, OfflineHarness
from repro.serving.scheduler import (ContinuousBatcher, Request, SimResult,
                                     lognormal_request_workload,
                                     queue_delay_stats)
from repro.serving.serve_step import generate, make_serve_fns, sample_logits

__all__ = ["ALIGN", "Allocation", "KVSlabPool", "KVTenantQuotaView",
           "PoolStats", "TenantTokens",
           "default_pow2_classes", "quantize_lengths", "token_quota_arbiter",
           "ContinuousBatcher", "OfflineHarness", "HarnessResult",
           "Request", "SimResult", "lognormal_request_workload",
           "queue_delay_stats",
           "generate", "make_serve_fns", "sample_logits"]
