"""Continuous-batching scheduler over the slab KV pool.

A discrete-event simulator faithful to serving dynamics (admission,
decode, completion, chunk reallocation on class overflow) that measures
what the paper's technique buys at the serving layer: HBM internal
fragmentation of the KV pool under default vs learned chunk classes,
plus admission failures (a fragmented pool admits fewer requests).

The tick is phase-structured the way the device harness executes it
(admit → decode bookkeeping → batched within-chunk growth → completion
→ observe/arbitrate/refit): :meth:`ContinuousBatcher.step` batches all
within-chunk decode growth into ONE ``KVSlabPool.extend_bulk`` call per
tick, mirroring the one-dispatch-per-tick decode step of
``offline_harness``. The pre-refactor per-request loop is kept verbatim
as :meth:`step_legacy`, the bit-parity oracle — every counter,
observation, admission and rejection must match it exactly
(tests/test_serving_harness.py runs the differential).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_slab_pool import ALIGN, KVSlabPool, quantize_lengths


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    output_len: int
    decoded: int = 0
    arrival: float = 0.0      # open-loop arrival time, in ticks
    tenant: str = "default"   # serving stream tag (trace client id)

    @property
    def kv_len(self) -> int:
        return self.prompt_len + self.decoded


def queue_delay_stats(delays) -> Tuple[float, float, float]:
    """(mean, p50, p99) of per-request queue delays (admit − arrival),
    in ticks; zeros when nothing was admitted."""
    if len(delays) == 0:
        return 0.0, 0.0, 0.0
    d = np.asarray(delays, dtype=np.float64)
    return (float(d.mean()), float(np.percentile(d, 50)),
            float(np.percentile(d, 99)))


@dataclasses.dataclass
class SimResult:
    steps: int
    completed: int
    rejected: int
    realloc_copies: int          # class-overflow chunk moves
    realloc_tokens: int          # tokens copied in those moves
    mean_waste_fraction: float   # time-averaged pool fragmentation
    peak_active: int
    mean_active: float
    n_refits: int = 0            # schedule changes applied during the run
    # per-request queue delay (admit tick − arrival), the latency the
    # aggregate step counts used to hide: an admission-starved stream
    # shows up here long before it shows up in `rejected`
    queue_delay_mean: float = 0.0
    queue_delay_p50: float = 0.0
    queue_delay_p99: float = 0.0


class ContinuousBatcher:
    """Admit-from-queue / decode-all / free-on-finish loop.

    Refit modes:
      * ``refit_every=N`` — legacy cadence: unconditionally re-learn the
        classes every N steps (through the pool's shared controller);
      * ``adaptive=True`` — drive the controller's full drift-detection /
        hysteresis / cost-model pipeline each step; refits happen only
        when the controller approves one. Decisions land in
        ``self.refit_decisions``.

    Open-loop arrivals: a request with ``arrival > 0`` is not
    admissible before tick ``ceil(arrival)``; admission stays FIFO (a
    not-yet-arrived head blocks the queue — order is part of the
    decision contract the harness must reproduce). Each admission
    records ``t - arrival`` into ``queue_delays``; :meth:`run` folds
    them into the ``SimResult`` p50/p99.

    ``legacy_loop=True`` routes :meth:`step` through
    :meth:`step_legacy`, the pre-refactor per-request loop kept as the
    bit-parity oracle for the phase-structured tick.

    Multi-tenant serving: several batchers (one per serving stream) may
    share ONE ``KVSlabPool``; each registers under its ``tenant`` name
    so the pool keeps per-stream token accounting (and optionally a
    quota). Request ids must be unique across all batchers of a shared
    pool. The pool's learned classes come from the merged traffic of
    all streams — the arbitration analogue of the memcached side.
    """

    def __init__(self, pool: KVSlabPool, *, max_batch: int = 64,
                 refit_every: Optional[int] = None,
                 adaptive: bool = False,
                 tenant: str = "default",
                 quota_tokens: Optional[int] = None,
                 arbiter=None,
                 legacy_loop: bool = False):
        self.pool = pool
        self.tenant = tenant
        pool.register_tenant(tenant, quota_tokens=quota_tokens)
        self.max_batch = max_batch
        self.refit_every = refit_every
        self.adaptive = adaptive
        # Token-quota arbitration (repro.serving.token_quota_arbiter):
        # the batcher reports its op count each step so the arbiter's
        # cadence advances with real serving work, not wall clock.
        self.arbiter = arbiter
        self.legacy_loop = legacy_loop
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.realloc_copies = 0
        self.realloc_tokens = 0
        self.completed = 0
        self.rejected = 0
        self.n_refits = 0
        self.refit_decisions: List = []
        self.queue_delays: List[float] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _try_admit(self, observed: List[int], t: int = 0) -> None:
        while (self.queue and self.queue[0].arrival <= t
                and len(self.active) < self.max_batch):
            req = self.queue[0]
            # observed BEFORE the attempt, success or not: the per-alloc
            # path feeds the sketch before its failure exits too, and
            # uncoverable lengths are exactly what a refit must learn
            observed.append(req.kv_len)
            # reserve capacity for the whole expected context
            a = self.pool.alloc(req.rid, req.kv_len, tenant=self.tenant)
            if a is None:
                self.rejected += 1
                self.queue.popleft()
                continue
            self.queue.popleft()
            self.active[req.rid] = req
            self.queue_delays.append(t - req.arrival)

    def step(self, t: int) -> None:
        if self.legacy_loop:
            self.step_legacy(t)
        else:
            self._step_tick(t)

    def _step_tick(self, t: int) -> None:
        """Phase-structured tick: within-chunk decode growth for the
        whole batch lands in ONE ``extend_bulk`` call — the host-side
        shape of the harness's one-dispatch decode tick. Decisions,
        counters and observation order are bit-identical to
        :meth:`step_legacy` (within-chunk growth commutes with the
        allocator's class/quota/freelist decisions; the overflow path
        runs inline, in request order, exactly as before)."""
        observed: List[int] = []
        self._try_admit(observed, t)
        done: List[int] = []
        grown: List[Tuple[int, int]] = []
        for rid, req in self.active.items():
            req.decoded += 1
            old = self.pool.allocation(rid)
            if req.kv_len <= old.chunk:
                grown.append((rid, req.kv_len))
            else:
                new = self.pool.extend(rid, req.kv_len)
                if new is None:      # pool full mid-flight: drop request
                    observed.append(req.kv_len)  # the attempt still counts
                    done.append(rid)
                    self.rejected += 1
                    continue
                if new.start != old.start:   # class overflow -> chunk copy
                    self.realloc_copies += 1
                    self.realloc_tokens += old.length
                    observed.append(req.kv_len)
            if req.decoded >= req.output_len:
                done.append(rid)
                self.completed += 1
        if grown:
            self.pool.extend_bulk(grown)
        self._finish_tick(t, done, observed)

    def step_legacy(self, t: int) -> None:
        """The pre-refactor per-request loop, preserved verbatim as the
        bit-parity oracle for :meth:`_step_tick` (one ``extend`` call
        per active request per tick)."""
        # In batch-observe mode (the pool's device-sketch path) alloc()
        # does not observe per item; the sizes of this step's allocations
        # are collected and handed to the controller as ONE batch below.
        # With the fused observe window (ControllerConfig.fused_observe)
        # these per-step batches just accumulate on host — the whole
        # cadence window folds into the device sketch in a single
        # dispatch at the adaptive drift check.
        observed: List[int] = []
        self._try_admit(observed, t)
        done: List[int] = []
        for rid, req in self.active.items():
            req.decoded += 1
            old = self.pool.allocation(rid)
            new = self.pool.extend(rid, req.kv_len)
            if new is None:          # pool full mid-flight: drop request
                observed.append(req.kv_len)   # the attempt still counts
                done.append(rid)
                self.rejected += 1
                continue
            if new.start != old.start:   # class overflow -> chunk copy
                self.realloc_copies += 1
                self.realloc_tokens += old.length
                observed.append(req.kv_len)
            if req.decoded >= req.output_len:
                done.append(rid)
                self.completed += 1
        self._finish_tick(t, done, observed)

    def _finish_tick(self, t: int, done: List[int],
                     observed: List[int]) -> None:
        """Completion frees, batched observation, arbitration cadence,
        refit policy — shared tail of both tick flavors."""
        for rid in done:
            if rid in self.pool._live:
                self.pool.free(rid)
            del self.active[rid]
        if self.pool.batch_observe and observed:
            self.pool.observe_lengths(np.asarray(observed, dtype=np.int64))
        if self.arbiter is not None:
            # one tick per step per stream: admissions + decodes both
            # already fed the pool's counters this step
            self.arbiter.tick(1)
        if self.adaptive:
            decision = self.pool.maybe_refit()
            if decision is not None:
                self.refit_decisions.append(decision)
                if decision.approved:
                    self.n_refits += 1
        elif self.refit_every and t > 0 and t % self.refit_every == 0:
            before = list(self.pool.chunk_classes)
            self.pool.refit()
            if list(self.pool.chunk_classes) != before:
                self.n_refits += 1

    def run(self, workload: List[Request], steps: int) -> SimResult:
        for r in workload:
            self.submit(r)
        waste_samples = []
        active_samples = []
        for t in range(steps):
            self.step(t)
            st = self.pool.stats()
            if st.active_requests:
                waste_samples.append(st.waste_fraction)
            active_samples.append(st.active_requests)
            if not self.active and not self.queue:
                break
        qd_mean, qd_p50, qd_p99 = queue_delay_stats(self.queue_delays)
        return SimResult(
            steps=t + 1,
            completed=self.completed,
            rejected=self.rejected,
            realloc_copies=self.realloc_copies,
            realloc_tokens=self.realloc_tokens,
            mean_waste_fraction=(float(np.mean(waste_samples))
                                 if waste_samples else 0.0),
            peak_active=int(np.max(active_samples)),
            mean_active=float(np.mean(active_samples)),
            n_refits=self.n_refits,
            queue_delay_mean=qd_mean,
            queue_delay_p50=qd_p50,
            queue_delay_p99=qd_p99)


def lognormal_request_workload(rng: np.random.Generator, n: int, *,
                               prompt_mean: float = 2048.0,
                               prompt_std: float = 700.0,
                               output_mean: float = 256.0,
                               output_std: float = 120.0,
                               arrival_rate: Optional[float] = None
                               ) -> List[Request]:
    """Request lengths log-normal — the serving analogue of the paper's
    traffic model (and what production traces look like).
    ``arrival_rate`` (requests per tick) adds open-loop Poisson
    arrivals: exponential inter-arrival gaps, cumulative; ``None``
    keeps the closed-loop default (everything arrives at 0)."""
    from repro.core.distribution import lognormal_params_from_moments
    pm, ps = lognormal_params_from_moments(prompt_mean, prompt_std)
    om, os_ = lognormal_params_from_moments(output_mean, output_std)
    prompts = np.clip(rng.lognormal(pm, ps, n), 16, None).astype(int)
    outputs = np.clip(rng.lognormal(om, os_, n), 1, None).astype(int)
    arrivals = np.zeros(n)
    if arrival_rate is not None:
        arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n))
    return [Request(rid=i, prompt_len=int(p), output_len=int(o),
                    arrival=float(a))
            for i, (p, o, a) in enumerate(zip(prompts, outputs, arrivals))]
