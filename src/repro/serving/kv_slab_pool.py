"""KV-cache slab pool with LEARNED chunk classes — the paper's technique
as a serving-runtime feature.

The mapping (DESIGN.md §2): a serving runtime allocates KV-cache space
per request; request context lengths are the "item sizes", the KV pool
is the memory, and rounding a request up to its allocation is internal
fragmentation of HBM. vLLM-style paging buys ~zero fragmentation with
per-page indirection; on TPU, contiguous DMA is strongly preferred, so
this pool allocates each request ONE contiguous chunk whose size comes
from a slab-class schedule *learned from the observed request-length
distribution* (SlabPolicy / the paper's algorithm). The learned schedule
bounds the fragmentation that contiguity would otherwise cost; the
contiguous layout is what `kernels/slab_attention.py` streams through
VMEM with zero indirection.

Implementation notes:
  * allocation granularity is ALIGN tokens (kernel tile = 128), so the
    learner fits on the align-quantized length histogram;
  * per-class free lists + bump pointer, O(1) alloc/free — the memcached
    discipline, in tokens instead of bytes;
  * observation and refitting are delegated to the shared
    ``repro.core.SlabController`` (the paper's "analyse the pattern of
    sizes previously entered" loop): every ``alloc`` feeds the
    controller's decayed sketch, ``refit()`` fits unconditionally through
    it, and ``maybe_refit()`` runs its full drift/hysteresis/cost
    decision pipeline — the same path the memcached simulator uses;
  * finished sequences can be *retained* (``finish(rid, retain=True)``)
    instead of freed — their token chunks stay resident as a prefix
    cache, ranked by the same pluggable
    :class:`~repro.memcached.eviction.EvictionPolicy` contract the
    memcached layer uses (``eviction_policy=``). Under pool pressure,
    ``alloc`` reclaims the retained chunk whose sequence is least
    likely to be re-referenced (``reuse``d) — Memshare's rank-based
    victim selection, with KV token pages as the page unit;
  * per-stream token quotas can be ARBITER-MANAGED: ``token_quota_arbiter``
    wraps each stream in a :class:`KVTenantQuotaView` over a
    ``ResourcePool(kind="kv_tokens")`` so the shared
    :class:`~repro.core.arbiter.TenantArbiter` moves quota between
    streams as their load phases, pricing donors by the retained-
    sequence reclaimable value (see docs/architecture.md, "The second
    resource kind").
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.registry import hot_path
from repro.core import ControllerConfig, SlabController, SlabPolicy
from repro.core.controller import RefitDecision
from repro.memcached.eviction import ColdestLRU, EvictionPolicy

ALIGN = 128  # tokens; matches the Pallas kernel's BLOCK_T


def quantize_lengths(lengths: np.ndarray, align: int = ALIGN) -> np.ndarray:
    """Round lengths up to the allocation grid (the learner's item size)."""
    lengths = np.asarray(lengths, dtype=np.int64)
    return ((lengths + align - 1) // align) * align


@dataclasses.dataclass
class PoolStats:
    active_requests: int
    pool_tokens: int
    allocated_tokens: int      # sum of chunk sizes of live allocations
    used_tokens: int           # sum of true KV lengths
    free_tokens: int
    n_failed: int
    n_retained: int = 0            # finished sequences kept as prefix cache
    retained_tokens: int = 0       # chunk tokens held by retained sequences
    n_retained_reused: int = 0     # retained sequences re-activated
    n_retained_evicted: int = 0    # retained chunks reclaimed under pressure

    @property
    def waste_tokens(self) -> int:
        return self.allocated_tokens - self.used_tokens

    @property
    def utilization(self) -> float:
        return self.used_tokens / max(self.allocated_tokens, 1)

    @property
    def waste_fraction(self) -> float:
        return self.waste_tokens / max(self.allocated_tokens, 1)


@dataclasses.dataclass
class Allocation:
    request_id: int
    start: int          # pool token offset (ALIGN-multiple)
    chunk: int          # slab class size (tokens)
    length: int         # true KV length
    tenant: str = "default"   # serving stream this allocation belongs to


class _RetainedClass:
    """Slab-class view over the retained (finished-sequence) chunks of
    one size, duck-typed for the ``EvictionPolicy`` contract:
    ``lru`` maps request key -> chunk tokens, least recently
    retained/touched first."""

    __slots__ = ("chunk_size", "lru")

    def __init__(self, chunk_size: int):
        self.chunk_size = chunk_size
        self.lru: OrderedDict[str, int] = OrderedDict()


@dataclasses.dataclass
class TenantTokens:
    """Per-tenant accounting inside a shared :class:`KVSlabPool`."""

    quota_tokens: Optional[int] = None   # cap on allocated tokens (None: ∞)
    allocated_tokens: int = 0            # chunk tokens of live allocations
    used_tokens: int = 0                 # true KV tokens of live allocations
    active_requests: int = 0
    n_failed: int = 0                    # allocs refused (pool or quota)
    # retained-chunk (prefix-cache) churn, split the way the memcached
    # layer splits pressure vs migration evictions: pressure reclaims
    # are the arbiter's demand signal, arbiter-driven reclaims must
    # never pollute it
    n_retained_evicted: int = 0          # pressure reclaims (alloc path)
    retained_evicted_tokens: int = 0     # their chunk tokens
    n_quota_reclaims: int = 0            # arbiter-driven reclaims
    quota_reclaimed_tokens: int = 0      # their chunk tokens
    # requests the arbiter's admission gate turned away BEFORE they
    # reached alloc (see TenantArbiter.admission) — kept apart from
    # n_failed so the allocator's own failure ledger stays honest, but
    # folded into the quota view's pressure signal
    n_admission_denied: int = 0


class KVSlabPool:
    """Contiguous KV pool with slab-class allocation.

    One token space, per-class freelists + bump pointer, chunk classes
    learned online through the shared ``SlabController``. Several
    serving streams may share the pool as *tenants*
    (:meth:`register_tenant` / ``alloc(..., tenant=)``): token
    accounting and failures are tracked per stream, an optional
    ``quota_tokens`` caps any one stream's share of HBM, and the
    learned classes come from the merged traffic of all streams.
    """

    def __init__(self, pool_tokens: int, chunk_classes, *,
                 align: int = ALIGN,
                 controller_config: Optional[ControllerConfig] = None,
                 eviction_policy: Optional[EvictionPolicy] = None,
                 device_observe: bool = False,
                 batch_observe: Optional[bool] = None):
        self.pool_tokens = int(pool_tokens)
        self.align = align
        self.set_classes(chunk_classes)
        self._bump = 0
        self._free: Dict[int, List[int]] = defaultdict(list)
        self._live: Dict[int, Allocation] = {}
        self.n_failed = 0
        self._tenants: Dict[str, TenantTokens] = {}
        self.register_tenant("default")
        # finished-sequence prefix cache, ranked by the eviction policy
        self.eviction_policy: EvictionPolicy = eviction_policy or ColdestLRU()
        self._retained: Dict[int, Allocation] = {}
        self._retained_cls: Dict[int, _RetainedClass] = {}
        self.n_retained_reused = 0
        self.n_retained_evicted = 0
        if controller_config is None:
            # half_life=inf: undecayed sketch == the legacy all-history
            # histogram, so `refit()` behaves exactly as it used to.
            controller_config = ControllerConfig(
                page_size=1 << 22, min_chunk=align, align=align,
                half_life=float("inf"))
        if device_observe and not controller_config.device:
            # Device-resident observe: the sketch lives in HBM on a
            # bucket grid of ALIGN tokens. The grid must cover every
            # ALLOCATABLE length — refits may grow the top class well
            # past the initial schedule, and a length beyond the pool's
            # own capacity can never be stored anyway, so pool_tokens is
            # the natural ceiling. Huge pools widen the grid (keeping
            # coverage, coarsening resolution) rather than silently
            # clamping allocatable lengths into the top bucket.
            width = align
            buckets = max(64, -(-self.pool_tokens // width))
            while buckets > (1 << 17):
                width *= 2
                buckets = -(-self.pool_tokens // width)
            controller_config = dataclasses.replace(
                controller_config, device=True,
                device_bucket_width=width, device_buckets=int(buckets))
        # Batched observation (the device path's natural feeding mode):
        # per-alloc observes are skipped and the serving loop hands whole
        # batches of lengths to observe_lengths() instead.
        self.batch_observe = (bool(controller_config.device)
                              if batch_observe is None else batch_observe)
        self.controller = SlabController(self.chunk_classes,
                                         config=controller_config)

    # -- class management ----------------------------------------------------
    def set_classes(self, chunk_classes) -> None:
        cc = sorted(int(c) for c in chunk_classes)
        if any(c % self.align for c in cc):
            raise ValueError(f"classes must be multiples of {self.align}")
        self.chunk_classes = cc
        if getattr(self, "_free", None):
            self._rehome_stranded_free()

    def _carve_range(self, size: int, start: int) -> None:
        """Split a free token range into current class sizes, largest
        first (a sub-min-class remainder can still strand — bounded by
        one min-chunk per range)."""
        remaining, pos = size, start
        for c in sorted(self.chunk_classes, reverse=True):
            while remaining >= c:
                self._free[c].append(pos)
                pos += c
                remaining -= c

    def _rehome_stranded_free(self) -> None:
        """Re-carve freelist ranges of vanished classes into current
        class sizes so pool tokens don't leak across refits."""
        valid = set(self.chunk_classes)
        stranded = [(size, start)
                    for size, starts in self._free.items()
                    if size not in valid for start in starts]
        for size in [s for s in list(self._free) if s not in valid]:
            del self._free[size]
        for size, start in stranded:
            self._carve_range(size, start)

    def class_for(self, length: int) -> Optional[int]:
        for c in self.chunk_classes:            # K is small
            if c >= length:
                return c
        return None

    # -- tenancy ---------------------------------------------------------------
    def register_tenant(self, name: str, *,
                        quota_tokens: Optional[int] = None) -> TenantTokens:
        """Register one serving stream as a tenant of this pool
        (idempotent; a later call may set/adjust the token quota). All
        tenants share the pool's token space, freelists, and learned
        classes — the controller's sketch sees the merged traffic —
        while allocated/used/failure accounting is kept per tenant and
        ``quota_tokens`` caps any one stream's share of HBM."""
        rec = self._tenants.get(name)
        if rec is None:
            rec = TenantTokens(quota_tokens=quota_tokens)
            self._tenants[name] = rec
        elif quota_tokens is not None:
            rec.quota_tokens = quota_tokens
        return rec

    # -- alloc/free ------------------------------------------------------------
    @hot_path
    def alloc(self, request_id: int, length: int, *,
              tenant: str = "default") -> Optional[Allocation]:
        rec = self._tenants.get(tenant)
        if rec is None:
            # strict: a typo'd tenant name must not silently bypass a
            # registered tenant's quota (PagePool.acquire is equally
            # strict) — and checked first, so the error path leaves no
            # phantom observation in the controller's sketch
            raise KeyError(f"tenant {tenant!r} not registered "
                           "(call register_tenant first)")
        if request_id in self._retained:    # id reuse while a stale
            self._drop_retained(request_id)   # retained chunk exists
        al = self.align
        if not self.batch_observe:
            self.controller.observe((int(length) + al - 1) // al * al)
        chunk = self.class_for(length)
        if chunk is None:
            self.n_failed += 1
            rec.n_failed += 1
            return None
        if (rec.quota_tokens is not None
                and rec.allocated_tokens + chunk > rec.quota_tokens):
            self.n_failed += 1
            rec.n_failed += 1
            return None
        if self._free[chunk]:
            start = self._free[chunk].pop()
        elif self._bump + chunk <= self.pool_tokens:
            start = self._bump
            self._bump += chunk
        else:
            start = self._reclaim_retained(chunk)
            if start is None:
                self.n_failed += 1
                rec.n_failed += 1
                return None
        a = Allocation(request_id, start, chunk, length, tenant)
        self._live[request_id] = a
        rec.allocated_tokens += chunk
        rec.used_tokens += length
        rec.active_requests += 1
        return a

    def extend(self, request_id: int, new_length: int
               ) -> Optional[Allocation]:
        """Grow a request's KV (decode). Within-chunk growth is free; a
        class overflow reallocates into the next class (copy cost is the
        caller's — it shows up in the scheduler's accounting)."""
        a = self._live[request_id]
        if new_length <= a.chunk:
            self._tenants[a.tenant].used_tokens += new_length - a.length
            a.length = new_length
            return a
        self.free(request_id)
        return self.alloc(request_id, new_length, tenant=a.tenant)

    def extend_bulk(self, updates: List[Tuple[int, int]]) -> None:
        """Batched within-chunk decode growth: ``updates`` is
        ``[(request_id, new_length), ...]`` for one tick's worth of
        sequences whose new length still fits their current chunk — the
        host-side analogue of the harness's one-dispatch decode tick
        (no per-request calls, one tenant-accounting pass). Every entry
        MUST fit its allocation's chunk; class overflow must go through
        :meth:`extend`, which reallocates (the caller separates the two
        cases — it needs to know about the chunk copy anyway)."""
        per_tenant: Dict[str, int] = {}
        for rid, new_length in updates:
            a = self._live[rid]
            if new_length > a.chunk:
                raise ValueError(
                    f"extend_bulk: request {rid} new length {new_length} "
                    f"overflows its chunk {a.chunk}; use extend()")
            per_tenant[a.tenant] = (per_tenant.get(a.tenant, 0)
                                    + new_length - a.length)
            a.length = new_length
        for tenant, delta in per_tenant.items():
            self._tenants[tenant].used_tokens += delta

    def free(self, request_id: int) -> None:
        a = self._live.pop(request_id)
        rec = self._tenants[a.tenant]
        rec.allocated_tokens -= a.chunk
        rec.used_tokens -= a.length
        rec.active_requests -= 1
        if a.chunk in self.chunk_classes:
            self._free[a.chunk].append(a.start)
        else:   # class vanished in a refit while this request was live
            self._carve_range(a.chunk, a.start)

    def allocation(self, request_id: int) -> Allocation:
        return self._live[request_id]

    # -- finished-sequence prefix cache (policy-ranked token pages) ----------
    def _drop_retained(self, request_id: int) -> None:
        """Discard a retained entry, returning its token range to the
        freelist (id collision: a new allocation or retention reuses
        the request id while the old retained chunk still exists)."""
        a = self._retained.pop(request_id)
        holder = self._retained_cls[a.chunk]
        del holder.lru[str(request_id)]
        self.eviction_policy.on_remove(holder, str(request_id))
        if a.chunk in self.chunk_classes:
            self._free[a.chunk].append(a.start)
        else:
            self._carve_range(a.chunk, a.start)

    def finish(self, request_id: int, *, retain: bool = True) -> bool:
        """Finish a sequence. ``retain=True`` keeps its KV chunk
        resident as a prefix-cache entry — it leaves the tenant's live
        accounting but stays out of the freelist, evictable under pool
        pressure by the eviction policy's rank. ``retain=False`` frees
        immediately. Returns whether the chunk was retained."""
        if not retain:
            self.free(request_id)
            return False
        if request_id in self._retained:    # stale entry under the same
            self._drop_retained(request_id)   # id: recycle, don't leak
        a = self._live.pop(request_id)
        rec = self._tenants[a.tenant]
        rec.allocated_tokens -= a.chunk
        rec.used_tokens -= a.length
        rec.active_requests -= 1
        self._retained[request_id] = a
        holder = self._retained_cls.get(a.chunk)
        if holder is None:
            holder = self._retained_cls[a.chunk] = _RetainedClass(a.chunk)
        holder.lru[str(request_id)] = a.chunk
        self.eviction_policy.on_insert(holder, str(request_id), a.chunk)
        return True

    def touch_retained(self, request_id: int) -> bool:
        """Mark a retained sequence re-referenced (a prefix-hit probe)
        without re-activating it; False when it is not retained."""
        a = self._retained.get(request_id)
        if a is None:
            return False
        holder = self._retained_cls[a.chunk]
        holder.lru.move_to_end(str(request_id))
        self.eviction_policy.on_access(holder, str(request_id))
        return True

    def reuse(self, request_id: int, *,
              tenant: str = "default") -> Optional[Allocation]:
        """Re-activate a retained sequence (prefix-cache hit): its chunk
        moves back to live accounting under ``tenant``. Returns ``None``
        when the chunk was already evicted, or when the tenant's quota
        has no room (both count as failures) — the caller re-allocates
        and recomputes the prefix."""
        rec = self._tenants.get(tenant)
        if rec is None:
            raise KeyError(f"tenant {tenant!r} not registered "
                           "(call register_tenant first)")
        a = self._retained.get(request_id)
        if a is None:
            return None
        if (rec.quota_tokens is not None
                and rec.allocated_tokens + a.chunk > rec.quota_tokens):
            self.n_failed += 1
            rec.n_failed += 1
            return None
        del self._retained[request_id]
        holder = self._retained_cls[a.chunk]
        del holder.lru[str(request_id)]
        self.eviction_policy.on_remove(holder, str(request_id))
        a.tenant = tenant
        self._live[request_id] = a
        rec.allocated_tokens += a.chunk
        rec.used_tokens += a.length
        rec.active_requests += 1
        self.n_retained_reused += 1
        return a

    def _reclaim_retained(self, chunk: int) -> Optional[int]:
        """Evict the retained sequence least likely to be reused whose
        chunk can hold ``chunk`` tokens (Memshare's rank-based victim
        selection on token pages); returns the start of a range of
        ``chunk`` tokens, or ``None`` when nothing evictable fits. A
        larger victim's remainder is carved back into the freelist."""
        pol = self.eviction_policy
        best = None                     # (weight, holder, key)
        for holder in self._retained_cls.values():
            if holder.chunk_size < chunk or not holder.lru:
                continue
            key = pol.select_victim(holder)
            w = pol.rereference_weight(holder, key)
            if (best is None or w < best[0]
                    or (w == best[0]
                        and holder.chunk_size < best[1].chunk_size)):
                best = (w, holder, key)
        if best is None:
            return None
        _, holder, key = best
        a = self._retained.pop(int(key))
        del holder.lru[key]
        pol.on_remove(holder, key)
        self.n_retained_evicted += 1
        vrec = self._tenants.get(a.tenant)
        if vrec is not None:    # pressure signal: whose prefix cache paid
            vrec.n_retained_evicted += 1
            vrec.retained_evicted_tokens += a.chunk
        if a.chunk > chunk:
            self._carve_range(a.chunk - chunk, a.start + chunk)
        return a.start

    # -- arbiter-facing retained-value surface (token-quota arbitration) -----
    def _retained_ranked(self, tenant: str) -> List[Tuple[float, int, int]]:
        """This tenant's retained chunks as ``(rereference_weight,
        request_id, chunk_tokens)``, cheapest (least likely re-used)
        first — the reclaimable-value signal the quota arbiter prices
        donors with."""
        pol = self.eviction_policy
        out = []
        for rid, a in self._retained.items():
            if a.tenant != tenant:
                continue
            holder = self._retained_cls[a.chunk]
            out.append((pol.rereference_weight(holder, str(rid)), rid,
                        a.chunk))
        out.sort(key=lambda t: (t[0], t[2]))
        return out

    def tenant_release_cost_tokens(self, tenant: str, tokens: int) -> float:
        """Predicted cost (in tokens, re-reference-weighted) of taking
        ``tokens`` of quota away from ``tenant`` right now. Unused
        quota headroom (quota minus live minus retained) goes first and
        is free — nobody is using it; then retained chunks cover the
        release at their policy-priced value (a dead prefix cache
        donates nearly free); only a remaining shortfall has to come
        out of tokens the stream is actively using, charged at full
        rate — the wholesale price of making a live stream fail
        allocations."""
        rec = self._tenants[tenant]
        covered = 0
        if rec.quota_tokens is not None:
            retained = sum(a.chunk for a in self._retained.values()
                           if a.tenant == tenant)
            covered = max(0, rec.quota_tokens - rec.allocated_tokens
                          - retained)
        cost = 0.0
        for w, _rid, chunk in self._retained_ranked(tenant):
            if covered >= tokens:
                break
            cost += w * chunk
            covered += chunk
        if covered < tokens:
            cost += float(tokens - covered)
        return cost

    def reclaim_tenant_retained(self, tenant: str, tokens: int
                                ) -> Tuple[int, int]:
        """Evict ``tenant``'s least-valuable retained chunks until
        ``tokens`` chunk tokens are freed (or its prefix cache is
        empty); the freed ranges re-enter the freelist. The quota
        arbiter's execute step — counted as quota reclaims, NOT as
        pressure evictions. Returns ``(n_evicted, tokens_freed)``."""
        rec = self._tenants[tenant]
        n, freed = 0, 0
        for _w, rid, chunk in self._retained_ranked(tenant):
            if freed >= tokens:
                break
            self._drop_retained(rid)
            n += 1
            freed += chunk
        rec.n_quota_reclaims += n
        rec.quota_reclaimed_tokens += freed
        return n, freed

    # -- learning -------------------------------------------------------------
    @hot_path
    def observe_lengths(self, lengths) -> None:
        """Feed one batch of request KV lengths into the controller's
        sketch (the ``batch_observe`` feeding mode). ``lengths`` may be
        a host array or a device array straight out of a serve step.

        On the device path the RAW lengths are handed over untouched:
        the sketch's bucket grid is a multiple of ALIGN, and
        ``ceil(ceil(s/a)*a / (m*a)) == ceil(s / (m*a))`` — bucketing
        raw lengths lands in exactly the bucket the ALIGN-quantized
        length would, so quantization, bucketing, and the decayed
        update all happen inside the controller's fused observe window
        (one dispatch per cadence, nothing computed per batch on host).
        """
        cfg = self.controller.config
        if cfg.device and cfg.device_bucket_width % self.align == 0:
            self.controller.observe_many(lengths)
            return
        if not hasattr(lengths, "astype"):   # plain python list/tuple
            lengths = np.asarray(lengths)
        al = self.align
        self.controller.observe_many((lengths + (al - 1)) // al * al)

    def refit(self, k: Optional[int] = None, *, method: str = "dp",
              policy: Optional[SlabPolicy] = None) -> np.ndarray:
        """Re-learn chunk classes from observed lengths (paper's loop),
        unconditionally, through the shared controller.

        Only safe when the pool is empty or during a maintenance window
        (live allocations keep their old chunks; new allocations use the
        new schedule — memcached's own constraint when slab_sizes change
        requires a restart, we allow hot refit for new chunks only).
        """
        if self.controller.n_observed == 0:
            return np.asarray(self.chunk_classes)
        new = self.controller.refit_now(k or len(self.chunk_classes),
                                        method=method, policy=policy)
        self.set_classes(new)
        return np.asarray(self.chunk_classes)

    def maybe_refit(self) -> Optional[RefitDecision]:
        """One step of the controller's drift/hysteresis/cost pipeline;
        applies the new classes when a refit is approved. Live
        allocations keep their chunks (hot refit), so no migration cost
        is charged; freelist ranges of vanished classes are re-carved
        into the new class sizes by ``set_classes``. Chunks still held
        by live requests re-enter the freelist at their old size on
        ``free`` and are re-carved at the next class change."""
        decision = self.controller.maybe_refit()
        if decision is not None and decision.approved:
            self.set_classes(decision.chunks)
            self.controller.set_chunks(self.chunk_classes)
        return decision

    # -- measurement ------------------------------------------------------------
    def stats(self) -> PoolStats:
        allocated = sum(a.chunk for a in self._live.values())
        used = sum(a.length for a in self._live.values())
        free_listed = sum(c * len(v) for c, v in self._free.items())
        return PoolStats(
            active_requests=len(self._live),
            pool_tokens=self.pool_tokens,
            allocated_tokens=allocated,
            used_tokens=used,
            free_tokens=self.pool_tokens - self._bump + free_listed,
            n_failed=self.n_failed,
            n_retained=len(self._retained),
            retained_tokens=sum(a.chunk for a in self._retained.values()),
            n_retained_reused=self.n_retained_reused,
            n_retained_evicted=self.n_retained_evicted)

    def stats_by_tenant(self) -> Dict[str, TenantTokens]:
        """Live per-tenant accounting (see :class:`TenantTokens`)."""
        return dict(self._tenants)

    def kernel_args(self, request_ids) -> Tuple[np.ndarray, np.ndarray]:
        """(starts, lens) int32 arrays for slab_decode_attention."""
        starts = np.asarray([self._live[r].start for r in request_ids],
                            dtype=np.int32)
        lens = np.asarray([self._live[r].length for r in request_ids],
                          dtype=np.int32)
        return starts, lens

    @property
    def max_chunk_tokens(self) -> int:
        return max(self.chunk_classes)


class KVTenantQuotaView:
    """One serving stream of a :class:`KVSlabPool`, duck-typed as the
    allocator a :class:`~repro.core.arbiter.TenantArbiter` expects —
    the adapter that makes KV **token quotas** the arbiter's second
    resource kind (``ResourcePool(kind="kv_tokens")``, one unit =
    ``unit_size`` tokens of quota).

    The mapping, column for column against the memcached tenant:

    * pressure — ``n_page_denials`` → the stream's failed allocations
      (quota or pool exhaustion), ``evicted_bytes`` → tokens of ITS
      retained prefix chunks reclaimed under pool pressure;
    * donor cost — ``page_release_cost_bytes`` → the policy-priced
      reclaimable value of one unit of its retained chunks
      (``KVSlabPool.tenant_release_cost_tokens``), shortfall charged
      wholesale;
    * execute — ``release_page`` → evict its least-valuable retained
      chunks for one unit (``reclaim_tenant_retained``) and return the
      unit to the shared pool; ``apply_quota`` pushes the moved quota
      back into ``KVSlabPool.register_tenant(quota_tokens=...)``, so
      the pool's own admission check enforces what the arbiter decided;
    * ownership — ``sync_owned`` re-measures the stream's real token
      usage (live + retained) each arbitration round, because KV
      traffic does not broker every alloc through the ResourcePool.

    Traffic never routes through ``arbiter.set``; the serving loop
    drives the cadence with ``arbiter.tick`` (see ``ContinuousBatcher``).
    """

    def __init__(self, kv: "KVSlabPool", tenant: str, pool):
        if tenant not in kv._tenants:
            raise KeyError(f"tenant {tenant!r} not registered "
                           "(call register_tenant first)")
        self.kv = kv
        self.tenant = tenant
        self.page_pool = pool

    @property
    def _rec(self) -> TenantTokens:
        return self.kv._tenants[self.tenant]

    @property
    def unit(self) -> int:
        return self.page_pool.unit_size

    @property
    def chunk_sizes(self) -> np.ndarray:
        return np.asarray(self.kv.chunk_classes, dtype=np.int64)

    # -- pressure signal -----------------------------------------------------
    @property
    def evicted_bytes(self) -> int:
        return self._rec.retained_evicted_tokens

    @property
    def n_page_denials(self) -> int:
        # admission-gate denials count as pressure too: a stream turned
        # away at the door is starving exactly like one failing allocs
        return self._rec.n_failed + self._rec.n_admission_denied

    def note_admission_denial(self) -> None:
        """Record one arbiter admission-gate denial against this stream
        (the harness's tick-granular admission seam — see
        ``TenantArbiter.admission``)."""
        self._rec.n_admission_denied += 1

    def current_demand_bytes(self) -> float:
        """Live chunk tokens — the demand series the forecaster tracks
        (a stream heading into its peak grows this before it starves)."""
        return float(self._rec.allocated_tokens)

    # -- ownership sync ------------------------------------------------------
    def retained_tokens(self) -> int:
        return sum(a.chunk for a in self.kv._retained.values()
                   if a.tenant == self.tenant)

    def sync_owned(self) -> None:
        self.page_pool.set_owned(
            self.tenant,
            (self._rec.allocated_tokens + self.retained_tokens())
            // self.unit)

    # -- donate --------------------------------------------------------------
    def page_release_cost_bytes(self) -> float:
        return self.kv.tenant_release_cost_tokens(self.tenant, self.unit)

    def release_page(self) -> Tuple[int, int]:
        n, freed = self.kv.reclaim_tenant_retained(self.tenant, self.unit)
        if self.page_pool.owned(self.tenant) > 0:
            self.page_pool.release(self.tenant)
        return n, freed

    def apply_quota(self, units: Optional[int]) -> None:
        if units is not None:
            self.kv.register_tenant(self.tenant,
                                    quota_tokens=units * self.unit)

    # -- controller/stat surface (idle for KV tenants) -----------------------
    def migration_cost_bytes(self, new_chunk_sizes) -> float:
        return 0.0      # KV refits are hot (live chunks keep their ranges)

    def stats(self):
        rec = self._rec
        import types
        return types.SimpleNamespace(
            n_resident=rec.active_requests,
            item_bytes=rec.used_tokens,
            waste=rec.allocated_tokens - rec.used_tokens,
            n_evicted=rec.n_retained_evicted,
            evicted_bytes=rec.retained_evicted_tokens,
            n_page_denials=rec.n_failed,
            migration_evictions=rec.n_quota_reclaims,
            evicted_hot_bytes=0,
            reused_after_evict=0,
            eviction_policy=type(self.kv.eviction_policy).__name__.lower())


def token_quota_arbiter(kv: KVSlabPool, *,
                        unit_tokens: Optional[int] = None,
                        floor_units: int = 1,
                        equal_partition: bool = False,
                        controller_config: Optional[ControllerConfig] = None,
                        **arbiter_kw):
    """Put a :class:`~repro.core.arbiter.TenantArbiter` in charge of a
    KV pool's per-stream token quotas.

    Every stream already registered on ``kv`` becomes a tenant of a
    ``ResourcePool(kind="kv_tokens")`` whose unit is ``unit_tokens``
    (default: 8 allocation grids, i.e. ``8 * kv.align``). A stream's
    existing ``quota_tokens`` converts to its starting unit quota
    (floor division; ``None`` stays unmanaged unless
    ``equal_partition``). From then on the arbiter owns the quotas:
    each round it re-measures real usage, prices donors by the
    retained-sequence reclaimable value (plus the forecast demand
    surcharge when ``forecast=`` is active), and pushes approved moves
    back into ``KVSlabPool.register_tenant(quota_tokens=...)``.

    Drive the cadence from the serving loop:
    ``ContinuousBatcher(pool, tenant=..., arbiter=arb)`` ticks it once
    per step, or call ``arb.tick(n)`` / ``arb.arbitrate()`` yourself.
    """
    from repro.core.arbiter import ResourcePool, TenantArbiter
    unit = int(unit_tokens or 8 * kv.align)
    total_units = max(1, kv.pool_tokens // unit)
    pool = ResourcePool(total_units, unit_size=unit, kind="kv_tokens")
    if controller_config is None:
        # per-tenant controllers are idle here (the pool's own shared
        # controller learns the classes from merged traffic); park the
        # check cadence out of reach
        controller_config = ControllerConfig(page_size=unit,
                                             check_every=1 << 62)
    arb = TenantArbiter(pool, controller_config=controller_config,
                        **arbiter_kw)
    for name, rec in kv._tenants.items():
        quota = (None if rec.quota_tokens is None
                 else max(floor_units, rec.quota_tokens // unit))
        arb.register(name, KVTenantQuotaView(kv, name, pool),
                     floor_pages=floor_units, quota=quota)
    if equal_partition:
        pool.equal_partition(floor=floor_units)
        for t in arb.tenants.values():
            t.allocator.apply_quota(pool.quota(t.name))
    return arb


def default_pow2_classes(min_chunk: int = ALIGN,
                         max_chunk: int = 1 << 17) -> np.ndarray:
    """The un-learned baseline: power-of-two chunk classes (the common
    'just double it' allocator — analogous to memcached's 1.25-geometric
    default, at allocator-friendly granularity)."""
    out = []
    c = min_chunk
    while c <= max_chunk:
        out.append(c)
        c *= 2
    return np.asarray(out, dtype=np.int64)
