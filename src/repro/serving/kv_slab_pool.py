"""KV-cache slab pool with LEARNED chunk classes — the paper's technique
as a serving-runtime feature.

The mapping (DESIGN.md §2): a serving runtime allocates KV-cache space
per request; request context lengths are the "item sizes", the KV pool
is the memory, and rounding a request up to its allocation is internal
fragmentation of HBM. vLLM-style paging buys ~zero fragmentation with
per-page indirection; on TPU, contiguous DMA is strongly preferred, so
this pool allocates each request ONE contiguous chunk whose size comes
from a slab-class schedule *learned from the observed request-length
distribution* (SlabPolicy / the paper's algorithm). The learned schedule
bounds the fragmentation that contiguity would otherwise cost; the
contiguous layout is what `kernels/slab_attention.py` streams through
VMEM with zero indirection.

Implementation notes:
  * allocation granularity is ALIGN tokens (kernel tile = 128), so the
    learner fits on the align-quantized length histogram;
  * per-class free lists + bump pointer, O(1) alloc/free — the memcached
    discipline, in tokens instead of bytes;
  * ``refit()`` re-learns classes online from the sliding histogram of
    observed lengths (the paper's "analyse the pattern of sizes
    previously entered"); pools refit at a configurable cadence.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import SlabPolicy, size_histogram, waste_exact

ALIGN = 128  # tokens; matches the Pallas kernel's BLOCK_T


def quantize_lengths(lengths: np.ndarray, align: int = ALIGN) -> np.ndarray:
    """Round lengths up to the allocation grid (the learner's item size)."""
    lengths = np.asarray(lengths, dtype=np.int64)
    return ((lengths + align - 1) // align) * align


@dataclasses.dataclass
class PoolStats:
    active_requests: int
    pool_tokens: int
    allocated_tokens: int      # sum of chunk sizes of live allocations
    used_tokens: int           # sum of true KV lengths
    free_tokens: int
    n_failed: int

    @property
    def waste_tokens(self) -> int:
        return self.allocated_tokens - self.used_tokens

    @property
    def utilization(self) -> float:
        return self.used_tokens / max(self.allocated_tokens, 1)

    @property
    def waste_fraction(self) -> float:
        return self.waste_tokens / max(self.allocated_tokens, 1)


@dataclasses.dataclass
class Allocation:
    request_id: int
    start: int          # pool token offset (ALIGN-multiple)
    chunk: int          # slab class size (tokens)
    length: int         # true KV length


class KVSlabPool:
    """Contiguous KV pool with slab-class allocation."""

    def __init__(self, pool_tokens: int, chunk_classes, *,
                 align: int = ALIGN):
        self.pool_tokens = int(pool_tokens)
        self.align = align
        self.set_classes(chunk_classes)
        self._bump = 0
        self._free: Dict[int, List[int]] = defaultdict(list)
        self._live: Dict[int, Allocation] = {}
        self.n_failed = 0
        self.observed_lengths: List[int] = []

    # -- class management ----------------------------------------------------
    def set_classes(self, chunk_classes) -> None:
        cc = sorted(int(c) for c in chunk_classes)
        if any(c % self.align for c in cc):
            raise ValueError(f"classes must be multiples of {self.align}")
        self.chunk_classes = cc

    def class_for(self, length: int) -> Optional[int]:
        for c in self.chunk_classes:            # K is small
            if c >= length:
                return c
        return None

    # -- alloc/free ------------------------------------------------------------
    def alloc(self, request_id: int, length: int) -> Optional[Allocation]:
        self.observed_lengths.append(length)
        chunk = self.class_for(length)
        if chunk is None:
            self.n_failed += 1
            return None
        if self._free[chunk]:
            start = self._free[chunk].pop()
        elif self._bump + chunk <= self.pool_tokens:
            start = self._bump
            self._bump += chunk
        else:
            self.n_failed += 1
            return None
        a = Allocation(request_id, start, chunk, length)
        self._live[request_id] = a
        return a

    def extend(self, request_id: int, new_length: int
               ) -> Optional[Allocation]:
        """Grow a request's KV (decode). Within-chunk growth is free; a
        class overflow reallocates into the next class (copy cost is the
        caller's — it shows up in the scheduler's accounting)."""
        a = self._live[request_id]
        if new_length <= a.chunk:
            a.length = new_length
            return a
        self.free(request_id)
        return self.alloc(request_id, new_length)

    def free(self, request_id: int) -> None:
        a = self._live.pop(request_id)
        self._free[a.chunk].append(a.start)

    def allocation(self, request_id: int) -> Allocation:
        return self._live[request_id]

    # -- learning -------------------------------------------------------------
    def refit(self, k: Optional[int] = None, *, method: str = "dp",
              policy: Optional[SlabPolicy] = None) -> np.ndarray:
        """Re-learn chunk classes from observed lengths (paper's loop).

        Only safe when the pool is empty or during a maintenance window
        (live allocations keep their old chunks; new allocations use the
        new schedule — memcached's own constraint when slab_sizes change
        requires a restart, we allow hot refit for new chunks only).
        """
        if not self.observed_lengths:
            return np.asarray(self.chunk_classes)
        k = k or len(self.chunk_classes)
        q = quantize_lengths(np.asarray(self.observed_lengths), self.align)
        support, freqs = size_histogram(q)
        policy = policy or SlabPolicy(page_size=1 << 22, min_chunk=self.align)
        sched = policy.fit(support, freqs, k, method=method,
                           baseline=np.asarray(self.chunk_classes))
        new = quantize_lengths(sched.chunk_sizes, self.align)
        self.set_classes(np.unique(new))
        return np.unique(new)

    # -- measurement ------------------------------------------------------------
    def stats(self) -> PoolStats:
        allocated = sum(a.chunk for a in self._live.values())
        used = sum(a.length for a in self._live.values())
        free_listed = sum(c * len(v) for c, v in self._free.items())
        return PoolStats(
            active_requests=len(self._live),
            pool_tokens=self.pool_tokens,
            allocated_tokens=allocated,
            used_tokens=used,
            free_tokens=self.pool_tokens - self._bump + free_listed,
            n_failed=self.n_failed)

    def kernel_args(self, request_ids) -> Tuple[np.ndarray, np.ndarray]:
        """(starts, lens) int32 arrays for slab_decode_attention."""
        starts = np.asarray([self._live[r].start for r in request_ids],
                            dtype=np.int32)
        lens = np.asarray([self._live[r].length for r in request_ids],
                          dtype=np.int32)
        return starts, lens

    @property
    def max_chunk_tokens(self) -> int:
        return max(self.chunk_classes)


def default_pow2_classes(min_chunk: int = ALIGN,
                         max_chunk: int = 1 << 17) -> np.ndarray:
    """The un-learned baseline: power-of-two chunk classes (the common
    'just double it' allocator — analogous to memcached's 1.25-geometric
    default, at allocator-friendly granularity)."""
    out = []
    c = min_chunk
    while c <= max_chunk:
        out.append(c)
        c *= 2
    return np.asarray(out, dtype=np.int64)
