"""Serving steps: prefill + batched decode with sampling.

``make_serve_fns`` wraps any zoo model into jittable prefill/decode; the
decode step is what the dry-run lowers for the decode_32k / long_500k
cells. Sampling supports greedy and temperature; generation loops live
in examples/ and launch/serve.py.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def sample_logits(key, logits: jnp.ndarray, *,
                  temperature: float = 0.0) -> jnp.ndarray:
    """logits: (B, 1, V) -> (B, 1) token ids."""
    logits = logits[:, -1].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


def make_serve_fns(model, *, temperature: float = 0.0):
    """Returns (prefill_fn, decode_fn), both jittable.

    prefill_fn(params, tokens, extras, max_len) -> (next_token, cache)
    decode_fn(params, token, cache, cache_len, extras, key)
        -> (next_token, logits, cache)
    """

    def prefill_fn(params, tokens, extras, max_len: int):
        logits, cache = (model.prefill(params, tokens, extras, max_len)
                         if max_len else
                         model.prefill(params, tokens, extras))
        tok = sample_logits(jax.random.PRNGKey(0), logits[:, -1:],
                            temperature=0.0)
        return tok, cache

    def decode_fn(params, token, cache, cache_len, extras, key):
        logits, cache = model.decode(params, token, cache, cache_len,
                                     extras)
        tok = sample_logits(key, logits, temperature=temperature)
        return tok, logits, cache

    return prefill_fn, decode_fn


def generate(model, params, prompt: jnp.ndarray, *, steps: int,
             extras: Optional[Dict[str, Any]] = None, max_len: int = 0,
             temperature: float = 0.0, seed: int = 0,
             jit: bool = True) -> jnp.ndarray:
    """Greedy/temperature generation loop (host-side loop, jitted steps)."""
    prefill_fn, decode_fn = make_serve_fns(model, temperature=temperature)
    if jit:
        decode_fn = jax.jit(decode_fn)
    b, s = prompt.shape
    max_len = max_len or (s + steps)
    tok, cache = prefill_fn(params, prompt, extras, max_len)
    out = [tok]
    key = jax.random.PRNGKey(seed)
    for i in range(steps - 1):
        key, sub = jax.random.split(key)
        tok, _, cache = decode_fn(params, tok, cache,
                                  jnp.int32(s + i), extras, sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
