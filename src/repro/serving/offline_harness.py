"""MLPerf-offline-style serving harness over the slab KV pool: batched
prefill/decode with ONE jitted dispatch per decode tick.

The last serving-path gap (ROADMAP "serve a real inference trace
end-to-end"): ``ContinuousBatcher`` proved the allocator's *decisions*
at the serving layer but decoded with a per-request host loop —
O(requests) dispatches per tick, exactly the engine-level bottleneck
that hides allocator wins. This harness runs the same open-loop request
stream (arrival timestamps, mixed prompt/output lengths, tenant tags —
synthetic or replayed through ``scenarios.trace.trace_requests``)
against the real device path:

* decode tick = ONE jitted call for the whole active batch: pending
  class-overflow chunk moves execute as a batched
  ``kv_chunk_copy_pallas`` scatter, ``slab_decode_attention_pallas``
  reads every sequence's KV straight out of the stacked slab-pool
  pages, and the new tokens' KV rows land via ``kv_append_pallas`` —
  carry buffers donated between ticks (off-CPU), O(ticks) dispatches
  (off-TPU the same step composes the kernels' jnp oracles instead:
  interpret-mode Pallas serializes the grid — see ``impl=``);
* prefill is batched per tick the same way (one call writes every
  newly admitted prompt's KV);
* admission runs at tick granularity through the forecast-driven
  token-quota arbiter when one is attached
  (``TenantArbiter.admission``), with the pool's own quota check as
  the enforcement backstop.

Parity contract (CI-gated in ``benchmarks/serving_bench.py --quick``):
``mode="legacy"`` executes the identical host bookkeeping but issues
one jitted call per request — and because every kernel computes each
sequence on fixed per-sequence block shapes, the generated tokens and
every admission/rejection/realloc decision are BIT-identical between
the two modes. The toy model is deterministic by construction: KV/Q
content are elementwise integer hashes of (request id, position,
token) — bit-exact under any compilation, no cross-batch matmuls —
and the next token is an argmax over a slice of the attention output,
so parity is exact, not approximate.

Junk-range contract: the device pools are padded ``max_chunk_tokens``
past ``pool_tokens`` so the scatter kernels' reserved tail range (see
``kernels/kv_scatter``) can never alias a real allocation.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import hot_path
from repro.kernels.kv_scatter import (kv_append_pallas, kv_append_ref,
                                      kv_chunk_copy_pallas,
                                      kv_chunk_copy_ref)
from repro.kernels.ref import slab_decode_attention_window_ref
from repro.kernels.slab_attention import slab_decode_attention_pallas
from repro.serving.kv_slab_pool import ALIGN, KVSlabPool
from repro.serving.scheduler import Request, queue_delay_stats


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _default_impl() -> str:
    # The Pallas kernels only parallelize their grid on a real TPU; in
    # interpret mode the grid runs serially, so a B=64 call costs the
    # same wall time as 64 B=1 calls and batching could never show its
    # dispatch-amortization win. Off-TPU the step functions therefore
    # compose the kernels' jnp oracles (same masked-softmax / scatter
    # semantics, batch-vectorized by XLA; kernel == oracle is CI-gated
    # in tests/test_kernels.py).
    return "pallas" if jax.default_backend() == "tpu" else "ref"


# -- deterministic toy model ---------------------------------------------------
# Content functions are INTEGER-hash based, not transcendental: uint32
# mixing wraps identically under every compilation, and the only float
# ops are a single convert + multiply-add per element (IEEE-exact). XLA
# compiles sin/cos with shape-dependent vectorization (a B=1 program
# and a B=64 program disagree in the last few ulps), which would break
# the batched-vs-legacy bit-parity contract; hashes cannot.

def _mix(rid, pos, token, salt: int, hkv: int, d: int) -> jnp.ndarray:
    """(..., hkv, d) uint32 hash of (request id, position, token)."""
    rid = jnp.asarray(rid).astype(jnp.uint32)
    pos = jnp.asarray(pos).astype(jnp.uint32)
    token = jnp.asarray(token).astype(jnp.uint32)
    h = jnp.arange(hkv, dtype=jnp.uint32)
    dd = jnp.arange(d, dtype=jnp.uint32)
    x = (rid[..., None, None] * jnp.uint32(2654435761)
         + pos[..., None, None] * jnp.uint32(40503)
         + token[..., None, None] * jnp.uint32(69069)
         + h[:, None] * jnp.uint32(97) + dd[None, :] * jnp.uint32(131)
         + jnp.uint32(salt))
    x = x ^ (x >> 15)
    x = x * jnp.uint32(2246822519)
    x = x ^ (x >> 13)
    return x


def _to_unit(x: jnp.ndarray) -> jnp.ndarray:
    """uint32 hash -> float32 in [-1, 1), one convert + one fma."""
    return ((x & jnp.uint32(0xFFFF)).astype(jnp.float32) / 32768.0 - 1.0)


def _kv_content(rid, pos, token, hkv: int, d: int):
    return (_to_unit(_mix(rid, pos, token, 0x9E37, hkv, d)),
            _to_unit(_mix(rid, pos, token, 0x85EB, hkv, d)))


def _q_content(rid, pos, hkv: int, d: int) -> jnp.ndarray:
    return _to_unit(_mix(rid, pos, 0, 0xC2B2, hkv, d))


# -- jitted step factories -----------------------------------------------------
# One compiled fn per (static config, donate) pair; donation follows the
# repo's conditional pattern (core/observe.py): enabled off-CPU, where
# jit donation is actually supported, disabled on CPU to avoid
# per-launch donation warnings (guards escalate those to errors).

_STEP_CACHE: Dict[tuple, Callable] = {}


def _decode_step_fn(max_chunk: int, vocab: int, interpret: bool,
                    donate: bool, impl: str) -> Callable:
    key = ("decode", max_chunk, vocab, interpret, donate, impl)
    fn = _STEP_CACHE.get(key)
    if fn is not None:
        return fn
    kernels = impl == "pallas"

    def copy(pool, src, dst, tok):
        if kernels:
            return kv_chunk_copy_pallas(pool, src, dst, tok,
                                        max_copy_tokens=max_chunk,
                                        interpret=interpret)
        return kv_chunk_copy_ref(pool, src, dst, tok,
                                 max_copy_tokens=max_chunk)

    def attend(q, k_pool, v_pool, starts, alens):
        if kernels:
            return slab_decode_attention_pallas(
                q, k_pool, v_pool, starts, alens,
                max_chunk_tokens=max_chunk, interpret=interpret)
        return slab_decode_attention_window_ref(
            q, k_pool, v_pool, starts, alens,
            max_chunk_tokens=max_chunk)

    def append(pool, rows, vals):
        if kernels:
            return kv_append_pallas(pool, rows, vals, interpret=interpret)
        return kv_append_ref(pool, rows, vals)

    def run(k_pool, v_pool, starts, lens, rids, active,
            mv_src, mv_dst, mv_tok):
        hkv, d = k_pool.shape[1], k_pool.shape[2]
        # 1) pending class-overflow chunk moves (array order = the
        #    allocator's processing order; WAR-safe, see kv_scatter)
        k_pool = copy(k_pool, mv_src, mv_dst, mv_tok)
        v_pool = copy(v_pool, mv_src, mv_dst, mv_tok)
        # 2) flash-decode over the pool for the whole batch
        q = _q_content(rids, lens, hkv, d)
        alens = jnp.where(active > 0, lens, 0).astype(jnp.int32)
        out = attend(q, k_pool, v_pool, starts.astype(jnp.int32), alens)
        # 3) next token: argmax over a slice of the attention output —
        #    per-row, no cross-batch mixing, ties break low
        tokens = jnp.argmax(out[:, 0, :vocab], axis=-1).astype(jnp.int32)
        tokens = jnp.where(active > 0, tokens, -1)
        # 4) append the new token's KV row at position lens
        kc, vc = _kv_content(rids, lens, jnp.maximum(tokens, 0), hkv, d)
        rows = jnp.where(active > 0, starts + lens, -1).astype(jnp.int32)
        k_pool = append(k_pool, rows, kc)
        v_pool = append(v_pool, rows, vc)
        return k_pool, v_pool, tokens

    fn = jax.jit(run, donate_argnums=(0, 1) if donate else ())
    _STEP_CACHE[key] = fn
    return fn


def _prefill_step_fn(max_chunk: int, vocab: int, donate: bool) -> Callable:
    key = ("prefill", max_chunk, vocab, donate)
    fn = _STEP_CACHE.get(key)
    if fn is not None:
        return fn

    def run(k_pool, v_pool, starts, plens, rids):
        t, hkv, d = k_pool.shape
        pos = jnp.arange(max_chunk, dtype=jnp.int32)

        def body(i, kv):
            k, v = kv
            rid_vec = jnp.full((max_chunk,), rids[i], jnp.float32)
            kc, vc = _kv_content(rid_vec, pos, pos % vocab, hkv, d)
            mask = (pos < plens[i])[:, None, None]
            base = (starts[i], 0, 0)
            curk = jax.lax.dynamic_slice(k, base, (max_chunk, hkv, d))
            curv = jax.lax.dynamic_slice(v, base, (max_chunk, hkv, d))
            k = jax.lax.dynamic_update_slice(
                k, jnp.where(mask, kc, curk), base)
            v = jax.lax.dynamic_update_slice(
                v, jnp.where(mask, vc, curv), base)
            return k, v

        return jax.lax.fori_loop(0, starts.shape[0], body, (k_pool, v_pool))

    fn = jax.jit(run, donate_argnums=(0, 1) if donate else ())
    _STEP_CACHE[key] = fn
    return fn


@dataclasses.dataclass
class HarnessResult:
    """One offline run's ledger. ``tokens`` maps request id → generated
    token ids (the parity surface: batched vs legacy must match
    bit-for-bit); dispatch counters are the O(ticks) contract."""
    ticks: int
    completed: int
    rejected: int
    realloc_copies: int
    realloc_tokens: int
    generated_tokens: int
    n_decode_dispatches: int
    n_prefill_dispatches: int
    queue_delay_mean: float
    queue_delay_p50: float
    queue_delay_p99: float
    mean_waste_fraction: float
    peak_active: int
    mean_active: float
    n_refits: int
    n_admission_denials: int
    tokens: Dict[int, List[int]]

    def decisions(self) -> tuple:
        """The admission/progress decision fingerprint two runs must
        share to count as identical (tokens compared separately)."""
        return (self.ticks, self.completed, self.rejected,
                self.realloc_copies, self.realloc_tokens,
                self.n_refits, self.n_admission_denials)


class OfflineHarness:
    """Open-loop offline serving over a :class:`KVSlabPool`.

    ``mode="batched"`` — one jitted decode dispatch per tick for the
    whole active batch (and one prefill dispatch per tick with
    admissions). ``mode="legacy"`` — identical host bookkeeping, one
    dispatch per request: the bit-parity oracle the bench gates on.

    ``impl`` picks the device math inside the step functions:
    ``"pallas"`` (the TPU kernels; default on TPU) or ``"ref"`` (the
    kernels' batch-vectorized jnp oracles; default elsewhere, where
    interpret-mode Pallas would serialize the grid and erase the
    batching win — see :func:`_default_impl`). Both modes of one
    harness config share one step function, so the parity contract is
    per-impl.

    The harness owns stacked device pools shaped
    ``(pool_tokens_padded, hkv, d)``; ``pool`` supplies allocation
    decisions only. Chunk classes may refit DOWN or re-partition freely
    mid-run (``adaptive=True``), but growing the top class past the
    harness's compiled ``max_chunk_tokens`` ceiling raises — the static
    shapes baked into the step functions cannot stretch.

    Admission: FIFO over arrivals; with an ``arbiter``, each candidate
    first passes ``TenantArbiter.admission`` (tick-granular gate,
    denials recorded as tenant pressure), then the pool's own
    quota/capacity check. Gate or alloc failure rejects (drops) the
    request — the ContinuousBatcher contract.
    """

    def __init__(self, pool: KVSlabPool, *, max_batch: int = 64,
                 mode: str = "batched", hkv: int = 1, d: int = 16,
                 vocab: int = 16, max_chunk_tokens: Optional[int] = None,
                 adaptive: bool = False, arbiter=None,
                 impl: Optional[str] = None,
                 interpret: Optional[bool] = None):
        if mode not in ("batched", "legacy"):
            raise ValueError(f"unknown mode {mode!r}")
        if vocab > d:
            raise ValueError(f"vocab {vocab} > head dim {d}")
        self.impl = _default_impl() if impl is None else impl
        if self.impl not in ("pallas", "ref"):
            raise ValueError(f"unknown impl {self.impl!r}")
        self.pool = pool
        self.mode = mode
        self.max_batch = int(max_batch)
        self.adaptive = adaptive
        self.arbiter = arbiter
        self.max_chunk = int(max_chunk_tokens or pool.max_chunk_tokens)
        if self.max_chunk % ALIGN:
            raise ValueError(f"max_chunk_tokens must be a multiple "
                             f"of {ALIGN}")
        self._interpret = (_default_interpret() if interpret is None
                           else bool(interpret))
        self._donate = jax.default_backend() != "cpu"
        # device pools: pad past pool_tokens so the scatter kernels'
        # reserved tail range is never a real allocation (junk-range
        # contract), and keep rows a multiple of ALIGN for tile copies
        t_pad = -(-pool.pool_tokens // ALIGN) * ALIGN + self.max_chunk
        self._k = jnp.zeros((t_pad, hkv, d), jnp.float32)
        self._v = jnp.zeros((t_pad, hkv, d), jnp.float32)
        self._decode = _decode_step_fn(self.max_chunk, vocab,
                                       self._interpret, self._donate,
                                       self.impl)
        self._prefill = _prefill_step_fn(self.max_chunk, vocab,
                                         self._donate)
        # fixed-size slot state (RT001: one traced shape per run)
        self._starts = np.zeros(self.max_batch, np.int32)
        self._lens = np.zeros(self.max_batch, np.int32)
        self._rids = np.zeros(self.max_batch, np.int32)
        self._act = np.zeros(self.max_batch, np.int32)
        self._free_slots = list(range(self.max_batch - 1, -1, -1))
        self._slot_of: Dict[int, int] = {}
        self._queue: Deque[Request] = deque()
        self._active: Dict[int, Request] = {}
        # ledger
        self.completed = 0
        self.rejected = 0
        self.realloc_copies = 0
        self.realloc_tokens = 0
        self.n_refits = 0
        self.n_decode_dispatches = 0
        self.n_prefill_dispatches = 0
        self.queue_delays: List[float] = []
        # (slot→rid snapshot, device tokens) per decode dispatch; synced
        # to host ONCE in result()
        self._token_log: List[Tuple[Tuple[Optional[int], ...],
                                    jnp.ndarray]] = []

    def submit(self, req: Request) -> None:
        if req.tenant not in self.pool._tenants:
            self.pool.register_tenant(req.tenant)
        self._queue.append(req)

    # -- host bookkeeping phases (shared verbatim by both modes) -------------
    def _admit_phase(self, t: int, observed: List[int]
                     ) -> List[Tuple[int, int, int, int]]:
        """FIFO admission under arrivals/slots/gate/quota; returns the
        prefill plan ``[(slot, start, prompt_len, rid), ...]`` in
        admission order."""
        plan: List[Tuple[int, int, int, int]] = []
        while (self._queue and self._queue[0].arrival <= t
                and self._free_slots):
            req = self._queue[0]
            # observed BEFORE the attempt (ContinuousBatcher contract)
            observed.append(req.kv_len)
            if self.arbiter is not None:
                chunk = self.pool.class_for(req.kv_len)
                if chunk is not None:
                    units = -(-chunk // self.arbiter.pool.unit_size)
                    if not self.arbiter.admission(req.tenant, units):
                        self.rejected += 1
                        self._queue.popleft()
                        continue
            a = self.pool.alloc(req.rid, req.kv_len, tenant=req.tenant)
            if a is None:
                self.rejected += 1
                self._queue.popleft()
                continue
            self._queue.popleft()
            slot = self._free_slots.pop()
            self._slot_of[req.rid] = slot
            self._active[req.rid] = req
            self._starts[slot] = a.start
            self._lens[slot] = req.kv_len
            self._rids[slot] = req.rid
            self._act[slot] = 1
            self.queue_delays.append(t - req.arrival)
            plan.append((slot, a.start, req.prompt_len, req.rid))
        return plan

    def _decode_phase(self, observed: List[int]
                      ) -> Tuple[List[Tuple[int, int, int, int]],
                                 List[int]]:
        """Per-tick decode bookkeeping: growth (bulk), class-overflow
        reallocation (inline, processing order), completion/drop
        marking. Returns ``(plan, finished)`` where plan rows are
        ``(slot, mv_src, mv_dst, mv_tok)`` (move tokens 0 = no move)
        and ``finished`` lists drops and completions in processing
        order (freelist order is part of the decision contract)."""
        plan: List[Tuple[int, int, int, int]] = []
        grown: List[Tuple[int, int]] = []
        finished: List[int] = []
        for rid, req in self._active.items():
            slot = self._slot_of[rid]
            req.decoded += 1
            old = self.pool.allocation(rid)
            pre_len = req.kv_len - 1
            mv = (0, 0, 0)
            if req.kv_len <= old.chunk:
                grown.append((rid, req.kv_len))
                start = old.start
            else:
                new = self.pool.extend(rid, req.kv_len)
                if new is None:   # pool full mid-flight: drop, no decode
                    observed.append(req.kv_len)
                    self.rejected += 1
                    finished.append(rid)
                    self._act[slot] = 0
                    continue
                if new.start != old.start:
                    self.realloc_copies += 1
                    self.realloc_tokens += old.length
                    observed.append(req.kv_len)
                    mv = (old.start, new.start, old.length)
                start = new.start
            self._starts[slot] = start
            self._lens[slot] = pre_len
            plan.append((slot, *mv))
            if req.decoded >= req.output_len:
                finished.append(rid)
                self.completed += 1
        if grown:
            self.pool.extend_bulk(grown)
        return plan, finished

    def _release(self, rids: List[int]) -> None:
        for rid in rids:
            if rid in self.pool._live:
                self.pool.free(rid)
            del self._active[rid]
            slot = self._slot_of.pop(rid)
            self._act[slot] = 0
            self._free_slots.append(slot)

    # -- device dispatches ----------------------------------------------------
    def _dispatch_prefill(self, plan) -> None:
        if not plan:
            return
        if self.mode == "batched":
            starts = np.zeros(self.max_batch, np.int32)
            plens = np.zeros(self.max_batch, np.int32)
            rids = np.zeros(self.max_batch, np.int32)
            for i, (_slot, start, plen, rid) in enumerate(plan):
                starts[i], plens[i], rids[i] = start, plen, rid
            # starts/plens/rids are freshly built per call: safe to
            # hand to jnp.asarray without copying
            self._k, self._v = self._prefill(
                self._k, self._v, jnp.asarray(starts), jnp.asarray(plens),
                jnp.asarray(rids))
            self.n_prefill_dispatches += 1
            return
        for _slot, start, plen, rid in plan:
            self._k, self._v = self._prefill(
                self._k, self._v,
                jnp.asarray([start], jnp.int32),
                jnp.asarray([plen], jnp.int32),
                jnp.asarray([rid], jnp.int32))
            self.n_prefill_dispatches += 1

    def _dispatch_decode(self, plan) -> None:
        if not plan:
            return
        if self.mode == "batched":
            mv_src = np.zeros(self.max_batch, np.int32)
            mv_dst = np.zeros(self.max_batch, np.int32)
            mv_tok = np.zeros(self.max_batch, np.int32)
            n_mv = 0
            for _slot, src, dst, tok in plan:
                if tok:
                    mv_src[n_mv], mv_dst[n_mv], mv_tok[n_mv] = src, dst, tok
                    n_mv += 1
            # .copy(): jnp.asarray may zero-copy a host array, and the
            # async-dispatched step can read it AFTER the next tick's
            # bookkeeping mutates the slot state in place
            self._k, self._v, tokens = self._decode(
                self._k, self._v, jnp.asarray(self._starts.copy()),
                jnp.asarray(self._lens.copy()),
                jnp.asarray(self._rids.copy()),
                jnp.asarray(self._act.copy()), jnp.asarray(mv_src),
                jnp.asarray(mv_dst), jnp.asarray(mv_tok))
            self.n_decode_dispatches += 1
            snap = tuple(int(self._rids[s]) if self._act[s] else None
                         for s in range(self.max_batch))
            self._token_log.append((snap, tokens))
            return
        for slot, src, dst, tok in plan:
            self._k, self._v, tokens = self._decode(
                self._k, self._v,
                jnp.asarray(self._starts[slot:slot + 1].copy()),
                jnp.asarray(self._lens[slot:slot + 1].copy()),
                jnp.asarray(self._rids[slot:slot + 1].copy()),
                jnp.asarray(self._act[slot:slot + 1].copy()),
                jnp.asarray([src], np.int32), jnp.asarray([dst], np.int32),
                jnp.asarray([tok], np.int32))
            self.n_decode_dispatches += 1
            self._token_log.append(((int(self._rids[slot]),), tokens))

    # -- the tick -------------------------------------------------------------
    @hot_path(counters=("n_decode_dispatches", "n_prefill_dispatches"))
    def tick(self, t: int) -> None:
        """One serving tick: admit → prefill dispatch → decode
        bookkeeping → ONE decode dispatch (batched mode) → frees →
        observe/arbitrate/refit. No device value is synced to host
        here — tokens stay on device until :meth:`result`."""
        observed: List[int] = []
        prefill_plan = self._admit_phase(t, observed)
        self._dispatch_prefill(prefill_plan)
        decode_plan, finished = self._decode_phase(observed)
        self._dispatch_decode(decode_plan)
        self._release(finished)
        if self.pool.batch_observe and observed:
            self.pool.observe_lengths(np.asarray(observed, dtype=np.int64))
        if self.arbiter is not None:
            self.arbiter.tick(1)
        if self.adaptive:
            decision = self.pool.maybe_refit()
            if decision is not None and decision.approved:
                self.n_refits += 1
                if self.pool.max_chunk_tokens > self.max_chunk:
                    raise RuntimeError(
                        f"refit grew the top class to "
                        f"{self.pool.max_chunk_tokens} tokens, past the "
                        f"harness's compiled ceiling {self.max_chunk}; "
                        f"construct the harness with max_chunk_tokens= "
                        f"headroom for adaptive runs")

    def run(self, workload: List[Request],
            max_ticks: Optional[int] = None) -> HarnessResult:
        for req in sorted(workload, key=lambda r: r.arrival):
            self.submit(req)
        if max_ticks is None:
            horizon = max((int(r.arrival) for r in workload), default=0)
            max_ticks = horizon + sum(r.output_len for r in workload) + 16
        waste_samples: List[float] = []
        active_samples: List[int] = []
        t = -1
        for t in range(max_ticks):
            self.tick(t)
            st = self.pool.stats()
            if st.active_requests:
                waste_samples.append(st.waste_fraction)
            active_samples.append(st.active_requests)
            if not self._active and not self._queue:
                break
        return self.result(t + 1, waste_samples, active_samples)

    def result(self, ticks: int, waste_samples=(), active_samples=(0,)
               ) -> HarnessResult:
        """Fold the run's ledger (syncing the device token log to host
        exactly once)."""
        tokens: Dict[int, List[int]] = {}
        for snap, dev in self._token_log:
            arr = np.asarray(dev)
            for slot, rid in enumerate(snap):
                if rid is not None and arr[slot] >= 0:
                    tokens.setdefault(rid, []).append(int(arr[slot]))
        qd_mean, qd_p50, qd_p99 = queue_delay_stats(self.queue_delays)
        denials = (self.arbiter.n_admission_denials
                   if self.arbiter is not None else 0)
        return HarnessResult(
            ticks=ticks,
            completed=self.completed,
            rejected=self.rejected,
            realloc_copies=self.realloc_copies,
            realloc_tokens=self.realloc_tokens,
            generated_tokens=sum(len(v) for v in tokens.values()),
            n_decode_dispatches=self.n_decode_dispatches,
            n_prefill_dispatches=self.n_prefill_dispatches,
            queue_delay_mean=qd_mean,
            queue_delay_p50=qd_p50,
            queue_delay_p99=qd_p99,
            mean_waste_fraction=(float(np.mean(waste_samples))
                                 if len(waste_samples) else 0.0),
            peak_active=int(np.max(active_samples)),
            mean_active=float(np.mean(active_samples)),
            n_refits=self.n_refits,
            n_admission_denials=denials,
            tokens=tokens)
