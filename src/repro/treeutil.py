"""Pytree path helpers shared by sharding rules and checkpointing.

``jax.tree_util.keystr(..., simple=True, separator=...)`` only exists in
jax >= 0.5; this repo pins an older wheel. ``simple_keystr`` reproduces
the simple form (bare key names joined by a separator, no brackets or
quoting) on every jax version, delegating to the native implementation
when it is available.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax

try:  # jax >= 0.5: keystr grew simple/separator kwargs
    jax.tree_util.keystr((), simple=True, separator="/")
    _NATIVE_SIMPLE = True
except TypeError:  # pragma: no cover - depends on installed jax
    _NATIVE_SIMPLE = False


def _entry_name(entry: Any) -> str:
    """Bare name of one KeyPath entry (DictKey.key, SequenceKey.idx,
    GetAttrKey.name, FlattenedIndexKey.key)."""
    for attr in ("key", "idx", "name"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def simple_keystr(path: Tuple[Any, ...], *, separator: str = "/") -> str:
    """``keystr(path, simple=True, separator=separator)`` on any jax."""
    if _NATIVE_SIMPLE:
        return jax.tree_util.keystr(path, simple=True, separator=separator)
    return separator.join(_entry_name(e) for e in path)
