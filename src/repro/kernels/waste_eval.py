"""Pallas TPU kernel: batched slab-schedule waste evaluation.

The search hot spot of the paper's technique: score B candidate schedules
(each K chunk sizes) against an S-bucket item-size histogram. The paper
evaluates one candidate per step on a CPU; here the whole move frontier of
`parallel_hillclimb` (B = K x |deltas| candidates) is one kernel launch.

TPU mapping: this is a compare/select/accumulate workload for the VPU —
no MXU. We tile (B, S) into (BLOCK_B, BLOCK_S) VMEM blocks; each grid step
holds a (BLOCK_B, K) slice of candidates and a (1, BLOCK_S) histogram
slice, computes the covering chunk per (candidate, size) via a static
K-step running minimum (avoids a (BLOCK_B, K, BLOCK_S) intermediate), and
accumulates partial waste into the (BLOCK_B, 1) output block across the
inner S grid dimension (TPU grids execute sequentially, so `+=` into the
revisited output block is the standard reduction idiom).

VMEM budget at defaults (BLOCK_B=8, BLOCK_S=512, K<=64):
  candidates 8*64*4 = 2 KiB, histogram 2*512*4 = 4 KiB,
  per-step temporaries 3 * 8*512*4 = 48 KiB  -> comfortably < 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.distribution import PAGE_SIZE

BLOCK_B = 8
BLOCK_S = 512


def _waste_eval_kernel(chunks_ref, support_ref, freqs_ref, out_ref, *,
                       page_size: int):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = chunks_ref[...].astype(jnp.float32)        # (BLOCK_B, K) sorted rows
    s = support_ref[0, :].astype(jnp.float32)      # (BLOCK_S,)
    f = freqs_ref[0, :]                            # (BLOCK_S,)

    k = c.shape[1]
    assigned = jnp.full((c.shape[0], s.shape[0]), jnp.inf, dtype=jnp.float32)
    for kk in range(k):  # static unroll: running min of covering chunks
        ck = c[:, kk:kk + 1]                       # (BLOCK_B, 1)
        assigned = jnp.minimum(assigned,
                               jnp.where(ck >= s[None, :], ck, jnp.inf))
    # Uncovered sizes are charged whole pages: ceil(s / page) pages (at
    # least one), never a negative amount when s > page_size.
    pages = jnp.maximum(jnp.ceil(s / jnp.float32(page_size)), 1.0)
    uncovered = pages[None, :] * jnp.float32(page_size) - s[None, :]
    waste = jnp.where(jnp.isfinite(assigned), assigned - s[None, :],
                      uncovered)
    out_ref[...] += jnp.sum(waste * f[None, :], axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "interpret"))
def waste_eval_pallas(chunk_batch, support, freqs, *,
                      page_size: int = PAGE_SIZE,
                      interpret: bool = False) -> jnp.ndarray:
    """(B, K) int32 schedules x (S,) histogram -> (B,) float32 waste.

    Pads B to BLOCK_B and S to BLOCK_S (padding sizes get freq 0 and size 0,
    which any chunk covers at zero cost). Rows are sorted here so the kernel
    can use the running-min trick.
    """
    b, k = chunk_batch.shape
    s = support.shape[0]
    chunk_batch = jnp.sort(chunk_batch.astype(jnp.int32), axis=1)
    support = support.astype(jnp.int32)
    freqs = freqs.astype(jnp.float32)

    b_pad = (-b) % BLOCK_B
    s_pad = (-s) % BLOCK_S
    if b_pad:
        chunk_batch = jnp.pad(chunk_batch, ((0, b_pad), (0, 0)),
                              constant_values=1)
    if s_pad:
        support = jnp.pad(support, (0, s_pad), constant_values=0)
        freqs = jnp.pad(freqs, (0, s_pad), constant_values=0.0)
    bp, sp = b + b_pad, s + s_pad

    grid = (bp // BLOCK_B, sp // BLOCK_S)
    out = pl.pallas_call(
        functools.partial(_waste_eval_kernel, page_size=page_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, BLOCK_S), lambda i, j: (0, j)),
            pl.BlockSpec((1, BLOCK_S), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        interpret=interpret,
    )(chunk_batch, support[None, :], freqs[None, :])
    return out[:b, 0]


# ---------------------------------------------------------------------------
# Fleet variant: B schedules against B per-row histograms (one launch
# scoring every pending tenant's candidate frontier at once)
# ---------------------------------------------------------------------------

def _waste_eval_fleet_kernel(chunks_ref, support_ref, freqs_ref, out_ref, *,
                             page_size: int):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = chunks_ref[...].astype(jnp.float32)        # (BLOCK_B, K) sorted rows
    s = support_ref[...].astype(jnp.float32)       # (BLOCK_B, BLOCK_S)
    f = freqs_ref[...]                             # (BLOCK_B, BLOCK_S)

    k = c.shape[1]
    assigned = jnp.full(s.shape, jnp.inf, dtype=jnp.float32)
    for kk in range(k):  # static unroll: running min of covering chunks
        ck = c[:, kk:kk + 1]                       # (BLOCK_B, 1)
        assigned = jnp.minimum(assigned, jnp.where(ck >= s, ck, jnp.inf))
    pages = jnp.maximum(jnp.ceil(s / jnp.float32(page_size)), 1.0)
    uncovered = pages * jnp.float32(page_size) - s
    waste = jnp.where(jnp.isfinite(assigned), assigned - s, uncovered)
    out_ref[...] += jnp.sum(waste * f, axis=1, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("page_size", "interpret"))
def waste_eval_fleet_pallas(chunk_batch, supports, freqs, *,
                            page_size: int = PAGE_SIZE,
                            interpret: bool = False) -> jnp.ndarray:
    """(B, K) schedules x (B, S) PER-ROW histograms -> (B,) waste.

    The multi-tenant sibling of :func:`waste_eval_pallas`: row b scores
    schedule b against histogram b, so one launch covers every pending
    tenant's frontier. Same tiling, same accumulation order — a row
    whose histogram is replicated from the single-histogram call gets a
    bit-identical score. Pads B to BLOCK_B and S to BLOCK_S (padded
    sizes get freq 0 / size 0, zero waste).
    """
    b, k = chunk_batch.shape
    s = supports.shape[1]
    chunk_batch = jnp.sort(chunk_batch.astype(jnp.int32), axis=1)
    supports = supports.astype(jnp.int32)
    freqs = freqs.astype(jnp.float32)

    b_pad = (-b) % BLOCK_B
    s_pad = (-s) % BLOCK_S
    if b_pad:
        chunk_batch = jnp.pad(chunk_batch, ((0, b_pad), (0, 0)),
                              constant_values=1)
        supports = jnp.pad(supports, ((0, b_pad), (0, 0)))
        freqs = jnp.pad(freqs, ((0, b_pad), (0, 0)))
    if s_pad:
        supports = jnp.pad(supports, ((0, 0), (0, s_pad)))
        freqs = jnp.pad(freqs, ((0, 0), (0, s_pad)))
    bp, sp = b + b_pad, s + s_pad

    grid = (bp // BLOCK_B, sp // BLOCK_S)
    out = pl.pallas_call(
        functools.partial(_waste_eval_fleet_kernel, page_size=page_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, k), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_B, BLOCK_S), lambda i, j: (i, j)),
            pl.BlockSpec((BLOCK_B, BLOCK_S), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        interpret=interpret,
    )(chunk_batch, supports, freqs)
    return out[:b, 0]


@functools.partial(jax.jit, static_argnames=("page_size",))
def waste_eval_fleet_ref(chunk_batch, supports, freqs, *,
                         page_size: int = PAGE_SIZE) -> jnp.ndarray:
    """Pure-jnp oracle for ``waste_eval_fleet_pallas``."""
    c = jnp.sort(chunk_batch.astype(jnp.float32), axis=1)

    def row(crow, srow, frow):
        s = srow.astype(jnp.float32)
        covering = jnp.where(crow[:, None] >= s[None, :],
                             crow[:, None], jnp.inf)
        assigned = jnp.min(covering, axis=0)
        pages = jnp.maximum(jnp.ceil(s / jnp.float32(page_size)), 1.0)
        uncovered = pages * jnp.float32(page_size) - s
        waste = jnp.where(jnp.isfinite(assigned), assigned - s, uncovered)
        return jnp.sum(waste * frow.astype(jnp.float32))

    return jax.vmap(row)(c, supports, freqs)
