"""Pallas TPU kernel: decode attention over a contiguous slab KV pool.

Where the paper's technique meets the serving hot path. With *learned*
slab classes bounding internal fragmentation (repro.serving.kv_slab_pool),
a sequence's whole KV cache can live in ONE contiguous pool range
(start, len) instead of vLLM-style scattered pages. That trade is
TPU-native: contiguous KV streams through VMEM with plain sequential DMA
and zero per-page index indirection (TPU DMA engines strongly prefer
contiguous transfers; gather-style paging is the expensive GPU-ism this
replaces — see DESIGN.md §2). The allocator's fragmentation cost that
contiguity usually implies is exactly what the learned schedule minimizes.

Kernel: flash-decoding over the pool.
  grid = (B, Hkv, max_tiles); scalar-prefetched (starts_tiles, lens) steer
  each sequence's BlockSpec window into the pool: the k/v block for grid
  step (b, h, t) is pool tile  starts_tiles[b] + t  (clamped; tiles past
  ceil(len/BLOCK_T) are masked out of the online softmax). Online
  (m, l, acc) state lives in VMEM scratch across the inner t dimension;
  the normalized output is written on the last tile.

VMEM per step (BLOCK_T=128, D<=256, G<=8):
  k,v blocks 2*128*256*4 = 256 KiB, q/acc/m/l < 20 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 128
NEG_INF = -1e30


def _decode_kernel(starts_ref, lens_ref, q_ref, k_ref, v_ref, out_ref,
                   acc_ref, m_ref, l_ref, *, sm_scale: float,
                   max_tiles: int):
    b = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[:, 0, :].astype(jnp.float32)           # (BLOCK_T, D)
    v = v_ref[:, 0, :].astype(jnp.float32)           # (BLOCK_T, D)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale  # (G, BLOCK_T)

    length = lens_ref[b]
    pos = t * BLOCK_T + jax.lax.broadcasted_iota(jnp.int32,
                                                 scores.shape, 1)
    scores = jnp.where(pos < length, scores, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(scores, axis=1, keepdims=True)    # (G, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                       # (G, BLOCK_T)
    p = jnp.where(pos < length, p, 0.0)               # kill NEG_INF shift
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(t == max_tiles - 1)
    def _finalize():
        l_fin = l_ref[...]
        safe = jnp.where(l_fin > 0.0, l_fin, 1.0)     # empty sequence -> 0s
        out_ref[0, 0] = (acc_ref[...] / safe).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("max_chunk_tokens", "block_t", "sm_scale",
                              "interpret"))
def slab_decode_attention_pallas(q, k_pool, v_pool, starts, lens, *,
                                 max_chunk_tokens: int,
                                 block_t: int = BLOCK_T,
                                 sm_scale: float | None = None,
                                 interpret: bool = False) -> jnp.ndarray:
    """Decode attention over a contiguous slab KV pool.

    q:        (B, Hq, D);  k_pool/v_pool: (T_pool, Hkv, D)
    starts:   (B,) int32, pool token offset of each sequence's chunk —
              must be multiples of ``block_t`` (the slab allocator aligns
              chunk starts; see kv_slab_pool)
    lens:     (B,) int32 current KV length per sequence
    max_chunk_tokens: static bound = largest slab class (tokens)
    """
    b, hq, d = q.shape
    t_pool, hkv, _ = k_pool.shape
    g = hq // hkv
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    if sm_scale is None:
        sm_scale = float(d) ** -0.5
    max_tiles = -(-max_chunk_tokens // block_t)

    pad_t = (-t_pool) % block_t
    if pad_t:
        k_pool = jnp.pad(k_pool, ((0, pad_t), (0, 0), (0, 0)))
        v_pool = jnp.pad(v_pool, ((0, pad_t), (0, 0), (0, 0)))
    n_tiles = (t_pool + pad_t) // block_t

    q4 = q.reshape(b, hkv, g, d)
    starts_tiles = (starts // block_t).astype(jnp.int32)
    lens = lens.astype(jnp.int32)

    def kv_index(bb, hh, tt, starts_t, lens_t):
        return (jnp.minimum(starts_t[bb] + tt, n_tiles - 1), hh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, max_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bb, hh, tt, s, l: (bb, hh, 0, 0)),
            pl.BlockSpec((block_t, 1, d), kv_index),
            pl.BlockSpec((block_t, 1, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bb, hh, tt, s, l: (bb, hh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, sm_scale=sm_scale,
                          max_tiles=max_tiles),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(starts_tiles, lens, q4, k_pool, v_pool)
    return out.reshape(b, hq, d)
