"""Pallas TPU kernel: batched decayed-histogram update (scatter-add).

The observe half of the paper's loop, on device: a `DeviceSizeSketch`
keeps a dense per-bucket weight vector resident in accelerator memory;
this kernel ingests one whole batch of bucketed sizes per launch —
decaying the existing state by the batch's total decay and scatter-adding
the (already per-item-decayed) batch weights — so a serving step can feed
thousands of observed sizes without a single device→host transfer.

TPU mapping: scatter is hostile to the VPU, so the add is expressed as a
compare/accumulate sweep — the same idiom as `waste_eval`. We tile
(BINS, N) into (BLOCK_BINS, BLOCK_N) pieces; each grid step holds one
(1, BLOCK_BINS) slice of the state/output and one (1, BLOCK_N) slice of
the batch, builds the `bucket_id == batch_index` hit mask with a
broadcasted iota, and accumulates `sum_i w_i * hit(i, b)` into the
revisited output block across the inner batch grid dimension (TPU grids
run sequentially, so `+=` into the output block is the standard
reduction idiom). The decay multiply of the carried state happens once,
at the first batch block.

VMEM at defaults (BLOCK_BINS=512, BLOCK_N=128): hit mask
128*512*4 = 256 KiB of temporaries, state/batch slices a few KiB —
comfortably inside the budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_BINS = 512
BLOCK_N = 128


def _sketch_update_kernel(state_ref, decay_ref, idx_ref, w_ref, out_ref):
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        # One decay step per observed item: the whole batch's decay is
        # folded into a single multiply of the carried state.
        out_ref[...] = state_ref[...] * decay_ref[0, 0]

    bins = out_ref.shape[1]
    first = pl.program_id(0) * bins
    bucket = first + jax.lax.broadcasted_iota(jnp.int32, (1, bins), 1)
    idx = idx_ref[0, :]                     # (BLOCK_N,) bucket ids, -1 = pad
    w = w_ref[0, :]                         # (BLOCK_N,) decayed item weights
    hits = idx[:, None] == bucket           # (BLOCK_N, BLOCK_BINS)
    out_ref[...] += jnp.sum(jnp.where(hits, w[:, None], 0.0),
                            axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sketch_update_pallas(state, bucket_idx, weights, decay_total, *,
                         interpret: bool = False) -> jnp.ndarray:
    """(BINS,) f32 state x (N,) int32 bucket ids x (N,) f32 weights
    -> (BINS,) f32 new state.

    ``new[b] = state[b] * decay_total + sum_{i: idx_i == b} w_i``.
    Callers fold the within-batch decay schedule into ``weights``
    (item i of an n-item batch carries ``decay ** (n-1-i)``) and pass
    ``decay_total = decay ** n``, which makes the launch bit-equivalent
    to n sequential host observations. Pads BINS to BLOCK_BINS and N to
    BLOCK_N (padding gets bucket id -1, which no bucket matches).
    """
    state = state.astype(jnp.float32)
    bucket_idx = bucket_idx.astype(jnp.int32)
    weights = weights.astype(jnp.float32)
    bins = state.shape[0]
    n = bucket_idx.shape[0]

    bins_pad = (-bins) % BLOCK_BINS
    n_pad = (-n) % BLOCK_N
    if bins_pad:
        state = jnp.pad(state, (0, bins_pad))
    if n_pad:
        bucket_idx = jnp.pad(bucket_idx, (0, n_pad), constant_values=-1)
        weights = jnp.pad(weights, (0, n_pad))
    bp, np_ = bins + bins_pad, n + n_pad

    grid = (bp // BLOCK_BINS, np_ // BLOCK_N)
    out = pl.pallas_call(
        _sketch_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_BINS), lambda i, j: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, BLOCK_N), lambda i, j: (0, j)),
            pl.BlockSpec((1, BLOCK_N), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_BINS), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, bp), jnp.float32),
        interpret=interpret,
    )(state[None, :],
      jnp.asarray(decay_total, dtype=jnp.float32).reshape(1, 1),
      bucket_idx[None, :], weights[None, :])
    return out[0, :bins]


@functools.partial(jax.jit, static_argnames=())
def sketch_update_ref(state, bucket_idx, weights, decay_total) -> jnp.ndarray:
    """Pure-jnp oracle (and CPU fallback) for ``sketch_update_pallas``."""
    state = state.astype(jnp.float32)
    decayed = state * jnp.asarray(decay_total, dtype=jnp.float32)
    valid = (bucket_idx >= 0) & (bucket_idx < state.shape[0])
    idx = jnp.where(valid, bucket_idx, 0).astype(jnp.int32)
    w = jnp.where(valid, weights.astype(jnp.float32), 0.0)
    return decayed.at[idx].add(w)


# ---------------------------------------------------------------------------
# Fused observe window: one dispatch per cadence window
# ---------------------------------------------------------------------------

# Trace-time side-effect counter: bumped once per (re)trace of a window
# scan, so tests can assert that feeding many same-shaped windows does
# NOT recompile (the dispatch-count regression test).
WINDOW_TRACE_COUNT = 0


def _bucketize(sizes_row, bucket_width: int, num_buckets: int):
    """Device-side bucket ids: ``ceil(s / width) - 1`` clipped into the
    grid; negative sizes map to -1, which the scatter ignores. Same
    mapping as ``DeviceSizeSketch.bucket_of`` — moved inside the jit so
    the host hands over RAW sizes."""
    s = sizes_row.astype(jnp.int32)
    idx = -(-s // jnp.int32(bucket_width)) - 1
    return jnp.where(s < 0, -1, jnp.clip(idx, 0, num_buckets - 1))


def _window_scan(state, sizes, weights, lengths, decay, decay_totals, *,
                 bucket_width: int, update):
    """``lax.scan`` over a stacked ``(B, N)`` chunk of observe batches,
    threading the sketch state through one ``update`` step per batch.

    Row semantics match B sequential ``observe_many`` calls exactly:
    item i of row b's ``lengths[b]``-item batch carries
    ``decay ** (lengths[b]-1-i)`` and the carried state decays once by
    ``decay_totals[b]`` (host-computed ``decay ** lengths[b]``, so the
    float64→float32 rounding matches the per-batch path bit-for-bit).
    Positions at or past ``lengths[b]`` are dead: bucket id -1, weight
    exactly 0.0 — and a zero-length row is an exact no-op, which makes
    padding B up to a stable shape free. The decay exponent is clamped
    at 0 on dead positions so ``decay ** huge`` can never underflow
    into an ``inf * 0`` NaN.
    """
    global WINDOW_TRACE_COUNT
    WINDOW_TRACE_COUNT += 1
    num_buckets = state.shape[0]
    pos = jnp.arange(sizes.shape[1], dtype=jnp.int32)
    decay = jnp.asarray(decay, dtype=jnp.float32)

    def step(st, xs):
        s_row, w_row, n, dtot = xs
        live = pos < n
        idx = jnp.where(live, _bucketize(s_row, bucket_width, num_buckets),
                        -1)
        expo = jnp.maximum(n - 1 - pos, 0).astype(jnp.float32)
        w = jnp.where(live, w_row.astype(jnp.float32) * jnp.power(decay,
                                                                  expo),
                      0.0)
        return update(st, idx, w, dtot), None

    out, _ = jax.lax.scan(
        step, state.astype(jnp.float32),
        (sizes, weights, lengths.astype(jnp.int32),
         decay_totals.astype(jnp.float32)))
    return out


@functools.partial(jax.jit, static_argnames=("bucket_width", "interpret"))
def sketch_window_pallas(state, sizes, weights, lengths, decay,
                         decay_totals, *, bucket_width: int = 1,
                         interpret: bool = False) -> jnp.ndarray:
    """(BINS,) state x (B, N) raw sizes -> new state, ONE dispatch.

    The scanned-window variant of ``sketch_update_pallas``: bucketize +
    per-item decay + B kernel steps compile into a single XLA program,
    so a whole cadence window of observe batches costs one launch
    instead of B. ``lengths[b]`` is row b's real batch length (rows are
    right-padded); see ``_window_scan`` for the exact equivalence
    contract.

    Rounding contract: results are BIT-identical to B per-batch
    launches whenever N matches what each per-batch launch padded to —
    i.e. all batch lengths fall in one BLOCK_N pad band (uniform
    serving batches always do). Across bands the padded grid shape
    changes, and XLA does not promise identical rounding across
    different programs: expect ~1 f32 ulp of drift on the kernel
    engine. ``sketch_window_ref`` is bit-stable for any raggedness
    (scatter order is index-determined; zero pads are exact no-ops).
    """
    return _window_scan(
        state, sizes, weights, lengths, decay, decay_totals,
        bucket_width=bucket_width,
        update=lambda st, idx, w, dt: sketch_update_pallas(
            st, idx, w, dt, interpret=interpret))


@functools.partial(jax.jit, static_argnames=("bucket_width",))
def sketch_window_ref(state, sizes, weights, lengths, decay,
                      decay_totals, *, bucket_width: int = 1) -> jnp.ndarray:
    """Pure-jnp oracle for ``sketch_window_pallas`` — and the engine of
    choice off-TPU, where a compiled scatter beats the interpret-mode
    kernel by orders of magnitude."""
    return _window_scan(state, sizes, weights, lengths, decay,
                        decay_totals, bucket_width=bucket_width,
                        update=sketch_update_ref)
