"""Pallas TPU kernel: batched decayed-histogram update (scatter-add).

The observe half of the paper's loop, on device: a `DeviceSizeSketch`
keeps a dense per-bucket weight vector resident in accelerator memory;
this kernel ingests one whole batch of bucketed sizes per launch —
decaying the existing state by the batch's total decay and scatter-adding
the (already per-item-decayed) batch weights — so a serving step can feed
thousands of observed sizes without a single device→host transfer.

TPU mapping: scatter is hostile to the VPU, so the add is expressed as a
compare/accumulate sweep — the same idiom as `waste_eval`. We tile
(BINS, N) into (BLOCK_BINS, BLOCK_N) pieces; each grid step holds one
(1, BLOCK_BINS) slice of the state/output and one (1, BLOCK_N) slice of
the batch, builds the `bucket_id == batch_index` hit mask with a
broadcasted iota, and accumulates `sum_i w_i * hit(i, b)` into the
revisited output block across the inner batch grid dimension (TPU grids
run sequentially, so `+=` into the output block is the standard
reduction idiom). The decay multiply of the carried state happens once,
at the first batch block.

VMEM at defaults (BLOCK_BINS=512, BLOCK_N=128): hit mask
128*512*4 = 256 KiB of temporaries, state/batch slices a few KiB —
comfortably inside the budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_BINS = 512
BLOCK_N = 128


def _sketch_update_kernel(state_ref, decay_ref, idx_ref, w_ref, out_ref):
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        # One decay step per observed item: the whole batch's decay is
        # folded into a single multiply of the carried state.
        out_ref[...] = state_ref[...] * decay_ref[0, 0]

    bins = out_ref.shape[1]
    first = pl.program_id(0) * bins
    bucket = first + jax.lax.broadcasted_iota(jnp.int32, (1, bins), 1)
    idx = idx_ref[0, :]                     # (BLOCK_N,) bucket ids, -1 = pad
    w = w_ref[0, :]                         # (BLOCK_N,) decayed item weights
    hits = idx[:, None] == bucket           # (BLOCK_N, BLOCK_BINS)
    out_ref[...] += jnp.sum(jnp.where(hits, w[:, None], 0.0),
                            axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sketch_update_pallas(state, bucket_idx, weights, decay_total, *,
                         interpret: bool = False) -> jnp.ndarray:
    """(BINS,) f32 state x (N,) int32 bucket ids x (N,) f32 weights
    -> (BINS,) f32 new state.

    ``new[b] = state[b] * decay_total + sum_{i: idx_i == b} w_i``.
    Callers fold the within-batch decay schedule into ``weights``
    (item i of an n-item batch carries ``decay ** (n-1-i)``) and pass
    ``decay_total = decay ** n``, which makes the launch bit-equivalent
    to n sequential host observations. Pads BINS to BLOCK_BINS and N to
    BLOCK_N (padding gets bucket id -1, which no bucket matches).
    """
    state = state.astype(jnp.float32)
    bucket_idx = bucket_idx.astype(jnp.int32)
    weights = weights.astype(jnp.float32)
    bins = state.shape[0]
    n = bucket_idx.shape[0]

    bins_pad = (-bins) % BLOCK_BINS
    n_pad = (-n) % BLOCK_N
    if bins_pad:
        state = jnp.pad(state, (0, bins_pad))
    if n_pad:
        bucket_idx = jnp.pad(bucket_idx, (0, n_pad), constant_values=-1)
        weights = jnp.pad(weights, (0, n_pad))
    bp, np_ = bins + bins_pad, n + n_pad

    grid = (bp // BLOCK_BINS, np_ // BLOCK_N)
    out = pl.pallas_call(
        _sketch_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_BINS), lambda i, j: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, BLOCK_N), lambda i, j: (0, j)),
            pl.BlockSpec((1, BLOCK_N), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_BINS), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, bp), jnp.float32),
        interpret=interpret,
    )(state[None, :],
      jnp.asarray(decay_total, dtype=jnp.float32).reshape(1, 1),
      bucket_idx[None, :], weights[None, :])
    return out[0, :bins]


@functools.partial(jax.jit, static_argnames=())
def sketch_update_ref(state, bucket_idx, weights, decay_total) -> jnp.ndarray:
    """Pure-jnp oracle (and CPU fallback) for ``sketch_update_pallas``."""
    state = state.astype(jnp.float32)
    decayed = state * jnp.asarray(decay_total, dtype=jnp.float32)
    valid = (bucket_idx >= 0) & (bucket_idx < state.shape[0])
    idx = jnp.where(valid, bucket_idx, 0).astype(jnp.int32)
    w = jnp.where(valid, weights.astype(jnp.float32), 0.0)
    return decayed.at[idx].add(w)
