"""Public jit'd wrappers for the Pallas kernels.

On TPU the kernels run compiled; everywhere else they fall back to
``interpret=True`` (the kernel body executed step-by-step on CPU), which
is how this repo validates them. Callers can force either mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.distribution import PAGE_SIZE
from repro.kernels.kv_scatter import kv_append_pallas, kv_chunk_copy_pallas
from repro.kernels.sketch_update import sketch_update_pallas
from repro.kernels.slab_attention import slab_decode_attention_pallas
from repro.kernels.waste_eval import waste_eval_fleet_pallas, waste_eval_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def sketch_update(state, bucket_idx, weights, decay_total, *,
                  interpret: bool | None = None) -> jnp.ndarray:
    """(BINS,) decayed histogram state + (N,) bucketed batch -> new state."""
    if interpret is None:
        interpret = _default_interpret()
    return sketch_update_pallas(jnp.asarray(state), jnp.asarray(bucket_idx),
                                jnp.asarray(weights), decay_total,
                                interpret=interpret)


def waste_eval(chunk_batch, support, freqs, *, page_size: int = PAGE_SIZE,
               interpret: bool | None = None) -> jnp.ndarray:
    """(B, K) candidate schedules -> (B,) waste, via the Pallas kernel."""
    if interpret is None:
        interpret = _default_interpret()
    return waste_eval_pallas(jnp.asarray(chunk_batch),
                             jnp.asarray(support), jnp.asarray(freqs),
                             page_size=page_size, interpret=interpret)


def waste_eval_fleet(chunk_batch, supports, freqs, *,
                     page_size: int = PAGE_SIZE,
                     interpret: bool | None = None) -> jnp.ndarray:
    """(B, K) schedules x (B, S) per-row histograms -> (B,) waste — the
    one-launch fleet scorer behind ``TenantArbiter``'s batched checks."""
    if interpret is None:
        interpret = _default_interpret()
    return waste_eval_fleet_pallas(jnp.asarray(chunk_batch),
                                   jnp.asarray(supports),
                                   jnp.asarray(freqs),
                                   page_size=page_size, interpret=interpret)


def kv_append(pool, rows, vals, *,
              interpret: bool | None = None) -> jnp.ndarray:
    """Batched one-row-per-sequence KV scatter, in place (-1 rows skip;
    see kv_scatter's junk-range contract)."""
    if interpret is None:
        interpret = _default_interpret()
    return kv_append_pallas(jnp.asarray(pool), jnp.asarray(rows),
                            jnp.asarray(vals), interpret=interpret)


def kv_chunk_copy(pool, src_starts, dst_starts, n_tokens, *,
                  max_copy_tokens: int,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Batched contiguous chunk moves inside a KV pool, in place (the
    class-overflow reallocation path; tile-granular, array order)."""
    if interpret is None:
        interpret = _default_interpret()
    return kv_chunk_copy_pallas(jnp.asarray(pool), jnp.asarray(src_starts),
                                jnp.asarray(dst_starts),
                                jnp.asarray(n_tokens),
                                max_copy_tokens=max_copy_tokens,
                                interpret=interpret)


def slab_decode_attention(q, k_pool, v_pool, starts, lens, *,
                          max_chunk_tokens: int, block_t: int = 128,
                          sm_scale: float | None = None,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Flash-decoding over a contiguous slab KV pool (see slab_attention)."""
    if interpret is None:
        interpret = _default_interpret()
    return slab_decode_attention_pallas(
        q, k_pool, v_pool, jnp.asarray(starts), jnp.asarray(lens),
        max_chunk_tokens=max_chunk_tokens, block_t=block_t,
        sm_scale=sm_scale, interpret=interpret)
