"""Pallas TPU kernels: batched KV-pool scatter ops (append + chunk copy).

The offline harness's decode tick is ONE jitted dispatch for the whole
active batch (see serving/offline_harness.py). The two host loops that
used to force per-request dispatches become device scatters here:

* token append — each active sequence writes its freshly decoded KV row
  at ``starts[b] + lens[b]`` (:func:`kv_append_pallas`);
* class-overflow reallocation — sequences that outgrew their slab class
  copy their whole chunk to the new class's range
  (:func:`kv_chunk_copy_pallas`).

Both express the scatter through dynamic BlockSpec index maps steered by
scalar-prefetched descriptors — the same grid-as-gather idiom
``slab_attention`` uses for its KV window, turned around to write — with
the pool aliased input→output (``input_output_aliases``) so unvisited
rows keep their content and the op is in-place on device.

Skip contract (shared by both kernels): batch slots are padded to a
fixed size (RT001 — one traced shape per pool), and padded/inactive
entries are routed to a reserved junk range at the END of the pool. A
skipped entry's index map points both its read and its write at the
junk range, so it rewrites that range with its own content — a no-op.
Callers must therefore never place real data in the last
``max(block rows)`` of the pool; the harness pads its device pools past
``pool_tokens`` so the allocator can never hand that range out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_T = 128   # copy tile, tokens; matches slab_attention / pool ALIGN


def _append_kernel(rows_ref, pool_ref, val_ref, out_ref):
    b = pl.program_id(0)
    write = rows_ref[b] >= 0
    out_ref[...] = jnp.where(write, val_ref[...], pool_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def kv_append_pallas(pool, rows, vals, *, interpret: bool = False
                     ) -> jnp.ndarray:
    """Scatter one new KV row per batch slot into a token pool, in place.

    pool: (T, H, D); rows: (B,) int32 destination token row per slot,
    ``-1`` = inactive slot (skip); vals: (B, H, D). Returns the pool
    with ``pool[rows[b]] = vals[b]`` for every non-negative row and
    every other row bit-unchanged (the pool buffer is aliased into the
    output, so only visited blocks are written).

    Live rows must be distinct — each sequence appends inside its own
    chunk. Skipped slots park on the reserved LAST row (T-1); see the
    module docstring's junk-range contract.
    """
    t, h, d = pool.shape
    rows = rows.astype(jnp.int32)
    vals = vals.astype(pool.dtype)
    b = rows.shape[0]

    def row_index(bb, rows_t):
        r = rows_t[bb]
        return (jnp.clip(jnp.where(r < 0, t - 1, r), 0, t - 1), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, d), row_index),
            pl.BlockSpec((1, h, d), lambda bb, rows_t: (bb, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), row_index),
    )
    # aliasing indices count the scalar-prefetch arg: operands are
    # (rows, pool, vals) -> pool is input 1, aliased onto output 0
    return pl.pallas_call(
        _append_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(rows, pool, vals)


@jax.jit
def kv_append_ref(pool, rows, vals) -> jnp.ndarray:
    """jnp oracle for :func:`kv_append_pallas` — identical semantics
    including the junk-row parking (a skipped slot re-writes row T-1
    with its own current content, a no-op)."""
    t = pool.shape[0]
    rows = rows.astype(jnp.int32)
    valid = rows >= 0
    idx = jnp.clip(jnp.where(valid, rows, t - 1), 0, t - 1)
    upd = jnp.where(valid[:, None, None], vals.astype(pool.dtype),
                    pool[idx])
    return pool.at[idx].set(upd)


def _chunk_copy_kernel(src_ref, dst_ref, lens_ref, pool_ref, out_ref):
    del src_ref, dst_ref, lens_ref
    out_ref[...] = pool_ref[...]


@functools.partial(
    jax.jit, static_argnames=("max_copy_tokens", "block_t", "interpret"))
def kv_chunk_copy_pallas(pool, src_starts, dst_starts, n_tokens, *,
                         max_copy_tokens: int, block_t: int = BLOCK_T,
                         interpret: bool = False) -> jnp.ndarray:
    """Batched contiguous range copies inside a token pool, in place.

    pool: (T, H, D) with T a multiple of ``block_t``; src_starts /
    dst_starts / n_tokens: (M,) int32 move descriptors — copy
    ``n_tokens[m]`` tokens from ``src_starts[m]`` to ``dst_starts[m]``.
    Starts must be ``block_t``-aligned (slab chunk starts are) and
    copies are TILE-granular: ``n_tokens`` is rounded UP to whole
    ``block_t`` tiles (slab classes are tile multiples, so real moves
    never see the rounding). ``n_tokens[m] == 0`` skips the move.

    Moves execute in array order (= grid order), so a later move may
    overwrite a range an earlier move READ — the WAR pattern
    class-overflow reallocation produces (the allocator frees the old
    chunk before carving the new one, and a tick's moves are issued in
    the order the allocator processed them). No move may read a range
    another move of the same call WRITES. Tiles past a move's length
    (and skipped moves) park on the reserved LAST tile — see the module
    docstring's junk-range contract: the final ``block_t`` rows of the
    pool must never hold real data.
    """
    t, h, d = pool.shape
    if t % block_t:
        raise ValueError(f"pool rows {t} not a multiple of {block_t}")
    n_tiles = t // block_t
    max_tiles = -(-max_copy_tokens // block_t)
    src_tiles = (src_starts // block_t).astype(jnp.int32)
    dst_tiles = (dst_starts // block_t).astype(jnp.int32)
    n_tokens = n_tokens.astype(jnp.int32)
    m = src_tiles.shape[0]

    def src_index(mm, tt, src_t, dst_t, len_t):
        live = tt * block_t < len_t[mm]
        return (jnp.clip(jnp.where(live, src_t[mm] + tt, n_tiles - 1),
                         0, n_tiles - 1), 0, 0)

    def dst_index(mm, tt, src_t, dst_t, len_t):
        live = tt * block_t < len_t[mm]
        return (jnp.clip(jnp.where(live, dst_t[mm] + tt, n_tiles - 1),
                         0, n_tiles - 1), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(m, max_tiles),
        in_specs=[pl.BlockSpec((block_t, h, d), src_index)],
        out_specs=pl.BlockSpec((block_t, h, d), dst_index),
    )
    # operands are (src_tiles, dst_tiles, n_tokens, pool): pool is
    # input 3 (scalar-prefetch args count), aliased onto output 0
    return pl.pallas_call(
        _chunk_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(src_tiles, dst_tiles, n_tokens, pool)


@functools.partial(jax.jit,
                   static_argnames=("max_copy_tokens", "block_t"))
def kv_chunk_copy_ref(pool, src_starts, dst_starts, n_tokens, *,
                      max_copy_tokens: int, block_t: int = BLOCK_T
                      ) -> jnp.ndarray:
    """jnp oracle for :func:`kv_chunk_copy_pallas`: sequential moves in
    array order, tile-granular lengths (``n_tokens`` rounded up to
    ``block_t``), untouched rows preserved."""
    t, h, d = pool.shape
    m = src_starts.shape[0]
    src_starts = src_starts.astype(jnp.int32)
    dst_starts = dst_starts.astype(jnp.int32)
    tiled = (((n_tokens.astype(jnp.int32) + block_t - 1) // block_t)
             * block_t)
    pos = jnp.arange(max_copy_tokens, dtype=jnp.int32)

    def body(i, p):
        src = jnp.clip(src_starts[i], 0, t - max_copy_tokens)
        dst = jnp.clip(dst_starts[i], 0, t - max_copy_tokens)
        blk = jax.lax.dynamic_slice(p, (src, 0, 0),
                                    (max_copy_tokens, h, d))
        cur = jax.lax.dynamic_slice(p, (dst, 0, 0),
                                    (max_copy_tokens, h, d))
        mask = (pos < tiled[i])[:, None, None]
        return jax.lax.dynamic_update_slice(
            p, jnp.where(mask, blk, cur), (dst, 0, 0))

    return jax.lax.fori_loop(0, m, body, pool)
