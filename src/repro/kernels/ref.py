"""Pure-jnp oracles for the Pallas kernels (the correctness contracts)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.distribution import PAGE_SIZE


def waste_eval_ref(chunk_batch, support, freqs, *,
                   page_size: int = PAGE_SIZE) -> jnp.ndarray:
    """(B, K) schedules x (S,) histogram -> (B,) float32 waste.

    Independent restatement of repro.core.waste semantics: each size goes
    to its smallest covering chunk; uncovered sizes are charged
    ``ceil(s / page_size)`` whole pages (never a negative amount). Rows
    of ``chunk_batch`` need not be sorted.
    """
    chunks = jnp.sort(chunk_batch.astype(jnp.float32), axis=1)  # (B, K)
    s = support.astype(jnp.float32)[None, None, :]              # (1,1,S)
    c = chunks[:, :, None]                                      # (B,K,1)
    covered = c >= s
    assigned = jnp.min(jnp.where(covered, c, jnp.inf), axis=1)  # (B,S)
    pages = jnp.maximum(jnp.ceil(s[0] / jnp.float32(page_size)), 1.0)
    w = jnp.where(jnp.isfinite(assigned), assigned - s[0],
                  pages * jnp.float32(page_size) - s[0])
    return jnp.sum(w * freqs.astype(jnp.float32)[None, :], axis=1)


def slab_decode_attention_ref(q, k_pool, v_pool, starts, lens, *,
                              sm_scale: float | None = None) -> jnp.ndarray:
    """Decode attention over a contiguous slab KV pool — oracle.

    q:       (B, Hq, D)   one new token per sequence
    k_pool:  (T, Hkv, D)  contiguous token pool (all sequences interleaved)
    v_pool:  (T, Hkv, D)
    starts:  (B,) int32   first pool token of each sequence's slab chunk
    lens:    (B,) int32   real KV length of each sequence
    returns: (B, Hq, D)

    GQA: Hq must be a multiple of Hkv; query head h attends with kv head
    h // (Hq // Hkv).
    """
    b, hq, d = q.shape
    t, hkv, _ = k_pool.shape
    g = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    pos = jnp.arange(t, dtype=jnp.int32)[None, :]               # (1, T)
    valid = (pos >= starts[:, None]) & (pos < starts[:, None]
                                        + lens[:, None])        # (B, T)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    kf = k_pool.astype(jnp.float32)
    vf = v_pool.astype(jnp.float32)
    # scores: (B, Hkv, G, T)
    scores = jnp.einsum("bhgd,thd->bhgt", qf, kf) * sm_scale
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = _softmax(scores)
    out = jnp.einsum("bhgt,thd->bhgd", p, vf)
    return out.reshape(b, hq, d).astype(q.dtype)


def slab_decode_attention_window_ref(q, k_pool, v_pool, starts, lens, *,
                                     max_chunk_tokens: int,
                                     sm_scale: float | None = None
                                     ) -> jnp.ndarray:
    """:func:`slab_decode_attention_ref` restricted to each sequence's
    chunk window: gathers ``max_chunk_tokens`` rows at ``starts[b]`` and
    runs the same masked softmax there. Because a sequence's valid rows
    all live inside its chunk (``lens <= max_chunk_tokens``), the valid
    score set is identical to the full-pool oracle's — this is the
    batch-vectorized form the offline harness serves with on backends
    where the Pallas kernel would run in interpret mode."""
    b, hq, d = q.shape
    t, hkv, _ = k_pool.shape
    g = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    w = jnp.arange(max_chunk_tokens, dtype=jnp.int32)
    idx = jnp.clip(starts.astype(jnp.int32)[:, None] + w[None, :],
                   0, t - 1)                                    # (B, W)
    valid = w[None, :] < lens[:, None]                          # (B, W)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    kf = k_pool[idx].astype(jnp.float32)                        # (B,W,Hkv,D)
    vf = v_pool[idx].astype(jnp.float32)
    scores = jnp.einsum("bhgd,bwhd->bhgw", qf, kf) * sm_scale
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = _softmax(scores)
    out = jnp.einsum("bhgw,bwhd->bhgd", p, vf)
    return out.reshape(b, hq, d).astype(q.dtype)


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    # guard fully-masked rows (empty sequences): max = -inf -> output 0
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(x - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return jnp.where(denom > 0, e / jnp.maximum(denom, 1e-30), 0.0)
