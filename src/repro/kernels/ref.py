"""Pure-jnp oracles for the Pallas kernels (the correctness contracts)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.distribution import PAGE_SIZE


def waste_eval_ref(chunk_batch, support, freqs, *,
                   page_size: int = PAGE_SIZE) -> jnp.ndarray:
    """(B, K) schedules x (S,) histogram -> (B,) float32 waste.

    Independent restatement of repro.core.waste semantics: each size goes
    to its smallest covering chunk; uncovered sizes are charged
    ``ceil(s / page_size)`` whole pages (never a negative amount). Rows
    of ``chunk_batch`` need not be sorted.
    """
    chunks = jnp.sort(chunk_batch.astype(jnp.float32), axis=1)  # (B, K)
    s = support.astype(jnp.float32)[None, None, :]              # (1,1,S)
    c = chunks[:, :, None]                                      # (B,K,1)
    covered = c >= s
    assigned = jnp.min(jnp.where(covered, c, jnp.inf), axis=1)  # (B,S)
    pages = jnp.maximum(jnp.ceil(s[0] / jnp.float32(page_size)), 1.0)
    w = jnp.where(jnp.isfinite(assigned), assigned - s[0],
                  pages * jnp.float32(page_size) - s[0])
    return jnp.sum(w * freqs.astype(jnp.float32)[None, :], axis=1)


def slab_decode_attention_ref(q, k_pool, v_pool, starts, lens, *,
                              sm_scale: float | None = None) -> jnp.ndarray:
    """Decode attention over a contiguous slab KV pool — oracle.

    q:       (B, Hq, D)   one new token per sequence
    k_pool:  (T, Hkv, D)  contiguous token pool (all sequences interleaved)
    v_pool:  (T, Hkv, D)
    starts:  (B,) int32   first pool token of each sequence's slab chunk
    lens:    (B,) int32   real KV length of each sequence
    returns: (B, Hq, D)

    GQA: Hq must be a multiple of Hkv; query head h attends with kv head
    h // (Hq // Hkv).
    """
    b, hq, d = q.shape
    t, hkv, _ = k_pool.shape
    g = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    pos = jnp.arange(t, dtype=jnp.int32)[None, :]               # (1, T)
    valid = (pos >= starts[:, None]) & (pos < starts[:, None]
                                        + lens[:, None])        # (B, T)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    kf = k_pool.astype(jnp.float32)
    vf = v_pool.astype(jnp.float32)
    # scores: (B, Hkv, G, T)
    scores = jnp.einsum("bhgd,thd->bhgt", qf, kf) * sm_scale
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = _softmax(scores)
    out = jnp.einsum("bhgt,thd->bhgd", p, vf)
    return out.reshape(b, hq, d).astype(q.dtype)


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    # guard fully-masked rows (empty sequences): max = -inf -> output 0
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(x - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return jnp.where(denom > 0, e / jnp.maximum(denom, 1e-30), 0.0)
