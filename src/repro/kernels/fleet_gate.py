"""Fleet-batched drift gate — one launch for every due tenant.

The legacy arbiter runs each due tenant's drift gate as its own device
launch (fused into that tenant's observe-window flush). At fleet scale
that is O(due tenants) dispatches per tick; this module collapses the
whole gate stage into ONE vmapped jitted launch over stacked
``[n_due, num_buckets]`` reference / live-sketch weight matrices,
followed by a single vector readback — the per-stage dispatch
accounting ``TenantArbiter(fleet=True)`` reports as
``n_gate_launches``.

The per-row math is :func:`repro.core.observe._dense_distance` — the
exact traced ops the solo gate (``histogram_distance_device`` and the
fused observe-window flush) runs — so a fleet row computes the same
distance the tenant would have computed alone, up to vmap's reduction
framing (float32 sums may differ in the last ulp; the bit-identical
differential contract is carried by the host-sketch path, and the
device path is held to decision-level parity in ``tests/test_fleet.py``).
"""
from __future__ import annotations

_GATE_CACHE = {}


def _build_gate(metric: str):
    import jax

    from repro.core.observe import _dense_distance

    @jax.jit
    def gate(refs, sketches):
        return jax.vmap(lambda a, b: _dense_distance(a, b, metric))(
            refs, sketches)

    return gate


def drift_gate_fleet(refs, sketches, *, metric: str = "l1"):
    """Drift distance per fleet row, in one jitted launch.

    ``refs`` and ``sketches`` are ``[n, num_buckets]`` stacks of dense
    per-bucket weight vectors (reference vs live, same grid). Returns a
    ``[n]`` device vector of distances in [0, 1]; the caller reads it
    back in one host sync for the whole fleet.
    """
    if metric not in ("l1", "emd"):
        raise ValueError(f"unknown metric {metric!r}")
    fn = _GATE_CACHE.get(metric)
    if fn is None:
        fn = _GATE_CACHE[metric] = _build_gate(metric)
    import jax.numpy as jnp
    refs = jnp.asarray(refs)
    sketches = jnp.asarray(sketches)
    if refs.ndim != 2 or refs.shape != sketches.shape:
        raise ValueError(
            f"need matching [n, buckets] stacks, got {refs.shape} "
            f"vs {sketches.shape}")
    return fn(refs, sketches)
