"""Full paper reproduction: Tables 1-5 with 1M items each.

    PYTHONPATH=src python examples/memcached_repro.py [--fast]

Prints old-vs-new waste per table alongside the paper's reported bytes
and recovered fractions, for the paper-faithful hill climb and the
exact DP optimum (beyond-paper).
"""
import sys

import numpy as np

from repro.core import PAPER_WORKLOADS, SlabPolicy, size_histogram, \
    waste_exact
from repro.memcached import paper_traffic


def main():
    n = 200_000 if "--fast" in sys.argv else 1_000_000
    print(f"{'table':>5} {'method':>10} {'old waste':>13} "
          f"{'new waste':>13} {'rec%':>6} {'paper rec%':>10}")
    for wl in PAPER_WORKLOADS:
        sizes = paper_traffic(wl, n_items=n)
        support, freqs = size_histogram(sizes)
        old = np.asarray(wl.old_chunks)
        w_old = waste_exact(old, support, freqs)
        for method in ("hillclimb", "dp"):
            policy = SlabPolicy(seed=wl.table)
            kwargs = dict(patience=1000, max_steps=150_000) \
                if method == "hillclimb" else {}
            sched = policy.fit(support, freqs, k=len(old), baseline=old,
                               method=method, **kwargs)
            print(f"{wl.table:>5} {method:>10} {w_old:>13,} "
                  f"{sched.waste:>13,} {sched.recovered_frac:>6.1%} "
                  f"{wl.recovered_frac:>10.1%}")
    print("\npaper reported (for reference):")
    for wl in PAPER_WORKLOADS:
        print(f"  table {wl.table}: old={wl.old_waste:,} "
              f"new={wl.new_waste:,} recovered={wl.recovered_frac:.1%}")


if __name__ == "__main__":
    main()
