"""Quickstart: learn slab classes for an observed traffic pattern.

    PYTHONPATH=src python examples/quickstart.py

Generates the paper's Table-1 workload, shows the default Memcached
classes' waste, learns a schedule three ways (paper's Algorithm 1, the
batched parallel climb, and the exact DP), and verifies the result in
the slab-allocator simulator.
"""
import numpy as np

from repro.core import (PAPER_WORKLOADS, SlabPolicy, size_histogram,
                        waste_exact)
from repro.memcached import compare_schedules, paper_traffic, run_workload


def main():
    wl = PAPER_WORKLOADS[0]  # mu=518B, sigma=10.5B
    sizes = paper_traffic(wl, n_items=300_000)
    support, freqs = size_histogram(sizes)
    old = np.asarray(wl.old_chunks)
    print(f"workload: lognormal mu={wl.mu}B sigma={wl.sigma}B, "
          f"{len(sizes):,} items")
    print(f"old (default) classes: {old.tolist()}")
    print(f"old waste: {waste_exact(old, support, freqs):,} bytes\n")

    policy = SlabPolicy(seed=0)
    for method in ("hillclimb", "parallel", "dp"):
        kwargs = dict(patience=1000, max_steps=120_000) \
            if method == "hillclimb" else {}
        sched = policy.fit(support, freqs, k=len(old), baseline=old,
                           method=method, **kwargs)
        print(f"{method:10s}: classes={sched.chunk_sizes.tolist()}")
        print(f"{'':10s}  waste={sched.waste:,} bytes "
              f"(recovered {sched.recovered_frac:.1%}, "
              f"utilization {sched.utilization:.1%})")

    # verify the DP schedule in the simulator (allocator ground truth)
    sched = policy.fit(support, freqs, k=len(old), baseline=old,
                       method="dp")
    sim_old = run_workload(old, sizes)
    sim_new = run_workload(sched.chunk_sizes, sizes)
    print(f"\nsimulator check: old={sim_old.waste:,}B "
          f"new={sim_new.waste:,}B "
          f"(recovered {1 - sim_new.waste / sim_old.waste:.1%})")


if __name__ == "__main__":
    main()
