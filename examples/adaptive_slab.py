"""The paper's loop, closed: watch the controller adapt to a phase shift.

    PYTHONPATH=src python examples/adaptive_slab.py [--fast]

(``--seed`` re-rolls the traffic; ``--fast`` shrinks the stream.)

Streams item sizes that jump between two of the paper's operating points
mid-run (Table 1 -> Table 3), through a live memcached-style allocator:

  observe  — every size lands in a decayed streaming histogram,
  detect   — the controller compares the live sketch against the
             fitting-time histogram (normalized L1 drift),
  refit    — candidate schedules are scored in one batched Pallas
             waste evaluation, then a cost model charges the predicted
             migration evictions against the predicted waste savings,
  reconfigure — approved schedules are applied live with memcached
             `slabs reassign` semantics (victim classes evicted, their
             pages re-carved).

Prints the drift checks as they happen and the final three-way waste
comparison (stock default vs frozen learned schedule vs adaptive).
"""
import argparse

import numpy as np

from repro.core import (ControllerConfig, SlabController, SlabPolicy,
                        default_memcached_schedule,
                        schedule_with_default_tail, size_histogram,
                        uncovered_charge)
from repro.core.distribution import PAPER_WORKLOADS
from repro.memcached import SlabAllocator, phase_shift_traffic


def replay(sizes, chunks, controller=None):
    alloc = SlabAllocator(chunks)
    cum_waste = cum_bytes = 0
    for i, s in enumerate(sizes.tolist()):
        s = int(s)
        idx = alloc.class_for(s)
        cum_waste += (int(alloc.chunk_sizes[idx]) - s if idx is not None
                      else int(uncovered_charge(s)))
        cum_bytes += s
        alloc.set(str(i), s)
        if controller is None:
            continue
        controller.observe(s)
        decision = controller.maybe_refit(
            cost_bytes_fn=lambda c: alloc.migration_cost_bytes(
                schedule_with_default_tail(c)))
        if decision is None:
            continue
        tag = "REFIT" if decision.approved else "hold "
        print(f"  item {i:>7,}: drift={decision.drift:.3f} {tag} "
              f"({decision.reason})")
        if decision.approved:
            deployed = schedule_with_default_tail(decision.chunks)
            report = alloc.reconfigure(deployed)
            controller.set_chunks(deployed)
            print(f"             new classes {decision.chunks.tolist()} — "
                  f"evicted {report.evicted_items:,} items "
                  f"({report.evicted_bytes:,} B), re-carved "
                  f"{report.reassigned_pages} pages")
    return cum_waste / max(cum_bytes, 1), alloc.stats()


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--seed", type=int, default=7,
                    help="traffic RNG seed (default 7)")
    args = ap.parse_args()
    n = 40_000 if args.fast else 200_000
    a, b = PAPER_WORKLOADS[0], PAPER_WORKLOADS[2]
    sizes = phase_shift_traffic(a, b, n_items=n, seed=args.seed)
    print(f"traffic: {n:,} items, mu={a.mu:.0f} -> mu={b.mu:.0f} "
          f"at item {n // 2:,}\n")

    warmup = sizes[:n // 10]
    support, freqs = size_histogram(warmup)
    fit = SlabPolicy().fit(support, freqs, 6, method="dp")
    learned = schedule_with_default_tail(fit.chunk_sizes)
    print(f"warmup fit (k=6): {fit.chunk_sizes.tolist()}")

    cadence = max(1000, n // 40)
    # seed with the DEPLOYED schedule (learned + tail) so the
    # controller's waste comparisons see what the allocator serves
    ctrl = SlabController(learned, config=ControllerConfig(
        k=6, check_every=cadence, half_life=2.0 * cadence,
        drift_threshold=0.12, min_items_between_refits=2 * cadence,
        amortization_windows=8.0, cost_weight=0.1))
    print("\nadaptive run:")
    adaptive, ast = replay(sizes, learned, ctrl)

    default, _ = replay(sizes, default_memcached_schedule())
    static, _ = replay(sizes, learned)
    print(f"\ncumulative waste fraction (charged per insert):")
    print(f"  default geometric : {default:7.2%}")
    print(f"  static learned    : {static:7.2%}")
    print(f"  adaptive          : {adaptive:7.2%}   "
          f"({ast.n_reassigned_pages} pages re-carved, "
          f"{ast.migration_evictions:,} migration evictions)")


if __name__ == "__main__":
    main()
