"""Multi-tenant quickstart: three cache tenants, one page pool, a
global arbiter moving pages to whoever is peaking.

    PYTHONPATH=src python examples/multitenant.py [--fast]

Three tenants with the paper's Table 1/2/3 size distributions share one
physical page pool. Their demand peaks out of phase (raised-cosine
arrival intensity offset by a third of a period each) and items expire
TTL-style, so an off-peak tenant sits on pages full of free chunks
while its neighbour at peak is evicting. Each tenant runs its own
SlabController (the PR-1 observe→drift→refit loop, per tenant); the
TenantArbiter adds the cross-tenant layer:

  pressure  — payload bytes lost to capacity evictions + page denials
              since the last round pick the recipient,
  donor     — the tenant whose coldest page is cheapest to reclaim
              (floor-guarded: never drained below floor_pages),
  score     — benefit = min(pressure, page) * amortization_windows vs
              cost = cost_weight * donor eviction payload (the
              controller's own cost model, applied across tenants),
  execute   — quota moves donor → recipient and the donor's page is
              reclaimed with `slabs reassign` eviction semantics.

Prints each approved transfer as it happens, then compares final memory
holes under static partitioning / pooled free-for-all / arbitration.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import multitenant_bench as mb
from repro.core.distribution import PAPER_WORKLOADS
from repro.memcached import multitenant_phased_ops


def narrated_run(ops, n_tenants, total_pages):
    arb = mb.build_arbiter("arbitrated", n_tenants, total_pages=total_pages)
    seen = 0
    for op in ops:
        if op.op == "set":
            arb.set(f"tenant{op.tenant}", op.key, op.size)
        else:
            arb.delete(f"tenant{op.tenant}", op.key)
        for d in arb.decisions[seen:]:
            if d.approved:
                print(f"  op {arb.n_ops:>7,}: {d.donor} -> {d.recipient}  "
                      f"benefit={d.benefit:>9,.0f}B  "
                      f"cost={d.cost:>7,.0f}B  "
                      f"evicted {d.evicted_items} items")
        seen = len(arb.decisions)
    return arb


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--seed", type=int, default=7,
                    help="op-stream RNG seed (default 7)")
    args = ap.parse_args()
    n_sets = 10_000 if args.fast else 30_000
    # the live working set scales with the stream (TTL ~ period/3), so
    # scale the pool down with --fast to keep tenants contending
    total_pages = max(12, mb.TOTAL_PAGES * n_sets // 30_000)
    workloads = PAPER_WORKLOADS[:3]
    ops = multitenant_phased_ops(workloads, n_sets=n_sets,
                                 trough_mix=0.5, seed=args.seed)
    print(f"{len(ops):,} ops, 3 tenants out of phase, "
          f"{total_pages} x {mb.PAGE_SIZE // 1024} KiB shared pages\n")
    print("arbitrated run (transfers as they happen):")
    arb = narrated_run(ops, 3, total_pages)
    print(f"\n  {arb.n_transfers} transfers; final pages per tenant: "
          + ", ".join(f"{n}={arb.pool.owned(n)}" for n in arb.tenants))
    assert arb.pool.conserved

    print("\nfinal comparison (mean memory-hole fraction of the pool):")
    for mode in mb.MODES:
        r = mb.drive(ops, 3, mode, total_pages=total_pages)
        print(f"  {mode:<10} holes={r['mean_hole_frac']:.4f}  "
              f"evicted={r['evicted_bytes'] / 2**20:6.1f} MiB  "
              f"transfers={r['n_transfers']}")


if __name__ == "__main__":
    main()
