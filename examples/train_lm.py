"""End-to-end training driver: ~100M-param LM, a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Uses the framework end to end on CPU: model zoo (scaled gemma3 family
config), learned length buckets from the data pipeline (the paper's
technique in the data path), AdamW + microbatching, periodic async
checkpoints with restart-resume, and the straggler watchdog.
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.gemma3_1b import CONFIG as GEMMA3_1B
from repro.data import DataConfig, Prefetcher, fit_corpus_buckets, \
    make_batches
from repro.models import build_model
from repro.training import (AdamWConfig, CheckpointManager, StepTimer,
                            TrainConfig, init_train_state, make_train_step)


def small_config(vocab=16384):
    """~100M-param member of the gemma3 family (CPU-trainable)."""
    return dataclasses.replace(
        GEMMA3_1B, name="gemma3-100m", n_layers=8, d_model=1024, n_heads=8,
        n_kv_heads=2, head_dim=128, d_ff=3072, vocab_size=vocab,
        block_pattern=GEMMA3_1B.block_pattern[:8], sliding_window=128,
        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = small_config()
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"model: {cfg.name}, {n_params / 1e6:.1f}M params")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, batch_size=args.batch,
                      max_len=args.seq, length_mean=args.seq * 0.6,
                      length_std=args.seq * 0.25)
    scheme = fit_corpus_buckets(dcfg, 4)
    print(f"learned buckets: {scheme.boundaries.tolist()} "
          f"(padding recovered vs pow2: {scheme.recovered_frac:.1%})")

    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=3e-4, warmup_steps=20, total_steps=args.steps),
        microbatches=2)
    step_fn = jax.jit(make_train_step(model, tcfg))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    state = init_train_state(model.init(jax.random.PRNGKey(0)), tcfg)
    start = 0
    if mgr.latest_step() is not None:
        state = mgr.restore(state)
        start = int(state.opt.step)
        print(f"resumed from checkpoint at step {start}")

    batches = Prefetcher(make_batches(dcfg))
    timer = StepTimer()
    t0 = time.time()
    for i, batch in zip(range(start, args.steps), batches):
        timer.start()
        state, metrics = step_fn(
            state, {"tokens": jnp.asarray(batch["tokens"])})
        straggler = timer.stop(i)
        if (i + 1) % 20 == 0 or i == start:
            print(f"step {i + 1:4d} loss={float(metrics['loss']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f}"
                  f"{'  [straggler]' if straggler else ''}")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state, blocking=False)
    mgr.wait()
    mgr.save(args.steps, state)
    batches.close()
    print(f"done in {time.time() - t0:.0f}s; "
          f"mean step {timer.mean_step_time * 1e3:.0f}ms; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
