"""Serve a small model with batched requests over the learned slab pool.

    PYTHONPATH=src python examples/serve_kv_slab.py [--seed N]

1. Simulates request traffic through the continuous batcher twice —
   pow2 chunk classes vs classes learned from the traffic — and prints
   the HBM fragmentation the paper's technique recovers.
2. Runs REAL batched decoding of a reduced model where every request's
   KV lives in one contiguous learned-class chunk, attended by the
   slab-pool Pallas kernel (interpret mode on CPU), and cross-checks
   the outputs against the dense-cache decode path.
"""
import argparse
import copy

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SlabPolicy, size_histogram
from repro.kernels.ops import slab_decode_attention
from repro.models import get_model
from repro.serving import (ContinuousBatcher, KVSlabPool,
                           default_pow2_classes,
                           lognormal_request_workload, quantize_lengths)


def fragmentation_study(seed: int = 0):
    rng = np.random.default_rng(seed)
    workload = lognormal_request_workload(rng, 400)
    final = quantize_lengths([r.prompt_len + r.output_len
                              for r in workload])
    sup, fr = size_histogram(final)
    sched = SlabPolicy(page_size=1 << 22, min_chunk=128).fit(
        sup, fr, 8, baseline=default_pow2_classes())
    learned = np.unique(quantize_lengths(sched.chunk_sizes))
    print("request traffic: lognormal prompts (mean 2048) + outputs")
    for name, classes in (("pow2", default_pow2_classes()),
                          ("learned", learned)):
        pool = KVSlabPool(2_000_000, classes)
        res = ContinuousBatcher(pool, max_batch=48).run(
            copy.deepcopy(workload), steps=4000)
        print(f"  {name:8s}: classes={list(classes)[:8]}... "
              f"waste={res.mean_waste_fraction:.1%} "
              f"completed={res.completed} copies={res.realloc_copies}")


def kernel_decode_demo(seed: int = 0):
    cfg, model = get_model("deepseek-7b", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    hkv, hd = cfg.n_kv_heads, cfg.head_dim

    # two requests with different contexts in one contiguous pool
    pool = KVSlabPool(4096, (128, 256, 512))
    lens = [100, 230]
    for rid, ln in enumerate(lens):
        pool.alloc(rid, ln)
    starts, lens_arr = pool.kernel_args([0, 1])
    print(f"\nslab pool: starts={starts.tolist()} lens={lens_arr.tolist()} "
          f"chunks={[pool.allocation(r).chunk for r in (0, 1)]}")

    rng = np.random.default_rng(seed + 1)
    k_pool = jnp.asarray(rng.normal(size=(4096, hkv, hd)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(4096, hkv, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(2, cfg.n_heads, hd)), jnp.float32)
    out = slab_decode_attention(
        q, k_pool, v_pool, jnp.asarray(starts), jnp.asarray(lens_arr),
        max_chunk_tokens=pool.max_chunk_tokens)
    # oracle: dense attention per request over its (start, len) window
    from repro.kernels.ref import slab_decode_attention_ref
    want = slab_decode_attention_ref(q, k_pool, v_pool,
                                     jnp.asarray(starts),
                                     jnp.asarray(lens_arr))
    err = float(jnp.max(jnp.abs(out - want)))
    print(f"slab-kernel decode vs oracle: max err {err:.2e} "
          f"({'OK' if err < 1e-4 else 'FAIL'})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--seed", type=int, default=0,
                    help="request-traffic / pool-content RNG seed")
    args = ap.parse_args()
    fragmentation_study(args.seed)
    kernel_decode_demo(args.seed)
