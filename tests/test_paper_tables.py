"""Reproduction of the paper's §5 experiments (Tables 1-5).

Workload calibration (see DESIGN.md §1): (mu, sigma) read as byte-space
moments of the log-normal, n = 1e6 items, zero metadata overhead. Under
this reading our regenerated *old-configuration* waste matches the paper's
reported bytes to ~0.1% on Tables 4 and 5 (the two tables whose structure
pins the workload unambiguously), confirming the calibration; Tables 1-3
agree in magnitude. The learned-schedule comparison is validated on the
paper's scale-invariant headline: fraction of wasted memory recovered,
which the paper reports as 33.65%-55.76%.

These tests run a reduced n (100k) for speed; benchmarks/paper_tables.py
runs the full 1e6-item experiment.
"""
import jax
import numpy as np
import pytest

from repro.core import (PAPER_WORKLOADS, SlabPolicy, dp_optimal,
                        size_histogram, waste_exact)
from repro.memcached import paper_traffic

N_TEST = 100_000


@pytest.fixture(scope="module", params=[w.table for w in PAPER_WORKLOADS])
def workload(request):
    wl = PAPER_WORKLOADS[request.param - 1]
    sizes = paper_traffic(wl, n_items=N_TEST, seed=0)
    support, freqs = size_histogram(sizes)
    return wl, support, freqs


def test_old_config_waste_scales_to_paper(workload):
    """Old-config waste per item is within 2x of the paper's figure for
    every table, and within 5% for Tables 4-5 (the calibration anchors)."""
    wl, support, freqs = workload
    w = waste_exact(wl.old_chunks, support, freqs)
    per_item = w / N_TEST
    paper_per_item = wl.old_waste / 1_000_000
    assert 0.5 * paper_per_item < per_item < 2.0 * paper_per_item
    if wl.table in (4, 5):
        assert per_item == pytest.approx(paper_per_item, rel=0.05)


def test_learned_schedule_beats_paper_band(workload):
    """Our search recovers at least the paper's reported fraction for the
    same table (the paper's result is the floor, not the ceiling)."""
    wl, support, freqs = workload
    policy = SlabPolicy(seed=0)
    sched = policy.fit(support, freqs, k=len(wl.old_chunks),
                       baseline=np.asarray(wl.old_chunks), method="dp")
    assert sched.recovered_frac >= wl.recovered_frac


def test_paper_hillclimb_reaches_band(workload):
    """The paper-faithful Algorithm 1 itself reaches the paper's reported
    recovery band (>= table's fraction) given a comparable step budget."""
    wl, support, freqs = workload
    policy = SlabPolicy(seed=1)
    sched = policy.fit(support, freqs, k=len(wl.old_chunks),
                       baseline=np.asarray(wl.old_chunks),
                       method="hillclimb", patience=1000, max_steps=150_000)
    assert sched.recovered_frac >= wl.recovered_frac


def test_baseline_waste_fraction_around_ten_percent(workload):
    """Paper §1: 'an average 10% wastage in memory' under log-normal
    traffic with the default classes."""
    wl, support, freqs = workload
    policy = SlabPolicy()
    sched = policy.fit(support, freqs, k=len(wl.old_chunks),
                       baseline=np.asarray(wl.old_chunks), method="dp")
    frac = sched.baseline_waste / max(
        int(np.sum(support * freqs)), 1)
    assert 0.03 < frac < 0.30  # ~10%, workload-dependent


def test_new_config_never_uncovers_items(workload):
    wl, support, freqs = workload
    policy = SlabPolicy(seed=0)
    for method in ("dp", "parallel"):
        sched = policy.fit(support, freqs, k=len(wl.old_chunks),
                           baseline=np.asarray(wl.old_chunks),
                           method=method)
        assert sched.chunk_sizes.max() >= support.max()
