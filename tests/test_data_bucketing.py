"""Data pipeline + learned length-bucket tests."""
import numpy as np
import pytest

from repro.core import sample_lognormal_sizes
from repro.data import (BucketScheme, DataConfig, Prefetcher,
                        SyntheticCorpus, batch_by_bucket, fit_buckets,
                        fit_corpus_buckets, make_batches, padding_waste,
                        pow2_buckets)


def test_fit_buckets_beats_pow2():
    rng = np.random.default_rng(0)
    lengths = sample_lognormal_sizes(rng, 50_000, 900.0, 450.0,
                                     max_size=4096)
    scheme = fit_buckets(lengths, 8)
    assert scheme.recovered_frac > 0.3
    assert scheme.boundaries.max() >= lengths.max()


def test_bucket_assignment_covers_all():
    rng = np.random.default_rng(1)
    lengths = rng.integers(1, 1000, 5_000)
    scheme = fit_buckets(lengths, 4)
    padded = scheme.padded_length(lengths)
    assert np.all(padded >= lengths)


def test_more_buckets_less_padding():
    rng = np.random.default_rng(2)
    lengths = sample_lognormal_sizes(rng, 30_000, 500.0, 200.0,
                                     max_size=2048)
    w4 = fit_buckets(lengths, 4).padded_tokens
    w16 = fit_buckets(lengths, 16).padded_tokens
    assert w16 <= w4


def test_padding_waste_consistency():
    lengths = np.asarray([10, 20, 30])
    waste, frac = padding_waste([32], lengths)
    assert waste == (32 - 10) + (32 - 20) + (32 - 30)
    assert frac == pytest.approx(waste / (waste + 60))


def test_batch_by_bucket_partitions_all_samples():
    rng = np.random.default_rng(3)
    lengths = rng.integers(1, 512, 1000)
    scheme = fit_buckets(lengths, 4)
    batches = batch_by_bucket(lengths, scheme, 64)
    seen = np.concatenate([idx for _, idx in batches])
    assert sorted(seen.tolist()) == list(range(1000))
    for bucket_len, idx in batches:
        assert np.all(lengths[idx] <= bucket_len)


def test_corpus_deterministic():
    cfg = DataConfig(vocab_size=1000, batch_size=4, max_len=64, seed=7)
    a = SyntheticCorpus(cfg).sample_lengths(100)
    b = SyntheticCorpus(cfg).sample_lengths(100)
    np.testing.assert_array_equal(a, b)


def test_make_batches_shapes_and_padding():
    cfg = DataConfig(vocab_size=100, batch_size=4, max_len=64,
                     length_mean=30, length_std=10)
    batch = next(make_batches(cfg))
    assert batch["tokens"].shape == (4, 65)
    for i, ln in enumerate(batch["lengths"]):
        assert np.all(batch["tokens"][i, ln:] == 0)  # padded tail


def test_fit_corpus_buckets_independent_probe():
    cfg = DataConfig(vocab_size=100, batch_size=4, max_len=128,
                     length_mean=60, length_std=25, seed=3)
    scheme = fit_corpus_buckets(cfg, 4, n_probe=5_000)
    assert len(scheme.boundaries) <= 4
    assert scheme.boundaries.max() <= cfg.max_len


def test_prefetcher_yields_and_closes():
    cfg = DataConfig(vocab_size=50, batch_size=2, max_len=32)
    pf = Prefetcher(make_batches(cfg))
    batches = [next(pf) for _ in range(3)]
    assert all(b["tokens"].shape == (2, 33) for b in batches)
    pf.close()
