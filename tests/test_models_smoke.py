"""Per-architecture smoke tests on reduced configs (deliverable f).

For each assigned arch: instantiate the family-preserving reduced config,
run one forward/train step on CPU, assert output shapes + no NaNs, and —
the strong check — verify that prefill+decode through the KV/state caches
reproduces the full-sequence forward logits exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model, get_config, get_model, list_archs

ARCHS = list_archs()


def extras_for(cfg, b, rng=None):
    rng = rng or np.random.default_rng(5)
    ex = {}
    if cfg.family == "encdec":
        ex["frames"] = jnp.asarray(
            rng.normal(size=(b, 24, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        ex["image_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_image_tokens, cfg.d_model)),
            jnp.float32)
    return ex


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec


def test_moe_configs():
    mixtral = get_config("mixtral-8x7b")
    assert (mixtral.n_experts, mixtral.experts_per_token) == (8, 2)
    arctic = get_config("arctic-480b")
    assert (arctic.n_experts, arctic.experts_per_token) == (128, 2)
    assert arctic.moe_dense_residual


def test_param_counts_in_range():
    """Parameter formulas land near the advertised sizes."""
    for arch, lo, hi in [("gemma3-1b", 0.8e9, 1.3e9),
                         ("gemma-7b", 7e9, 10e9),
                         ("deepseek-7b", 6e9, 8e9),
                         ("mixtral-8x7b", 42e9, 50e9),
                         ("arctic-480b", 430e9, 520e9),
                         ("xlstm-350m", 0.2e9, 0.5e9)]:
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo},{hi}]"
    mixtral = get_config("mixtral-8x7b")
    assert mixtral.active_param_count() < 0.4 * mixtral.param_count()


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg, model = get_model(arch, reduced=True)
    b, s = 2, 32
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    logits, aux = model.train_logits(params, tokens, extras_for(cfg, b))
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step_no_nans(arch):
    """One SGD step on the reduced config: finite loss and grads."""
    cfg, model = get_model(arch, reduced=True)
    b, s = 2, 16
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                                cfg.vocab_size)
    ex = extras_for(cfg, b)

    def loss_fn(p):
        logits, aux = model.train_logits(p, tokens[:, :-1], ex)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(
            logp, tokens[:, 1:, None], axis=-1).mean()
        return nll + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in leaves)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0
               for g in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    """prefill + decode through the cache == full-sequence forward.

    The strongest cache-correctness property; catches masking, RoPE
    position, rolling-buffer, and state-carry bugs in one assert.
    """
    cfg, model = get_model(arch, reduced=True)
    if cfg.n_experts:
        # generous capacity so no token drops differ between lengths
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        model = build_model(cfg)
    b, s = 2, 17
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                                cfg.vocab_size)
    ex = extras_for(cfg, b)
    full, _ = model.train_logits(params, tokens, ex)
    if cfg.family == "ssm":
        lg, cache = model.prefill(params, tokens[:, :s], ex)
    else:
        lg, cache = model.prefill(params, tokens[:, :s], ex, 32)
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(full[:, s - 1]),
                               rtol=2e-3, atol=2e-3)
    dec, _ = model.decode(params, tokens[:, s:s + 1], cache,
                          jnp.int32(s), ex)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, s]),
                               rtol=2e-3, atol=2e-3)


def test_rolling_window_decode_past_wraparound():
    """mixtral-style all-SWA rolling cache: decoding far past the window
    still matches the full forward (eviction order + position masking)."""
    cfg, model = get_model("mixtral-8x7b", reduced=True)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0, sliding_window=8)
    model = build_model(cfg)
    b, s_total = 1, 40
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s_total), 0,
                                cfg.vocab_size)
    full, _ = model.train_logits(params, tokens, None)
    prompt = 13
    _, cache = model.prefill(params, tokens[:, :prompt], None, 64)
    assert cache["k"].shape[2] == 8  # rolling buffer is window-sized
    for t in range(prompt, s_total):
        dec, cache = model.decode(params, tokens[:, t:t + 1], cache,
                                  jnp.int32(t), None)
        np.testing.assert_allclose(
            np.asarray(dec[:, 0]), np.asarray(full[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"divergence at step {t}")


def test_reduced_keeps_family_structure():
    for arch in ARCHS:
        cfg = get_config(arch)
        red = cfg.reduced()
        assert red.family == cfg.family
        assert set(red.block_pattern) == set(cfg.block_pattern) or \
            not cfg.block_pattern
        if cfg.n_experts:
            assert red.n_experts > 0
