"""End-to-end behaviour tests: the paper's loop running through the
whole system — observe traffic, learn a schedule, deploy it, measure."""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PAPER_WORKLOADS, SlabPolicy, size_histogram,
                        waste_exact)
from repro.memcached import paper_traffic, run_workload


def test_observe_learn_deploy_measure_loop():
    """The full paper pipeline: traffic -> histogram -> learned schedule
    -> redeploy in the allocator -> measured waste drops by the schedule's
    predicted amount (analytic objective == allocator ground truth)."""
    wl = PAPER_WORKLOADS[2]  # mu=2109
    sizes = paper_traffic(wl, n_items=50_000)
    support, freqs = size_histogram(sizes)
    old = np.asarray(wl.old_chunks)

    sched = SlabPolicy(seed=0).fit(support, freqs, k=len(old),
                                   baseline=old, method="dp")
    sim_old = run_workload(old, sizes)
    sim_new = run_workload(sched.chunk_sizes, sizes)
    assert sim_old.waste == sched.baseline_waste
    assert sim_new.waste == sched.waste
    assert sched.recovered_frac >= wl.recovered_frac  # >= paper's band


def test_train_then_serve_same_params():
    """Framework loop: init a zoo model, take two optimizer steps, then
    serve greedy tokens from the trained params through the cache path."""
    from repro.models import get_model
    from repro.serving import generate
    from repro.training import (AdamWConfig, TrainConfig, init_train_state,
                                make_train_step)

    cfg, model = get_model("gemma3-1b", reduced=True)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=1,
                                             total_steps=10),
                       microbatches=2, z_loss=0.0)
    state = init_train_state(model.init(jax.random.PRNGKey(0)), tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0,
                                cfg.vocab_size)
    losses = []
    for _ in range(2):
        state, metrics = step(state, {"tokens": tokens})
        losses.append(float(metrics["loss"]))
    assert losses[1] < losses[0]

    out = generate(model, state.params, tokens[:2, :8], steps=4,
                   max_len=16, jit=False)
    assert out.shape == (2, 4)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_slab_pool_serves_learned_schedule_end_to_end():
    """Serving loop: traffic through the pool, refit online, waste drops."""
    from repro.serving import (ContinuousBatcher, KVSlabPool,
                               default_pow2_classes,
                               lognormal_request_workload)

    rng = np.random.default_rng(0)
    workload = lognormal_request_workload(rng, 150)
    pool = KVSlabPool(2_000_000, default_pow2_classes())
    before_classes = list(pool.chunk_classes)
    batcher = ContinuousBatcher(pool, max_batch=32, refit_every=150)
    res = batcher.run(copy.deepcopy(workload), steps=3000)
    assert res.completed + res.rejected == 150
    assert list(pool.chunk_classes) != before_classes  # refit happened
    assert pool.stats().active_requests == 0
