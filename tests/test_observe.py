"""Observation-layer tests: the host sketch's prune/weight fixes, the
device-resident sketch + Pallas sketch_update kernel (interpret mode on
CPU), the on-device drift metric, and host/device controller parity."""
import numpy as np
import pytest

from repro.analysis.guards import no_implicit_transfers
from repro.core import (ControllerConfig, DecayedSizeHistogram,
                        DeviceSizeSketch, SlabController, SlabPolicy,
                        histogram_distance, histogram_distance_device,
                        schedule_with_default_tail, size_histogram)
from repro.core.distribution import PAPER_WORKLOADS
from repro.memcached import phase_shift_traffic


# -- host sketch regressions -------------------------------------------------

def test_prune_recomputes_total_from_kept_bins():
    """Regression: _prune used to drop bins without subtracting their
    weight from _total, permanently overstating effective_count."""
    h = DecayedSizeHistogram(half_life=50.0, max_bins=32)
    for s in range(1, 200):          # many distinct sizes -> many prunes
        h.observe(s)
    support, weights = h.snapshot_weights()
    assert h.effective_count == pytest.approx(weights.sum(), rel=1e-9)


def test_prune_total_stays_consistent_under_repeated_pressure():
    rng = np.random.default_rng(0)
    h = DecayedSizeHistogram(half_life=200.0, max_bins=64)
    for chunk in np.split(rng.integers(1, 10_000, 4_000), 16):
        h.observe_many(chunk)
        _, weights = h.snapshot_weights()
        assert h.effective_count == pytest.approx(weights.sum(), rel=1e-9)
    # the decayed mass can never exceed the undecayed geometric bound
    decay = 0.5 ** (1.0 / 200.0)
    assert h.effective_count <= 1.0 / (1.0 - decay) + 1e-6


def test_observe_many_weighted_matches_sequential_observe():
    """Regression: observe_many used to silently drop weights."""
    sizes = [10, 20, 10, 30]
    weights = [1.0, 2.5, 0.5, 3.0]
    a = DecayedSizeHistogram(half_life=100.0)
    a.observe_many(sizes, weights)
    b = DecayedSizeHistogram(half_life=100.0)
    for s, w in zip(sizes, weights):
        b.observe(s, w)
    sa, wa = a.snapshot_weights()
    sb, wb = b.snapshot_weights()
    np.testing.assert_array_equal(sa, sb)
    np.testing.assert_allclose(wa, wb, rtol=1e-12)
    assert a.effective_count == pytest.approx(b.effective_count)


def test_observe_many_scalar_weight_broadcasts():
    h = DecayedSizeHistogram()
    h.observe_many([10, 10, 20], 2.0)
    support, freqs = h.snapshot()
    assert support.tolist() == [10, 20]
    assert freqs.tolist() == [4, 2]


# -- device sketch: kernel + parity with the host sketch ---------------------

def test_sketch_update_kernel_matches_oracle():
    from repro.kernels.ops import sketch_update
    from repro.kernels.sketch_update import sketch_update_ref
    rng = np.random.default_rng(3)
    state = rng.random(2000).astype(np.float32)
    idx = rng.integers(0, 2000, 700).astype(np.int32)
    w = rng.random(700).astype(np.float32)
    got = np.asarray(sketch_update(state, idx, w, 0.875, interpret=True))
    want = np.asarray(sketch_update_ref(state, idx, w, 0.875))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_sketch_update_kernel_ignores_padding_ids():
    from repro.kernels.ops import sketch_update
    state = np.zeros(600, dtype=np.float32)
    idx = np.array([5, -1, 5], dtype=np.int32)
    w = np.ones(3, dtype=np.float32)
    out = np.asarray(sketch_update(state, idx, w, 1.0, interpret=True))
    assert out[5] == 2.0 and out.sum() == 2.0


def test_device_sketch_exact_without_decay():
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, 300, 5_000)
    d = DeviceSizeSketch(num_buckets=512)        # no decay, width 1
    d.observe_many(sizes)
    support, freqs = d.snapshot()
    ref_s, ref_f = size_histogram(sizes)
    np.testing.assert_array_equal(support, ref_s)
    np.testing.assert_array_equal(freqs, ref_f)
    assert d.n_observed == 5_000


def test_device_sketch_decay_matches_host_batched():
    rng = np.random.default_rng(1)
    sizes = rng.integers(1, 400, 3_000)
    h = DecayedSizeHistogram(half_life=500.0)
    d = DeviceSizeSketch(half_life=500.0, num_buckets=512)
    for i in range(0, len(sizes), 173):          # ragged batch sizes
        h.observe_many(sizes[i:i + 173])
        d.observe_many(sizes[i:i + 173])
    hs, hw = h.snapshot_weights()
    ds, dw = d.snapshot_weights()
    np.testing.assert_array_equal(hs, ds)
    np.testing.assert_allclose(hw, dw, rtol=2e-5)
    assert d.effective_count == pytest.approx(h.effective_count, rel=1e-4)


def test_device_sketch_weighted_observe():
    h = DecayedSizeHistogram(half_life=100.0)
    d = DeviceSizeSketch(half_life=100.0, num_buckets=64)
    sizes = [10, 20, 10, 30]
    weights = [1.0, 2.5, 0.5, 3.0]
    h.observe_many(sizes, weights)
    d.observe_many(sizes, weights)
    hs, hw = h.snapshot_weights()
    ds, dw = d.snapshot_weights()
    np.testing.assert_array_equal(hs, ds)
    np.testing.assert_allclose(hw, dw, rtol=1e-5)


def test_device_sketch_bucket_width_quantizes_up():
    d = DeviceSizeSketch(num_buckets=32, bucket_width=128)
    d.observe_many([1, 128, 129, 256])
    support, freqs = d.snapshot()
    # 1 -> 128, 128 -> 128, 129 -> 256, 256 -> 256: the representative
    # always covers the item (the direction slab fitting needs)
    assert support.tolist() == [128, 256]
    assert freqs.tolist() == [2, 2]


def test_device_sketch_overflow_clamps_to_top_bucket():
    d = DeviceSizeSketch(num_buckets=16, bucket_width=1)
    d.observe_many([1000, 2000])
    support, freqs = d.snapshot()
    assert support.tolist() == [16]
    assert freqs.tolist() == [2]


def test_device_sketch_negative_dropped_zero_coarsens():
    """The host sketch raises on negatives; raising on device would need
    a readback, so invalid sizes are dropped from the histogram (the
    scatter's ignored pad id). Size 0 — valid on the host — stays
    counted: it coarsens into the first bucket's representative like
    any other in-bucket size."""
    d = DeviceSizeSketch(num_buckets=16, bucket_width=1)
    d.observe_many([-5, 0, 3])
    support, freqs = d.snapshot()
    assert support.tolist() == [1, 3]
    assert freqs.tolist() == [1, 1]


def test_device_sketch_sync_accounting_and_reset():
    d = DeviceSizeSketch(num_buckets=64)
    d.observe_many([1, 2, 3])
    assert d.n_host_syncs == 0                   # observing never syncs
    d.snapshot()
    d.snapshot_weights()
    assert d.n_host_syncs == 2
    d.reset()
    assert d.n_host_syncs == 0 and d.n_observed == 0
    assert d.snapshot()[0].size == 0


def test_device_drift_matches_host_metrics():
    rng = np.random.default_rng(5)
    h1, h2 = DecayedSizeHistogram(), DecayedSizeHistogram()
    d1 = DeviceSizeSketch(num_buckets=512)
    d2 = DeviceSizeSketch(num_buckets=512)
    s1 = rng.integers(1, 500, 2_000)
    s2 = rng.integers(200, 480, 1_500)
    h1.observe_many(s1)
    d1.observe_many(s1)
    h2.observe_many(s2)
    d2.observe_many(s2)
    for metric in ("l1", "emd"):
        host = histogram_distance(h1.snapshot_weights(),
                                  h2.snapshot_weights(), metric=metric)
        dev = float(histogram_distance_device(
            d1.weights_device, d2.weights_device, metric=metric))
        assert dev == pytest.approx(host, abs=1e-5)


def test_device_drift_empty_semantics():
    import jax.numpy as jnp
    z = jnp.zeros(64)
    m = jnp.zeros(64).at[3].set(5.0)
    assert float(histogram_distance_device(z, z)) == 0.0
    assert float(histogram_distance_device(z, m)) == 1.0
    with pytest.raises(ValueError):
        histogram_distance_device(z, m, metric="chi2")


# -- controller device path --------------------------------------------------

def _phase_shift_setup(n: int):
    a, b = PAPER_WORKLOADS[0], PAPER_WORKLOADS[2]
    sizes = phase_shift_traffic(a, b, n_items=n, shift_at=0.5, seed=11)
    support, freqs = size_histogram(sizes[:n // 10])
    fit = SlabPolicy().fit(support, freqs, 6, method="dp")
    return sizes, schedule_with_default_tail(fit.chunk_sizes)


def test_controller_device_path_matches_host_decisions():
    n = 12_000
    sizes, deployed = _phase_shift_setup(n)
    common = dict(k=6, check_every=500, half_life=1000.0,
                  drift_threshold=0.12, min_items_between_refits=2000,
                  amortization_windows=8.0, cost_weight=0.1)
    host = SlabController(deployed, config=ControllerConfig(**common))
    dev = SlabController(deployed, config=ControllerConfig(
        **common, device=True, device_buckets=1 << 12))
    for i in range(0, n, 250):
        host.observe_many(sizes[i:i + 250])
        dev.observe_many(sizes[i:i + 250])
        host.maybe_refit()
        dev.maybe_refit()
    assert host.n_refits == dev.n_refits >= 1
    assert ([(d.approved, d.reason) for d in host.decisions]
            == [(d.approved, d.reason) for d in dev.decisions])
    assert list(host.chunks) == list(dev.chunks)
    # the whole point: the device path materializes the sketch only when
    # a refit is actually evaluated, not at every drift check
    assert dev.sketch.n_host_syncs < host.sketch.n_host_syncs / 4
    assert dev.last_drift == pytest.approx(host.last_drift, abs=1e-4)


def test_controller_device_drift_method():
    ctl = SlabController([64, 256], config=ControllerConfig(
        check_every=4, half_life=float("inf"), device=True,
        device_buckets=64, page_size=4096))
    assert ctl.drift() == 0.0                    # no reference yet
    ctl.observe_many([10, 10, 12, 13])
    assert ctl.maybe_refit() is None             # first check: adopt ref
    ctl.observe_many([50, 50, 50, 50])
    assert 0.0 < ctl.drift() <= 1.0
    assert ctl.sketch.n_host_syncs == 0          # all of that on device


def test_kv_pool_device_observe_batches():
    from repro.serving import KVSlabPool, default_pow2_classes
    pool = KVSlabPool(1 << 20, default_pow2_classes(max_chunk=1 << 13),
                      device_observe=True)
    assert pool.batch_observe and pool.controller.config.device
    assert pool.controller.config.device_bucket_width == pool.align
    # the bucket grid covers every ALLOCATABLE length, not just the
    # initial classes — refits can grow the top class without the
    # sketch silently clamping the traffic that motivates them
    cfg = pool.controller.config
    assert cfg.device_buckets * cfg.device_bucket_width >= pool.pool_tokens
    a = pool.alloc(1, 1000)
    assert a is not None
    assert pool.controller.n_observed == 0       # alloc no longer observes
    pool.observe_lengths(np.asarray([1000, 129, 4096]))
    assert pool.controller.n_observed == 3
    support, freqs = pool.controller.sketch.snapshot()
    assert support.tolist() == [256, 1024, 4096]  # ALIGN-quantized


def test_kv_pool_device_grid_widens_for_huge_pools():
    """When covering the pool at ALIGN resolution would exceed the
    bucket budget, the grid widens (coarser buckets) instead of
    silently clamping allocatable lengths into the top bucket."""
    from repro.serving import KVSlabPool
    pool = KVSlabPool(1 << 19, [256, 512], align=1, device_observe=True)
    cfg = pool.controller.config
    assert cfg.device_buckets <= 1 << 17
    assert cfg.device_bucket_width == 4          # 1 -> 2 -> 4
    assert cfg.device_buckets * cfg.device_bucket_width >= pool.pool_tokens


def test_batcher_batch_observe_includes_rejected_lengths():
    """Parity with the per-alloc path: alloc() observes a length BEFORE
    its failure exits, so batch-observe mode must feed rejected /
    uncoverable lengths too — they are exactly what a refit must learn."""
    from repro.serving import ContinuousBatcher, KVSlabPool, Request
    pool = KVSlabPool(1 << 14, [256, 512], device_observe=True)
    batcher = ContinuousBatcher(pool, max_batch=4, adaptive=False)
    batcher.submit(Request(rid=1, prompt_len=300, output_len=1))
    batcher.submit(Request(rid=2, prompt_len=4000, output_len=1))  # > 512
    batcher.step(0)
    assert batcher.rejected == 1
    assert pool.controller.n_observed == 2       # the reject was observed
    support, _ = pool.controller.sketch.snapshot()
    assert 4096 in support.tolist()              # quantized reject length


# -- property test: device sketch tracks the (fixed) host sketch -------------

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @hypothesis.given(
        seed=st.integers(0, 2**31 - 1),
        half_life=st.one_of(st.none(), st.floats(5.0, 5000.0)),
        max_bins=st.sampled_from([16, 64, 1 << 14]),
        n=st.integers(1, 400),
    )
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_device_sketch_tracks_host_property(seed, half_life, max_bins,
                                                n):
        """For random streams, decays, and prune pressure: every bin the
        host sketch kept agrees with the device bucket of the same size,
        and the device total never undershoots the host's (prunes only
        ever drop host mass — the device sketch has no prune)."""
        rng = np.random.default_rng(seed)
        sizes = rng.integers(1, 512, n)
        h = DecayedSizeHistogram(half_life=half_life, max_bins=max_bins)
        d = DeviceSizeSketch(half_life=half_life, num_buckets=512)
        for i in range(0, n, 97):
            h.observe_many(sizes[i:i + 97])
            d.observe_many(sizes[i:i + 97])
        host_s, host_w = h.snapshot_weights()
        dense = np.zeros(513)
        dense[np.asarray(d.snapshot_weights()[0])] = d.snapshot_weights()[1]
        for s, w in zip(host_s.tolist(), host_w.tolist()):
            assert dense[s] == pytest.approx(w, rel=1e-3, abs=1e-5)
        assert (np.asarray(d.weights_device).sum()
                >= h.effective_count * (1 - 1e-4))


# -- fused observe windows (single-launch cadence) ---------------------------

ENGINES = [
    pytest.param(dict(window_kernel=False), id="jnp-oracle"),
    pytest.param(dict(window_kernel=True, interpret=True),
                 id="pallas-interpret"),
]


def _reference_sketch(rng, engine):
    ref = DeviceSizeSketch(half_life=300.0, num_buckets=256,
                           bucket_width=4, **engine)
    ref.observe_many(rng.integers(1, 900, 300))
    return ref.weights_device


@pytest.mark.parametrize("engine", ENGINES)
def test_observe_window_bitwise_matches_sequential(engine):
    """One fused window over K ragged, weighted batches produces the
    SAME bits as K per-batch launches — sketch and drift scalar alike.

    Batch lengths here share one BLOCK_N pad band (all <= 128), where
    the window stacks rows at exactly the width each per-batch launch
    used — the condition under which the kernel engine is bit-stable
    (see test_window_cross_band_rounding for the cross-band contract)."""
    rng = np.random.default_rng(5)
    batches = [rng.integers(1, 900, n) for n in (64, 1, 33, 100, 128)]
    weights = [rng.uniform(0.25, 3.0, len(b)).astype(np.float32)
               for b in batches]
    reference = _reference_sketch(np.random.default_rng(9), engine)

    seq = DeviceSizeSketch(half_life=300.0, num_buckets=256,
                           bucket_width=4, **engine)
    for b, w in zip(batches, weights):
        seq.observe_many(b, w)
    drift_seq = float(histogram_distance_device(reference,
                                                seq.weights_device))

    win = DeviceSizeSketch(half_life=300.0, num_buckets=256,
                           bucket_width=4, window=True, **engine)
    # the fused launch must not smuggle in implicit device->host syncs
    with no_implicit_transfers():
        drift_win = win.observe_window(batches, weights,
                                       reference=reference)

    assert win.n_dispatches == 1
    assert win.n_observed == seq.n_observed
    np.testing.assert_array_equal(np.asarray(win.weights_device),
                                  np.asarray(seq.weights_device))
    assert float(drift_win) == drift_seq


def test_window_cross_band_rounding():
    """The padding contract across BLOCK_N bands: the jnp oracle stays
    BITWISE identical for arbitrarily ragged windows (scatter-add order
    is index-determined; zero pads are exact no-ops), while the kernel
    engine — whose padded grid shape changes across bands, and XLA does
    not promise identical rounding across different programs — may
    drift by ~1 f32 ulp, far inside every decision threshold."""
    rng = np.random.default_rng(8)
    lens = (64, 1, 33, 200, 300, 513)       # three different pad bands
    batches = [rng.integers(1, 900, n) for n in lens]
    weights = [rng.uniform(0.25, 3.0, n).astype(np.float32) for n in lens]
    for engine, exact in ((dict(window_kernel=False), True),
                          (dict(window_kernel=True, interpret=True),
                           False)):
        seq = DeviceSizeSketch(half_life=300.0, num_buckets=256,
                               bucket_width=4, **engine)
        for b, w in zip(batches, weights):
            seq.observe_many(b, w)
        win = DeviceSizeSketch(half_life=300.0, num_buckets=256,
                               bucket_width=4, window=True, **engine)
        win.observe_window(batches, weights)
        a = np.asarray(seq.weights_device)
        b_ = np.asarray(win.weights_device)
        if exact:
            np.testing.assert_array_equal(a, b_)
        else:
            np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("engine", ENGINES)
def test_window_buffering_is_invisible(engine):
    """window=True buffers observe_many batches (zero launches) and any
    state view flushes them — consumers cannot tell the modes apart."""
    rng = np.random.default_rng(2)
    batches = [rng.integers(1, 250, n) for n in (40, 7, 40)]
    plain = DeviceSizeSketch(half_life=50.0, num_buckets=64, **engine)
    win = DeviceSizeSketch(half_life=50.0, num_buckets=64, window=True,
                           **engine)
    for b in batches:
        plain.observe_many(b)
        win.observe_many(b)
    assert win.n_dispatches == 0          # everything still buffered
    assert win.n_observed == plain.n_observed
    sup_w, frq_w = win.snapshot()         # view -> implicit flush
    sup_p, frq_p = plain.snapshot()
    assert win.n_dispatches == 1
    np.testing.assert_array_equal(sup_w, sup_p)
    np.testing.assert_array_equal(frq_w, frq_p)
    assert win.effective_count == pytest.approx(plain.effective_count)


def test_window_flush_empty_is_noop_and_reset_clears_pending():
    win = DeviceSizeSketch(num_buckets=64, window=True,
                           window_kernel=False)
    assert win.flush_window() is None
    assert win.n_dispatches == 0
    win.observe_many([1, 2, 3])
    win.reset()
    assert win.n_observed == 0 and win.n_dispatches == 0
    assert win.snapshot()[0].size == 0    # pending was dropped, not kept


def test_fused_window_single_dispatch_no_retrace():
    """Dispatch-count regression: every same-shaped cadence window is
    exactly ONE launch of ONE compiled program (no per-window retrace —
    the trace counter in kernels.sketch_update ticks at most once)."""
    from repro.kernels import sketch_update as su
    rng = np.random.default_rng(0)
    win = DeviceSizeSketch(half_life=100.0, num_buckets=256, window=True,
                           window_kernel=False)
    win.observe_window([rng.integers(1, 900, 64) for _ in range(8)])
    traces0 = su.WINDOW_TRACE_COUNT
    with no_implicit_transfers():
        for _ in range(3):
            win.observe_window([rng.integers(1, 900, 64)
                                for _ in range(8)])
    assert win.n_dispatches == 4
    assert su.WINDOW_TRACE_COUNT == traces0      # shapes reuse the jit
    # ragged batch lengths pad to the same compiled shapes too
    win.observe_window([rng.integers(1, 900, n)
                        for n in (63, 64, 1, 17, 60, 64, 2, 9)])
    assert su.WINDOW_TRACE_COUNT == traces0
    assert win.n_dispatches == 5


def test_escaped_reference_survives_later_windows():
    """A weights_device reference handed out (the controller's drift
    reference) must stay valid across later fused launches — donation
    is skipped while a reference is escaped."""
    win = DeviceSizeSketch(num_buckets=64, window=True,
                           window_kernel=False)
    win.observe_many([10, 10, 20])
    ref = win.weights_device
    before = np.asarray(ref).copy()
    win.observe_window([[30, 40, 50]] * 4)
    np.testing.assert_array_equal(np.asarray(ref), before)


def test_controller_fused_window_matches_per_batch_decisions():
    """ControllerConfig.fused_observe must not change a single verdict:
    same decisions, same drifts, same final schedule — with one launch
    and at most one scalar sync per cadence window."""
    n = 12_000
    sizes, deployed = _phase_shift_setup(n)
    common = dict(k=6, check_every=500, half_life=1000.0,
                  drift_threshold=0.12, min_items_between_refits=2000,
                  amortization_windows=8.0, cost_weight=0.1,
                  device=True, device_buckets=1 << 12)
    per_batch = SlabController(deployed, config=ControllerConfig(
        **common, fused_observe=False))
    fused = SlabController(deployed, config=ControllerConfig(**common))
    assert fused.sketch._window and not per_batch.sketch._window
    # the whole drive runs under the transfer sanitizer: the only
    # device->host pulls allowed are the declared deliberate_sync sites
    # (drift gates, refit-search readbacks)
    with no_implicit_transfers():
        for i in range(0, n, 125):      # 4 batches per cadence window
            per_batch.observe_many(sizes[i:i + 125])
            fused.observe_many(sizes[i:i + 125])
            per_batch.maybe_refit()
            fused.maybe_refit()
    assert fused.n_refits == per_batch.n_refits >= 1
    assert ([(d.approved, d.reason, d.drift) for d in fused.decisions]
            == [(d.approved, d.reason, d.drift)
                for d in per_batch.decisions])
    assert list(fused.chunks) == list(per_batch.chunks)
    # the tentpole accounting contract: a cadence window of buffered
    # batches folds in ONE dispatch, the drift gate rides along as a
    # single scalar readback
    assert fused.sketch.n_dispatches <= fused.n_checks
    assert fused.sketch.n_scalar_syncs <= fused.n_checks
    assert fused.sketch.n_dispatches < per_batch.sketch.n_dispatches / 2


if HAVE_HYPOTHESIS:
    @hypothesis.given(
        seed=st.integers(0, 2**31 - 1),
        # one BLOCK_N pad band (<=128): the regime where the kernel
        # engine guarantees bit-identity (test_window_cross_band_rounding
        # covers the ulp-bounded cross-band contract)
        lens=st.lists(st.integers(1, 128), min_size=1, max_size=6),
        half_life=st.one_of(st.none(), st.floats(5.0, 2000.0)),
        weighted=st.booleans(),
    )
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_observe_window_property(seed, lens, half_life, weighted):
        """For random ragged windows, decays, and weights: the fused
        window is bit-identical to sequential launches on BOTH engines,
        and drift comes back identical to the standalone metric."""
        rng = np.random.default_rng(seed)
        batches = [rng.integers(1, 1000, n) for n in lens]
        weights = ([rng.uniform(0.1, 4.0, n).astype(np.float32)
                    for n in lens] if weighted else None)
        ref_sizes = rng.integers(1, 1000, 150)
        for engine in (dict(window_kernel=False),
                       dict(window_kernel=True, interpret=True)):
            ref = DeviceSizeSketch(half_life=half_life, num_buckets=128,
                                   bucket_width=8, **engine)
            ref.observe_many(ref_sizes)
            reference = ref.weights_device
            seq = DeviceSizeSketch(half_life=half_life, num_buckets=128,
                                   bucket_width=8, **engine)
            for i, b in enumerate(batches):
                seq.observe_many(b, None if weights is None
                                 else weights[i])
            drift_seq = float(histogram_distance_device(
                reference, seq.weights_device))
            win = DeviceSizeSketch(half_life=half_life, num_buckets=128,
                                   bucket_width=8, window=True, **engine)
            drift_win = win.observe_window(batches, weights,
                                           reference=reference)
            assert win.n_dispatches == 1
            np.testing.assert_array_equal(
                np.asarray(win.weights_device),
                np.asarray(seq.weights_device))
            assert float(drift_win) == drift_seq
