"""HLO cost-walker unit tests on canned HLO text (no devices needed)."""
import sys
import os

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))

from benchmarks.hlo_analysis import (_shape_bytes, analyze, parse_hlo)

CANNED = """\
HloModule jit_f, num_partitions=8

%body (param: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %param = (s32[], f32[16,128]) parameter(0)
  %iv = s32[] get-tuple-element(%param), index=0
  %x = f32[16,128] get-tuple-element(%param), index=1
  %w = f32[128,128] constant({...})
  %dot.1 = f32[16,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[16,128] all-reduce(%dot.1), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %next = s32[] add(%iv, %one)
  ROOT %tuple = (s32[], f32[16,128]) tuple(%next, %ar)
}

%cond (param.1: (s32[], f32[16,128])) -> pred[] {
  %param.1 = (s32[], f32[16,128]) parameter(0)
  %iv.1 = s32[] get-tuple-element(%param.1), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv.1, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[16,128]) -> f32[16,128] {
  %arg = f32[16,128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,128]) tuple(%zero, %arg)
  %loop = (s32[], f32[16,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[128,128] all-gather(%arg), replica_groups={}, dimensions={0}
  %w2 = f32[128,64] constant({...})
  %dot.2 = f32[128,64]{1,0} dot(%ag, %w2), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  ROOT %out = f32[16,128] get-tuple-element(%loop), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert _shape_bytes("bf16[4,4]{1,0}") == 32
    assert _shape_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert _shape_bytes("pred[]") == 1


def test_dot_flops_inside_while_trip_counted():
    res = analyze(CANNED)
    # loop dot: 2*16*128*128 per iter x 5 trips; entry dot: 2*128*64*16
    loop_flops = 5 * 2 * 16 * 128 * 128
    entry_flops = 2 * 128 * 64 * 128
    assert res.dot_flops == loop_flops + entry_flops


def test_collectives_attributed_with_trips():
    res = analyze(CANNED)
    ar = 5 * 16 * 128 * 4     # all-reduce inside the loop, x5
    ag = 128 * 128 * 4        # all-gather at entry, x1
    assert res.coll_by_kind["all-reduce"] == ar
    assert res.coll_by_kind["all-gather"] == ag
    assert res.collective_bytes == ar + ag


def test_cond_fallback_trip_count():
    no_backend = CANNED.replace(
        ', backend_config={"known_trip_count":{"n":"5"}}', "")
    res = analyze(no_backend)
    assert res.dot_flops == 5 * 2 * 16 * 128 * 128 + 2 * 128 * 64 * 128


def test_unknown_trip_defaults_to_one():
    txt = CANNED.replace(
        ', backend_config={"known_trip_count":{"n":"5"}}', "").replace(
        "direction=LT", "direction=NE")
    res = analyze(txt)
    assert res.dot_flops == 2 * 16 * 128 * 128 + 2 * 128 * 64 * 128


def test_parse_names_computations():
    stats = parse_hlo(CANNED)
    assert {"body", "cond", "add", "main"} <= set(stats)
    assert stats["main"].calls  # while edge to body
