"""Eviction-policy contract tests: segment promotion/demotion
invariants, rank ordering, predicted-vs-realized cost agreement,
allocator access-tracking edge cases, the KV retained-chunk mapping,
and the end-to-end refit-approval win over ColdestLRU."""
import math

import numpy as np
import pytest

from repro.core import ControllerConfig, SlabController
from repro.core.distribution import PAPER_WORKLOADS
from repro.memcached import (ColdestLRU, RankedPageEviction, SegmentedLRU,
                             SlabAllocator, make_policy,
                             zipfian_rereference_ops)
from repro.serving import KVSlabPool

PAGE = 4096


def seg_state(policy, cls):
    return policy._segs[id(cls)]


# -- registry ----------------------------------------------------------------

def test_make_policy_registry():
    assert isinstance(make_policy("coldest"), ColdestLRU)
    assert isinstance(make_policy("segmented"), SegmentedLRU)
    assert isinstance(make_policy("ranked"), RankedPageEviction)
    with pytest.raises(ValueError):
        make_policy("nope")


# -- ColdestLRU: the extracted legacy behaviour ------------------------------

def test_coldest_is_bitcompatible_with_legacy_lru():
    a = SlabAllocator([1024], mem_limit=PAGE, page_size=PAGE)  # 4 chunks
    for i in range(6):
        a.set(str(i), 1000)
    st = a.stats()
    assert st.n_evicted == 2
    assert not a.get("0") and not a.get("1")     # LRU head evicted first
    assert a.get("5")


def test_coldest_costs_are_wholesale():
    a = SlabAllocator([64, 512], page_size=PAGE)
    for i in range(4):
        a.set(f"k{i}", 500)
    # predicted teardown == full resident payload == realized eviction
    assert a.migration_cost_bytes([64]) == 2000
    report = a.reconfigure([64])
    assert report.evicted_bytes == 2000


# -- SegmentedLRU invariants -------------------------------------------------

def test_segmented_caps_hold_after_every_event():
    pol = SegmentedLRU(hot_max=0.32, warm_max=0.32)
    a = SlabAllocator([256], page_size=PAGE, eviction_policy=pol)
    rng = np.random.default_rng(0)
    for i in range(200):
        a.set(f"k{i}", 200)
        if rng.random() < 0.5:
            a.get(f"k{rng.integers(0, i + 1)}")
        cls = a.classes[0]
        hot, warm, cold = seg_state(pol, cls)
        n = len(cls.lru)
        assert len(hot) <= math.ceil(0.32 * n)
        assert len(warm) <= math.ceil(0.32 * n)
        assert len(hot) + len(warm) + len(cold) == n


def test_segmented_promotion_and_demotion_flow():
    pol = SegmentedLRU(hot_max=0.32, warm_max=0.32)
    a = SlabAllocator([256], page_size=PAGE, eviction_policy=pol)
    for i in range(50):
        a.set(f"k{i}", 200)
    cls = a.classes[0]
    hot, warm, cold = seg_state(pol, cls)
    assert "k0" in cold          # early inserts crawled out of HOT
    a.get("k0")                  # re-reference promotes COLD -> WARM
    assert "k0" in warm and "k0" not in cold
    assert "k49" in hot          # newest insert still HOT


def test_segmented_victims_come_from_cold_first():
    pol = SegmentedLRU()
    a = SlabAllocator([256], page_size=PAGE, eviction_policy=pol)
    for i in range(50):
        a.set(f"k{i}", 200)
    cls = a.classes[0]
    hot, warm, cold = seg_state(pol, cls)
    victim = pol.select_victim(cls)
    assert victim in cold
    n = len(cold)
    victims = pol.page_victims(cls, n + 2)
    assert set(victims[:n]) == set(cold)          # all of COLD before WARM/HOT


def test_segmented_cost_weights_by_segment():
    pol = SegmentedLRU(w_hot=1.0, w_warm=0.5, w_cold=0.05)
    a = SlabAllocator([256], page_size=PAGE, eviction_policy=pol)
    for i in range(50):
        a.set(f"k{i}", 200)
    cls = a.classes[0]
    raw = sum(cls.lru.values())
    cost = pol.class_teardown_cost_bytes(cls)
    assert 0 < cost < raw        # cheaper than wholesale, never free


# -- RankedPageEviction ordering ---------------------------------------------

def test_rank_ordering_follows_rereference_frequency():
    pol = RankedPageEviction(half_life=1000.0)
    a = SlabAllocator([256], page_size=PAGE, eviction_policy=pol)
    for i in range(20):
        a.set(f"k{i}", 200)
    for _ in range(5):
        a.get("k3")              # k3 is hot
    a.get("k7")                  # k7 is warm-ish
    cls = a.classes[0]
    assert pol.score(cls, "k3") > pol.score(cls, "k7")
    assert pol.score(cls, "k7") > pol.score(cls, "k0")
    victims = pol.page_victims(cls, 19)
    assert "k3" not in victims               # hottest survives
    order = {k: i for i, k in enumerate(victims)}
    assert order["k0"] < order["k7"]         # colder evicted earlier


def test_ranked_capacity_eviction_spares_hot_lru_head():
    # k0 sits at the LRU head position-wise but is re-referenced often;
    # plain LRU would evict it, the ranked scan must not.
    pol = RankedPageEviction(half_life=500.0, scan_width=8)
    a = SlabAllocator([1024], mem_limit=PAGE, page_size=PAGE,
                      eviction_policy=pol)   # 4 chunks
    for i in range(4):
        a.set(f"k{i}", 1000)
    for _ in range(5):
        a.get("k0")              # k0 is by far the hottest...
    for j in (1, 2, 3):
        a.get(f"k{j}")           # ...but ends up LRU-oldest positionally
    a.set("k4", 1000)            # forces one eviction
    assert a.get("k0")           # hot head survived (LRU would evict it)
    assert not a.get("k1")       # the low-score candidate went instead
    assert a.stats().n_evicted == 1


def test_ranked_page_cost_charges_only_likely_rereferenced_bytes():
    pol = RankedPageEviction()
    a = SlabAllocator([256], page_size=PAGE, eviction_policy=pol)
    for i in range(30):
        a.set(f"k{i}", 200)
    cls = a.classes[0]
    raw = sum(cls.lru[k] for k in pol.page_victims(cls, 10))
    predicted = pol.page_reclaim_cost_bytes(cls, 10)
    assert 0 < predicted < raw


# -- cost-model agreement (predicted vs realized) ----------------------------

@pytest.mark.parametrize("name", ["coldest", "segmented", "ranked"])
def test_page_release_prediction_bounds_realized_bytes(name):
    a = SlabAllocator([512], page_size=PAGE,
                      eviction_policy=make_policy(name))
    for i in range(16):          # two full pages
        a.set(f"k{i}", 500)
    predicted = a.page_release_cost_bytes()
    _, realized = a.release_page()
    assert predicted <= realized + 1e-9      # never over-charges
    if name == "coldest":
        assert predicted == realized         # wholesale model is exact


@pytest.mark.parametrize("name", ["coldest", "segmented", "ranked"])
def test_migration_cost_prediction_bounds_reconfigure(name):
    a = SlabAllocator([64, 512], page_size=PAGE,
                      eviction_policy=make_policy(name))
    for i in range(6):
        a.set(f"k{i}", 500)
    predicted = a.migration_cost_bytes([64, 600])
    report = a.reconfigure([64, 600])
    assert predicted <= report.evicted_bytes + 1e-9
    if name == "coldest":
        assert predicted == report.evicted_bytes


def test_reassign_victims_follow_policy_rank():
    pol = RankedPageEviction(half_life=500.0)
    a = SlabAllocator([512, 1024], page_size=PAGE, eviction_policy=pol)
    for i in range(8):           # one page of the 512 class
        a.set(f"k{i}", 500)
    for _ in range(4):
        for i in range(4):       # first half is hot
            a.get(f"k{i}")
    a.reassign(src=0, dst=1)     # reclaims one page = 8 chunks... all evicted
    # all residents evicted (class had exactly one page) — but a partial
    # reclaim must have preferred the cold half: check via page_victims
    b = SlabAllocator([512, 1024], page_size=PAGE,
                      eviction_policy=RankedPageEviction(half_life=500.0))
    for i in range(10):          # two pages, 8 + 2
        b.set(f"k{i}", 500)
    for _ in range(4):
        for i in range(6):
            b.get(f"k{i}")
    victims = b.policy.page_victims(b.classes[0], 4)
    assert set(victims) <= {f"k{i}" for i in range(6, 10)} | {"k4", "k5"}
    assert "k0" not in victims and "k1" not in victims


# -- allocator access-tracking edge cases ------------------------------------

def test_touch_on_get_missing_key_is_noop():
    a = SlabAllocator([256], page_size=PAGE)
    assert not a.get("ghost")
    assert a.op_clock == 1               # clock ticks, no state appears
    assert a.stats().reused_after_evict == 0
    assert "ghost" not in a._last_access


def test_reused_after_evict_counts_get_and_set_once():
    a = SlabAllocator([1024], mem_limit=PAGE, page_size=PAGE)  # 4 chunks
    for i in range(5):
        a.set(str(i), 1000)              # evicts "0"
    assert a.stats().n_evicted == 1
    assert not a.get("0")                # miss on evicted key: one reuse
    assert a.stats().reused_after_evict == 1
    assert not a.get("0")                # second miss does not double-count
    assert a.stats().reused_after_evict == 1
    a.set("1", 1000)                     # overwrite of a RESIDENT key: no
    assert a.stats().reused_after_evict == 1   # reuse (never evicted)


def test_refill_set_of_evicted_key_counts_reuse():
    a = SlabAllocator([1024], mem_limit=PAGE, page_size=PAGE)
    for i in range(5):
        a.set(str(i), 1000)
    a.set("0", 1000)                     # read-through refill
    assert a.stats().reused_after_evict == 1


def test_evicted_hot_bytes_tracks_recent_access():
    # cold eviction: the victim's last touch is > hot_window ops old
    a = SlabAllocator([1024], mem_limit=PAGE, page_size=PAGE,
                      hot_window=2)
    for i in range(4):
        a.set(str(i), 1000)
    a.set("4", 1000)                     # evicts "0", touched 4 ops ago
    assert a.stats().evicted_hot_bytes == 0
    # hot eviction: same flow, window generous enough to cover it
    b = SlabAllocator([1024], mem_limit=PAGE, page_size=PAGE,
                      hot_window=100)
    for i in range(4):
        b.set(str(i), 1000)
    b.set("4", 1000)
    assert b.stats().evicted_hot_bytes == 1000


def test_policy_swap_mid_run_rebuilds_state_and_keeps_counters():
    a = SlabAllocator([1024], mem_limit=PAGE, page_size=PAGE)
    for i in range(5):
        a.set(str(i), 1000)              # one eviction under coldest
    evicted_before = a.stats().n_evicted
    pol = SegmentedLRU()
    a.set_policy(pol)
    assert a.stats().eviction_policy == "segmented"
    assert a.stats().n_evicted == evicted_before     # counters carry over
    hot, warm, cold = seg_state(pol, a.classes[0])
    assert len(hot) + len(warm) + len(cold) == len(a.classes[0].lru)
    a.set("9", 1000)                     # eviction flows through new policy
    assert a.stats().n_evicted == evicted_before + 1


def test_access_state_consistent_across_reconfigure():
    a = SlabAllocator([64, 512], page_size=PAGE,
                      eviction_policy=RankedPageEviction())
    for i in range(4):
        a.set(f"k{i}", 500)
    a.get("k0")
    before = a.stats()
    report = a.reconfigure([64, 600])    # 512 vanishes, evicts everything
    st = a.stats()
    # cumulative counters persist across reconfigure...
    assert st.migration_evictions == before.migration_evictions + 4
    # ...while per-item access state of evicted keys is dropped
    assert all(f"k{i}" not in a._last_access for i in range(4))
    assert report.evicted_items == 4
    # and evicted keys are tracked for reuse detection
    assert not a.get("k0")
    assert a.stats().reused_after_evict == 1


def test_referenced_bytes_window():
    a = SlabAllocator([1024], page_size=PAGE)
    a.set("a", 1000)
    a.set("b", 1000)
    for _ in range(10):
        a.get("b")
    assert a.referenced_bytes(5) == 1000          # only "b" is recent
    assert a.referenced_bytes(10**9) == 2000      # everything, eventually


# -- KV pool: the policy on finished-sequence token pages --------------------

def test_kv_finish_retain_and_reuse_roundtrip():
    kv = KVSlabPool(1024, [128, 256, 512])
    kv.alloc(1, 500)
    assert kv.finish(1, retain=True)
    st = kv.stats()
    assert st.n_retained == 1 and st.retained_tokens == 512
    assert st.active_requests == 0
    back = kv.reuse(1)
    assert back is not None and back.chunk == 512
    assert kv.stats().n_retained == 0
    assert kv.stats().n_retained_reused == 1


def test_kv_pressure_evicts_least_valuable_retained():
    kv = KVSlabPool(1024, [128, 256, 512],
                    eviction_policy=make_policy("ranked"))
    kv.alloc(1, 500)
    kv.alloc(2, 250)
    kv.alloc(3, 250)                     # pool now full (512+256+256)
    kv.finish(1)
    kv.finish(2)
    kv.touch_retained(2)                 # 2 looks reusable, 1 does not
    a4 = kv.alloc(4, 400)                # needs 512: must evict retained 1
    assert a4 is not None
    assert kv.reuse(1) is None           # 1 was the victim
    assert kv.reuse(2) is not None       # 2 survived
    assert kv.stats().n_retained_evicted == 1


def test_kv_retained_larger_chunk_carves_remainder():
    kv = KVSlabPool(512, [128, 512])
    kv.alloc(1, 500)
    kv.finish(1)                         # 512 retained, pool exhausted
    a2 = kv.alloc(2, 100)                # 128 carved out of the 512 victim
    assert a2 is not None and a2.chunk == 128
    assert kv.stats().n_retained_evicted == 1
    assert kv.alloc(3, 100) is not None  # remainder reached the freelist


def test_kv_retained_id_collision_recycles_old_chunk():
    # finish -> alloc (same id) -> finish must not leak the first chunk
    kv = KVSlabPool(1024, [512])
    kv.alloc(1, 500)
    kv.finish(1)
    kv.alloc(1, 500)                     # id reuse: stale retained entry
    kv.finish(1)
    st = kv.stats()
    assert st.n_retained == 1 and st.retained_tokens == 512
    assert st.free_tokens == 512         # first range back in the freelist
    assert kv.alloc(2, 500) is not None  # and actually reachable


def test_kv_finish_no_retain_frees():
    kv = KVSlabPool(1024, [512])
    kv.alloc(1, 500)
    assert not kv.finish(1, retain=False)
    assert kv.stats().n_retained == 0
    assert kv.alloc(2, 500) is not None  # chunk went back to the freelist


# -- zipfian re-reference traffic --------------------------------------------

def test_zipfian_ops_shape_and_skew():
    ops = zipfian_rereference_ops(PAPER_WORKLOADS[:2], n_ops=5000, seed=1)
    assert len(ops) == 5000
    assert {o.op for o in ops} <= {"get", "set"}
    gets = [o for o in ops if o.op == "get"]
    assert 0.6 < len(gets) / len(ops) < 0.8      # get_frac=0.7
    # zipf head: rank-0 keys dominate re-references
    from collections import Counter
    top = Counter(o.key for o in gets).most_common(1)[0]
    assert top[0].endswith(":z0") and top[1] > len(gets) / 50
    # gets carry the refill payload
    assert all(o.size > 0 for o in gets)


def test_zipfian_tail_shift_changes_identity_not_head():
    ops = zipfian_rereference_ops(PAPER_WORKLOADS[:1], n_ops=4000,
                                  alt_workloads=[PAPER_WORKLOADS[2]],
                                  shift_at=0.5, head_frac=0.05, seed=1)
    first, second = ops[:2000], ops[2000:]
    assert not any(o.key.split(":")[1].startswith("b") for o in first)
    assert any(o.key.split(":")[1].startswith("b") for o in second)
    # head keys (low zipf ranks) keep their identity after the shift
    head_keys = {o.key for o in second if o.key.endswith(":z0")}
    assert head_keys                     # rank-0 still referenced as z0


def test_zipfian_single_workload_no_alt_never_shifts():
    ops = zipfian_rereference_ops(PAPER_WORKLOADS[:1], n_ops=2000, seed=1)
    assert not any(":b" in o.key for o in ops)


# -- end-to-end: refit-approval win over ColdestLRU --------------------------

def test_e2e_honest_cost_model_approves_refit_coldest_vetoes():
    """The ISSUE's scenario, compact: phase one fills the cache with
    items the traffic then stops referencing; phase two switches to a
    small size the current schedule wastes heavily on. The wholesale
    model charges the full stale payload and vetoes the refit; the
    ranked model prices the dead residents near zero, approves the same
    refit, and ends with less insert-charged waste. (The full-scale
    version of this comparison is `adaptive_bench.py --policy ranked`.)
    """
    page = 1 << 20
    results = {}
    for name in ("coldest", "ranked"):
        policy = (make_policy("ranked", half_life=300.0)
                  if name == "ranked" else make_policy(name))
        alloc = SlabAllocator([1024, 2048], page_size=page,
                              eviction_policy=policy)
        ctl = SlabController([1024, 2048], config=ControllerConfig(
            k=2, page_size=page, check_every=400, half_life=400.0,
            drift_threshold=0.12, min_items_between_refits=400,
            amortization_windows=4.0, cost_weight=1.0))
        waste = stored = 0
        key = 0

        def store(size, alloc=alloc, ctl=ctl):
            nonlocal waste, stored, key
            cs = alloc.chunk_sizes
            idx = int(np.searchsorted(cs, size, side="left"))
            waste += int(cs[idx]) - size if idx < len(cs) else page - size
            stored += size
            alloc.set(f"k{key}", size)
            key += 1
            ctl.observe(size)
            d = ctl.maybe_refit(
                cost_bytes_fn=lambda c: alloc.migration_cost_bytes(c))
            if d is not None and d.approved:
                alloc.reconfigure(d.chunks)
                ctl.set_chunks(alloc.chunk_sizes)

        for _ in range(5000):            # phase 1: 700-byte residents of
            store(700)                   # the 1024 class, then never
        #                                  referenced again (stale tail)
        for _ in range(1200):            # phase 2: 1100-byte items forced
            store(1100)                  # into 2048 (heavy recurring waste
        #                                  until a refit drops the 1024s)
        results[name] = (ctl.n_refits, waste / stored,
                         [d.reason for d in ctl.decisions])

    coldest_refits, coldest_waste, coldest_reasons = results["coldest"]
    ranked_refits, ranked_waste, _ = results["ranked"]
    assert ranked_refits > coldest_refits          # the approval win
    assert "cost-exceeds-savings" in coldest_reasons
    assert ranked_waste < coldest_waste            # and it paid off
